"""Dead-link check for the docs tree (stdlib-only; runs in the CI lint
job, which installs no project dependencies).

Scans ``docs/*.md`` and ``README.md`` for Markdown links and fails on
any *relative* target that does not exist on disk.  External schemes
(``http(s)``, ``mailto``) and pure in-page anchors are skipped; a
``path#anchor`` target is checked for the path only — anchor text is
renderer-specific and not worth pinning.

Usage::

  python tools/check_docs.py [repo_root]
"""
from __future__ import annotations

import os
import re
import sys

# inline links [text](target); images ![alt](target) match too via the
# same suffix.  Angle-bracketed targets <...> are unwrapped below.
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_SKIP_SCHEMES = ("http://", "https://", "mailto:")


def doc_files(root: str) -> list:
    out = []
    readme = os.path.join(root, "README.md")
    if os.path.exists(readme):
        out.append(readme)
    docs = os.path.join(root, "docs")
    if os.path.isdir(docs):
        for fn in sorted(os.listdir(docs)):
            if fn.endswith(".md"):
                out.append(os.path.join(docs, fn))
    return out


def check_file(path: str) -> list:
    """(line, target, reason) for every dead relative link in one file."""
    bad = []
    base = os.path.dirname(path)
    with open(path, encoding="utf-8") as f:
        for lineno, line in enumerate(f, 1):
            for m in _LINK_RE.finditer(line):
                target = m.group(1).strip("<>")
                if not target or target.startswith("#"):
                    continue
                if target.startswith(_SKIP_SCHEMES):
                    continue
                rel = target.split("#", 1)[0]
                if not rel:
                    continue
                resolved = os.path.normpath(os.path.join(base, rel))
                if not os.path.exists(resolved):
                    bad.append((lineno, target, resolved))
    return bad


def main(argv) -> int:
    root = argv[1] if len(argv) > 1 else "."
    files = doc_files(root)
    if not files:
        print(f"docs-check: no Markdown files found under {root!r}")
        return 1
    failures = 0
    for path in files:
        for lineno, target, resolved in check_file(path):
            rel = os.path.relpath(path, root)
            print(f"{rel}:{lineno}: dead relative link ({target}) — "
                  f"{resolved} does not exist")
            failures += 1
    if failures:
        print(f"docs-check: {failures} dead link(s)")
        return 1
    print(f"docs-check: {len(files)} file(s) clean")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
