# Tier-1 verification + the compat-shim grep gate.
#
# `make check` is the CI entry point: it enforces the repo rule that no
# version-sensitive JAX attribute lookup (jax.shard_map / jax.typeof /
# jax.lax.pcast / jax.lax.pvary / pltpu.[TPU]CompilerParams) appears
# outside src/repro/compat.py (the recursive grep covers every package,
# src/repro/eig/ included), that the eig subsystem routes all rotation
# application through the dispatch registry (eig-gate), that internal
# code speaks RotationSequence rather than raw (A, C, S) arrays
# (seq-gate), then runs the full test suite.

.PHONY: check test compat-gate eig-gate seq-gate smoke bench

check: compat-gate eig-gate seq-gate test

# pytest.ini promotes the library's own DeprecationWarnings to errors
# when they originate *from repro internals* (module regex; a -W flag
# cannot express this because it escapes+anchors the module field):
# internal callers must stay on the typed RotationSequence API, while
# external callers of the compat wrappers only get the warning.
test:
	PYTHONPATH=src python -m pytest -q

compat-gate:
	@! grep -rnE 'jax\.shard_map|jax\.typeof|jax\.lax\.p(cast|vary)\b|pltpu\.(TPU)?CompilerParams' \
		--include='*.py' src benchmarks examples tests \
		| grep -v 'src/repro/compat\.py' \
		|| { echo 'compat-gate FAILED: version-sensitive JAX attrs outside src/repro/compat.py (see matches above)'; exit 1; }
	@echo 'compat-gate OK'

# src/repro/eig must dispatch every application through the registry API
# (apply_rotation_sequence / DelayedRotationBuffer) — never a backend or
# kernel module directly, or the cost model + plan cache are bypassed.
eig-gate:
	@! grep -rnE 'repro\.kernels|core\.(blocked|accumulate|ref)\b|rot_sequence_(blocked|accumulated|unoptimized|wavefront|wave|mxu)' \
		--include='*.py' src/repro/eig \
		|| { echo 'eig-gate FAILED: src/repro/eig must go through the dispatch registry (see matches above)'; exit 1; }
	@echo 'eig-gate OK'

# Internal code must construct RotationSequence objects and go through
# seq.plan / SequencePlan.apply; the raw-array entry point
# apply_rotation_sequence(...) is the *external* compatibility wrapper
# and may only be called from core/api.py itself.
seq-gate:
	@! grep -rnE 'apply_rotation_sequence\s*\(' \
		--include='*.py' src/repro \
		| grep -v 'src/repro/core/api\.py' \
		|| { echo 'seq-gate FAILED: internal raw (A, C, S) application outside core/api.py — construct a RotationSequence and use seq.plan(...).apply (see matches above)'; exit 1; }
	@echo 'seq-gate OK'

smoke:
	PYTHONPATH=src:. python benchmarks/run.py --only smoke

bench:
	PYTHONPATH=src:. python benchmarks/run.py
