# Tier-1 verification + the compat-shim grep gate.
#
# `make check` is the CI entry point: it enforces the repo rule that no
# version-sensitive JAX attribute lookup (jax.shard_map / jax.typeof /
# jax.lax.pcast / jax.lax.pvary / pltpu.[TPU]CompilerParams) appears
# outside src/repro/compat.py (the recursive grep covers every package,
# src/repro/eig/ included), that the eig subsystem routes all rotation
# application through the dispatch registry (eig-gate), that internal
# code speaks RotationSequence rather than raw (A, C, S) arrays
# (seq-gate), that the serving path applies rotations only through
# SequencePlan/RotationSequence (serve-gate), then runs the full test
# suite.

.PHONY: check test compat-gate eig-gate seq-gate serve-gate smoke bench \
	bench-artifacts bench-compare

check: compat-gate eig-gate seq-gate serve-gate test

# pytest.ini promotes the library's own DeprecationWarnings to errors
# when they originate *from repro internals* (module regex; a -W flag
# cannot express this because it escapes+anchors the module field):
# internal callers must stay on the typed RotationSequence API, while
# external callers of the compat wrappers only get the warning.
#
# Parallelism: pytest-xdist (`-n auto`) when installed — CI installs it
# via requirements-dev.txt; environments without it degrade to serial.
# Fail-fast is --maxfail=1 rather than -x because -x is unreliable
# across xdist workers.
PYTEST_PAR := $(shell python -c 'import xdist' 2>/dev/null && echo '-n auto')
test:
	PYTHONPATH=src python -m pytest -q --maxfail=1 $(PYTEST_PAR)

compat-gate:
	@! grep -rnE 'jax\.shard_map|jax\.typeof|jax\.lax\.p(cast|vary)\b|pltpu\.(TPU)?CompilerParams' \
		--include='*.py' src benchmarks examples tests \
		| grep -v 'src/repro/compat\.py' \
		|| { echo 'compat-gate FAILED: version-sensitive JAX attrs outside src/repro/compat.py (see matches above)'; exit 1; }
	@echo 'compat-gate OK'

# src/repro/eig must dispatch every application through the registry API
# (apply_rotation_sequence / DelayedRotationBuffer) — never a backend or
# kernel module directly, or the cost model + plan cache are bypassed.
eig-gate:
	@! grep -rnE 'repro\.kernels|core\.(blocked|accumulate|ref)\b|rot_sequence_(blocked|accumulated|unoptimized|wavefront|wave|mxu|batched)' \
		--include='*.py' src/repro/eig \
		|| { echo 'eig-gate FAILED: src/repro/eig must go through the dispatch registry (see matches above)'; exit 1; }
	@echo 'eig-gate OK'

# Internal code must construct RotationSequence objects and go through
# seq.plan / SequencePlan.apply; the raw-array entry point
# apply_rotation_sequence(...) is the *external* compatibility wrapper
# and may only be called from core/api.py itself.
seq-gate:
	@! grep -rnE 'apply_rotation_sequence\s*\(' \
		--include='*.py' src/repro \
		| grep -v 'src/repro/core/api\.py' \
		|| { echo 'seq-gate FAILED: internal raw (A, C, S) application outside core/api.py — construct a RotationSequence and use seq.plan(...).apply (see matches above)'; exit 1; }
	@echo 'seq-gate OK'

# The serving path (RotationService + launch/serve.py) must apply
# rotations only through SequencePlan / RotationSequence (which route
# bucket drains to the fused rotseq_batched backend or the per-request
# vmap/loop fallback) — never the raw-array compat wrapper, a backend
# module, or a kernel (the fused one included) directly — or bucket
# plans stop being the single dispatch point.
serve-gate:
	@! grep -rnE 'apply_rotation_sequence\s*\(|repro\.kernels|core\.(blocked|accumulate|ref)\b|rot_sequence_(blocked|accumulated|unoptimized|wavefront|wave|mxu|batched)|rotseq_batched_pallas' \
		--include='*.py' src/repro/serve src/repro/launch/serve.py \
		|| { echo 'serve-gate FAILED: the serving path must apply rotations through SequencePlan/RotationSequence only, fused or vmap (see matches above)'; exit 1; }
	@echo 'serve-gate OK'

smoke:
	PYTHONPATH=src:. python benchmarks/run.py --only smoke

bench:
	PYTHONPATH=src:. python benchmarks/run.py

# CI perf artifacts: JSON rows for the regression compare + upload.
bench-artifacts:
	PYTHONPATH=src:. python benchmarks/run.py --only smoke --json BENCH_smoke.json
	PYTHONPATH=src:. python benchmarks/bench_eig.py --quick --json BENCH_eig.json
	PYTHONPATH=src:. python benchmarks/run.py --only serve --json BENCH_serve.json

# Fails when a tracked metric (counts exactly; interpret-mode rates by
# >30%) regresses vs benchmarks/baselines/bench_baseline.json.
# Regenerate the baseline with:
#   python benchmarks/compare_baseline.py --update --baseline \
#     benchmarks/baselines/bench_baseline.json BENCH_*.json
bench-compare:
	PYTHONPATH=src:. python benchmarks/compare_baseline.py \
		--baseline benchmarks/baselines/bench_baseline.json \
		BENCH_smoke.json BENCH_eig.json BENCH_serve.json
