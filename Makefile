# Tier-1 verification + the compat-shim grep gate.
#
# `make check` is the CI entry point: it enforces the repo rule that no
# version-sensitive JAX attribute lookup (jax.shard_map / jax.typeof /
# jax.lax.pcast / jax.lax.pvary / pltpu.[TPU]CompilerParams) appears
# outside src/repro/compat.py (the recursive grep covers every package,
# src/repro/eig/ included), that the eig subsystem routes all rotation
# application through the dispatch registry (eig-gate), then runs the
# full test suite.

.PHONY: check test compat-gate eig-gate smoke bench

check: compat-gate eig-gate test

test:
	PYTHONPATH=src python -m pytest -q

compat-gate:
	@! grep -rnE 'jax\.shard_map|jax\.typeof|jax\.lax\.p(cast|vary)\b|pltpu\.(TPU)?CompilerParams' \
		--include='*.py' src benchmarks examples tests \
		| grep -v 'src/repro/compat\.py' \
		|| { echo 'compat-gate FAILED: version-sensitive JAX attrs outside src/repro/compat.py (see matches above)'; exit 1; }
	@echo 'compat-gate OK'

# src/repro/eig must dispatch every application through the registry API
# (apply_rotation_sequence / DelayedRotationBuffer) — never a backend or
# kernel module directly, or the cost model + plan cache are bypassed.
eig-gate:
	@! grep -rnE 'repro\.kernels|core\.(blocked|accumulate|ref)\b|rot_sequence_(blocked|accumulated|unoptimized|wavefront|wave|mxu)' \
		--include='*.py' src/repro/eig \
		|| { echo 'eig-gate FAILED: src/repro/eig must go through the dispatch registry (see matches above)'; exit 1; }
	@echo 'eig-gate OK'

smoke:
	PYTHONPATH=src:. python benchmarks/run.py --only smoke

bench:
	PYTHONPATH=src:. python benchmarks/run.py
