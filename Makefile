# Tier-1 verification + the compat-shim grep gate.
#
# `make check` is the CI entry point: it enforces the repo rule that no
# version-sensitive JAX attribute lookup (jax.shard_map / jax.typeof /
# jax.lax.pcast / jax.lax.pvary / pltpu.[TPU]CompilerParams) appears
# outside src/repro/compat.py, then runs the full test suite.

.PHONY: check test compat-gate smoke bench

check: compat-gate test

test:
	PYTHONPATH=src python -m pytest -q

compat-gate:
	@! grep -rnE 'jax\.shard_map|jax\.typeof|jax\.lax\.p(cast|vary)\b|pltpu\.(TPU)?CompilerParams' \
		--include='*.py' src benchmarks examples tests \
		| grep -v 'src/repro/compat\.py' \
		|| { echo 'compat-gate FAILED: version-sensitive JAX attrs outside src/repro/compat.py (see matches above)'; exit 1; }
	@echo 'compat-gate OK'

smoke:
	PYTHONPATH=src:. python benchmarks/run.py --only smoke

bench:
	PYTHONPATH=src:. python benchmarks/run.py
