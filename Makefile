# Tier-1 verification + static invariant analysis.
#
# `make check` is the CI entry point: `make lint` runs the AST-based
# invariant analyzer (src/repro/analysis — rule families RA1 compat
# isolation, RA2 dispatch layering, RA3 bitwise contract, RA4 kernel
# hygiene, RA5 plan-cache determinism) plus ruff when available, then
# the full test suite runs.  The analyzer replaced the four grep gates
# (compat/eig/seq/serve): it resolves import aliases, walks pallas_call
# kernel bodies, and suppresses via `# repro-lint: disable=RAx` — see
# `python -m repro.analysis --list-rules`.

.PHONY: check lint analyze ruff docs-check test smoke bench \
	bench-artifacts bench-compare obs-report

check: lint test

lint: analyze ruff docs-check

# Dead relative links in docs/*.md + README.md.  Stdlib-only on
# purpose: the CI lint job installs no project dependencies.
docs-check:
	python tools/check_docs.py .

# Mtime-cached AST walk (REPRO_LINT_CACHE=off disables); exits 1 on any
# non-baselined violation.
analyze:
	PYTHONPATH=src python -m repro.analysis

# ruff is optional locally (CI installs it via requirements-dev.txt);
# config in ruff.toml.
ruff:
	@command -v ruff >/dev/null 2>&1 \
		&& ruff check . \
		|| echo 'ruff not installed; skipping (CI runs it)'

# pytest.ini promotes the library's own DeprecationWarnings to errors
# when they originate *from repro internals* (module regex; a -W flag
# cannot express this because it escapes+anchors the module field):
# internal callers must stay on the typed RotationSequence API, while
# external callers of the compat wrappers only get the warning.
#
# Parallelism: pytest-xdist (`-n auto`) when installed — CI installs it
# via requirements-dev.txt; environments without it degrade to serial.
# Fail-fast is --maxfail=1 rather than -x because -x is unreliable
# across xdist workers.
PYTEST_PAR := $(shell python -c 'import xdist' 2>/dev/null && echo '-n auto')
test:
	PYTHONPATH=src python -m pytest -q --maxfail=1 $(PYTEST_PAR)

smoke:
	PYTHONPATH=src:. python benchmarks/run.py --only smoke

bench:
	PYTHONPATH=src:. python benchmarks/run.py

# CI perf artifacts: JSON rows for the regression compare + upload.
bench-artifacts:
	PYTHONPATH=src:. python benchmarks/run.py --only smoke --json BENCH_smoke.json
	PYTHONPATH=src:. python benchmarks/bench_eig.py --quick --json BENCH_eig.json
	PYTHONPATH=src:. python benchmarks/run.py --only serve --json BENCH_serve.json
	PYTHONPATH=src:. python benchmarks/bench_dist.py --quick --json BENCH_dist.json

# Fails when a tracked metric (counts exactly; interpret-mode rates by
# >30%) regresses vs benchmarks/baselines/bench_baseline.json.
# Regenerate the baseline with:
#   python benchmarks/compare_baseline.py --update --baseline \
#     benchmarks/baselines/bench_baseline.json BENCH_*.json
bench-compare:
	PYTHONPATH=src:. python benchmarks/compare_baseline.py \
		--baseline benchmarks/baselines/bench_baseline.json \
		BENCH_smoke.json BENCH_eig.json BENCH_serve.json BENCH_dist.json

# Observability report: obs-enabled rotation-serving runs writing the
# metrics + roofline snapshot (OBS_metrics.json) and a Perfetto-loadable
# Chrome trace (trace.jsonl — load at ui.perfetto.dev), once through the
# synchronous service and once through the streaming engine
# (OBS_stream_metrics.json / trace_stream.jsonl, bit-checked against the
# synchronous drain).  See the README "Observability" section for the
# metric catalogue.
obs-report:
	PYTHONPATH=src python -m repro.launch.serve --rotations \
		--requests 24 --slots 8 --check \
		--metrics-json OBS_metrics.json --trace trace.jsonl
	PYTHONPATH=src python -m repro.launch.serve --rotations --stream \
		--requests 24 --slots 8 --check \
		--metrics-json OBS_stream_metrics.json --trace trace_stream.jsonl
