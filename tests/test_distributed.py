"""Distributed tests run in subprocesses with 8 host devices so the main
pytest process keeps a single device (the dry-run owns 512)."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = REPO_SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=900, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_row_and_column_sharded_rotseq():
    out = _run("""
        import warnings
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.rotations import random_sequence
        from repro.core.ref import rot_sequence_numpy
        from repro.core.distributed import (rot_sequence_row_sharded,
            rot_sequence_column_sharded_padded)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rng = np.random.default_rng(5)
        for (m, n, k, n_b, k_b, method) in [
                (8, 32, 5, 4, 2, "blocked"), (16, 64, 7, 8, 4, "blocked"),
                (8, 32, 9, 8, 3, "accumulated"),
                (4, 64, 2, 16, 8, "accumulated")]:
            A = rng.standard_normal((m, n)).astype(np.float32)
            seq = random_sequence(jax.random.key(n + k), n, k)
            ref = rot_sequence_numpy(A, seq.cos, seq.sin)
            o1 = rot_sequence_row_sharded(jnp.array(A), seq, mesh,
                                          n_b=n_b, k_b=k_b)
            o2 = rot_sequence_column_sharded_padded(
                jnp.array(A), seq, mesh, col_axis="model",
                n_b=n_b, k_b=k_b, row_axes=("data",), method=method)
            for o in (o1, o2):
                err = np.abs(np.asarray(o, np.float64) - ref).max()
                assert err < 1e-4, (m, n, k, method, err)
        # legacy raw-array signature still works, with a DeprecationWarning
        A = rng.standard_normal((8, 32)).astype(np.float32)
        seq = random_sequence(jax.random.key(0), 32, 5)
        ref = rot_sequence_numpy(A, seq.cos, seq.sin)
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            o = rot_sequence_row_sharded(jnp.array(A), seq.cos, seq.sin,
                                         mesh, n_b=4, k_b=2)
        assert any(issubclass(x.category, DeprecationWarning) for x in w)
        assert np.abs(np.asarray(o, np.float64) - ref).max() < 1e-4
        # mesh accepted as a keyword; forgetting it is a clear TypeError
        o = rot_sequence_row_sharded(jnp.array(A), seq, mesh=mesh,
                                     n_b=4, k_b=2)
        assert np.abs(np.asarray(o, np.float64) - ref).max() < 1e-4
        try:
            rot_sequence_row_sharded(jnp.array(A), seq)
        except TypeError as e:
            assert "mesh" in str(e), e
        else:
            raise AssertionError("missing mesh must raise TypeError")
        print("DIST OK")
    """)
    assert "DIST OK" in out


def test_mini_dryrun_multipod_mesh():
    """(2,2,2) pod/data/model mini-mesh: lower+compile a reduced arch with
    the same code path as the production dry-run."""
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.launch.mesh import make_rules_for_mesh
        from repro.launch.specs import (abstract_opt_state, input_specs,
                                        sharding_trees)
        from repro.models import build_model
        from repro.optim import AdamW
        from repro.parallel.sharding import axis_rules
        from repro.train import make_train_step
        from repro.configs.base import ShapeConfig

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = get_config("smollm-135m").reduced()
        shape = ShapeConfig("mini", 64, 8, "train")
        rules = make_rules_for_mesh(mesh)
        model = build_model(cfg)
        opt = AdamW(lr=1e-4)
        with axis_rules(rules, mesh=mesh):
            trees = sharding_trees(model, cfg, shape, opt, rules, mesh)
            step = make_train_step(model, cfg, opt)
            jf = jax.jit(step,
                         in_shardings=(trees["params"], trees["opt"],
                                       trees["batch"]),
                         out_shardings=(trees["params"], trees["opt"],
                                        None))
            lowered = jf.lower(trees["params_abs"],
                               abstract_opt_state(opt, trees["params_abs"]),
                               input_specs(cfg, shape))
            compiled = lowered.compile()
        assert compiled.memory_analysis().temp_size_in_bytes > 0
        txt = compiled.as_text()
        assert any(c in txt for c in ("all-reduce", "all-gather",
                                      "reduce-scatter")), "no collectives?"
        print("MINI DRYRUN OK")
    """)
    assert "MINI DRYRUN OK" in out


def test_hlo_collectives_accounting():
    """Collective bytes from the loop-aware analyzer: an all-reduce inside
    a scan of length L must count L times."""
    out = _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.launch.hlo_analysis import analyze_hlo
        mesh = jax.make_mesh((8,), ("d",))
        L, M = 5, 64

        def f(x, ws):
            def body(x, w):
                return jnp.tanh(x @ w), None
            x, _ = jax.lax.scan(body, x, ws)
            return x

        sh_x = NamedSharding(mesh, P(None, "d"))
        sh_w = NamedSharding(mesh, P(None, "d", None))
        jf = jax.jit(f, in_shardings=(sh_x, sh_w),
                     out_shardings=sh_x)
        comp = jf.lower(jax.ShapeDtypeStruct((M, M), jnp.float32),
                        jax.ShapeDtypeStruct((L, M, M), jnp.float32)
                        ).compile()
        hc = analyze_hlo(comp.as_text())
        total_coll = sum(hc.collective_bytes.values())
        n_coll = sum(hc.collective_counts.values())
        assert n_coll >= L, (n_coll, hc.collective_counts)
        assert hc.flops >= L * 2 * M * M * (M // 8) * 0.9
        print("HLO COLL OK", hc.collective_counts)
    """)
    assert "HLO COLL OK" in out
