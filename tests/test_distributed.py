"""Distributed tests run in subprocesses with 8 host devices so the main
pytest process keeps a single device (the dry-run owns 512).  Pure
cost-model/plan-key tests (no mesh needed) run in-process."""
import math
import os
import subprocess
import sys
import textwrap

import pytest

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = REPO_SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=900, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_row_and_column_sharded_rotseq():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.rotations import random_sequence
        from repro.core.ref import rot_sequence_numpy
        from repro.dist import (rot_sequence_row_sharded,
            rot_sequence_column_sharded_padded)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rng = np.random.default_rng(5)
        for (m, n, k, n_b, k_b, method) in [
                (8, 32, 5, 4, 2, "blocked"), (16, 64, 7, 8, 4, "blocked"),
                (8, 32, 9, 8, 3, "accumulated"),
                (4, 64, 2, 16, 8, "accumulated")]:
            A = rng.standard_normal((m, n)).astype(np.float32)
            seq = random_sequence(jax.random.key(n + k), n, k)
            ref = rot_sequence_numpy(A, seq.cos, seq.sin)
            o1 = rot_sequence_row_sharded(jnp.array(A), seq, mesh,
                                          n_b=n_b, k_b=k_b)
            o2 = rot_sequence_column_sharded_padded(
                jnp.array(A), seq, mesh, col_axis="model",
                n_b=n_b, k_b=k_b, row_axes=("data",), method=method)
            for o in (o1, o2):
                err = np.abs(np.asarray(o, np.float64) - ref).max()
                assert err < 1e-4, (m, n, k, method, err)
        # the deprecated raw (A, C, S, mesh) positional form is removed:
        # passing bare cos arrays is now a plain TypeError, not a warning
        A = rng.standard_normal((8, 32)).astype(np.float32)
        seq = random_sequence(jax.random.key(0), 32, 5)
        ref = rot_sequence_numpy(A, seq.cos, seq.sin)
        try:
            rot_sequence_row_sharded(jnp.array(A), seq.cos, seq.sin,
                                     mesh, n_b=4, k_b=2)
        except TypeError:
            pass  # too many positional arguments
        else:
            raise AssertionError("raw (A, C, S, mesh) form must raise")
        try:
            rot_sequence_row_sharded(jnp.array(A), seq.cos, mesh=mesh)
        except TypeError as e:
            assert "RotationSequence" in str(e), e
        else:
            raise AssertionError("raw-array seq must raise TypeError")
        # mesh accepted as a keyword; forgetting it is a clear TypeError
        o = rot_sequence_row_sharded(jnp.array(A), seq, mesh=mesh,
                                     n_b=4, k_b=2)
        assert np.abs(np.asarray(o, np.float64) - ref).max() < 1e-4
        try:
            rot_sequence_row_sharded(jnp.array(A), seq)
        except TypeError as e:
            assert "mesh" in str(e), e
        else:
            raise AssertionError("missing mesh must raise TypeError")
        print("DIST OK")
    """)
    assert "DIST OK" in out


def test_core_distributed_compat_wrapper():
    """repro.core.distributed delegates to repro.dist with a
    DeprecationWarning and identical results."""
    out = _run("""
        import warnings
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.rotations import random_sequence
        from repro import dist
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(7)
        A = jnp.asarray(rng.standard_normal((16, 32)), jnp.float32)
        seq = random_sequence(jax.random.key(3), 32, 5)
        ref = dist.rot_sequence_row_sharded(A, seq, mesh, n_b=8, k_b=2)
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            from repro.core.distributed import rot_sequence_row_sharded
            o = rot_sequence_row_sharded(A, seq, mesh, n_b=8, k_b=2)
        assert any(issubclass(x.category, DeprecationWarning) for x in w), \\
            [x.category for x in w]
        assert any("repro.dist" in str(x.message) for x in w)
        assert jnp.array_equal(o, ref)
        print("COMPAT OK")
    """)
    assert "COMPAT OK" in out


def test_sharded_fused_parity_and_obs():
    """Acceptance bar: a batch bucket row-sharded over the forced
    8-device mesh executes one planned launch per shard and is
    bit-identical to the replicated ``apply_batched`` — for plain,
    signed, and reflector sequences."""
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro import dist, obs
        from repro.core.rotations import random_sequence
        from repro.core.sequence import RotationSequence
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        b, m, n, k = 8, 64, 32, 6
        A = jnp.asarray(rng.standard_normal((b, m, n)), jnp.float32)
        base = random_sequence(jax.random.key(1), n, k)
        G = jnp.asarray(np.where(rng.standard_normal((n - 1, k)) > 0,
                                 1.0, -1.0), jnp.float32)
        variants = {
            "plain": base,
            "signed": RotationSequence(base.cos, base.sin, G),
            "reflector": RotationSequence(base.cos, base.sin, None, True),
        }
        for name, seq in variants.items():
            plan = dist.plan_sharded(seq, like=A, mesh=mesh,
                                     method="blocked")
            rep = seq.plan(like=A, method="blocked",
                           shared_sequence=True).apply_batched(A)
            out = plan.apply_batched(A)
            assert jnp.array_equal(out, rep), name
        # obs attribution: exactly one planned launch per shard, a
        # modeled comm-bytes counter, and the mesh size as a gauge
        obs.set_enabled(True)
        obs.reset()
        plan = dist.plan_sharded(variants["plain"], like=A, mesh=mesh,
                                 method="blocked")
        plan.apply_batched(A)
        snap = obs.snapshot()
        obs.set_enabled(False)
        assert snap["gauges"]["dist.launches_per_shard"] == 1.0, snap
        assert snap["gauges"]["dist.devices"] == 8.0
        assert snap["counters"]["dist.comm_bytes"] > 0
        assert snap["counters"]["dist.applies"] == 1
        rows = [r for r in snap["roofline"]["dispatches"]
                if r.get("comm_bytes")]
        assert rows and rows[0]["launches_per_shard"] == 1, rows
        print("PARITY OK")
    """)
    assert "PARITY OK" in out


def test_sharded_plan_grad_and_roundtrip():
    """custom_vjp parity through ``ShardedSequencePlan.apply`` and the
    to_dict/from_dict round-trip (mesh re-supplied at load)."""
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro import dist
        from repro.dist import ShardedSequencePlan
        from repro.core.rotations import random_sequence
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(2)
        A = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
        seq = random_sequence(jax.random.key(4), 32, 6)
        plan = dist.plan_sharded(seq, like=A, mesh=mesh, method="blocked")
        rp = seq.plan(like=A, method="blocked")
        g_sh = jax.grad(lambda x: (plan.apply(x) ** 2).sum())(A)
        g_rep = jax.grad(lambda x: (rp.apply(x) ** 2).sum())(A)
        assert jnp.allclose(g_sh, g_rep, rtol=1e-5, atol=1e-5)
        # serialization round-trip: the mesh cannot ride in JSON, so it
        # is re-supplied; the restored plan applies identically
        d = plan.to_dict()
        import json
        d = json.loads(json.dumps(d))
        plan2 = ShardedSequencePlan.from_dict(d, seq, mesh)
        assert plan2.devices == plan.devices
        assert plan2.execute_sharded == plan.execute_sharded
        assert jnp.array_equal(plan2.apply(A), plan.apply(A))
        print("GRAD OK")
    """)
    assert "GRAD OK" in out


def test_auto_crossover_small_and_large():
    """``method="auto"`` with ``mesh=`` picks replicated for small n and
    sharded for large n, consistently with ``modeled_crossover`` (the
    comm-extended ``cost_components`` arbitration)."""
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro import dist
        from repro.core.rotations import random_sequence
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(3)
        for (m, n, k), expect_sharded in [((64, 32, 8), False),
                                          ((2048, 512, 64), True)]:
            A = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
            seq = random_sequence(jax.random.key(n), n, k)
            plan = dist.plan_sharded(seq, like=A, mesh=mesh, method="auto")
            sh_s, rep_s = dist.modeled_crossover(m, n, k, devices=8)
            assert (sh_s < rep_s) == expect_sharded, (n, sh_s, rep_s)
            assert plan.execute_sharded == expect_sharded, \\
                (n, plan.execute_sharded, sh_s, rep_s)
        print("AUTO OK")
    """)
    assert "AUTO OK" in out


def test_comm_term_monotone_in_devices():
    """The §6 communication term: zero when unsharded or D=1, and
    monotonically increasing bytes/seconds in the mesh size."""
    from repro.core.registry import Problem, cost_components

    zero = cost_components("blocked", Problem(256, 64, 16))["comm"]
    assert zero == {"bytes": 0.0, "hops": 0.0, "seconds": 0.0}
    one = cost_components("blocked",
                          Problem(256, 64, 16, sharded=True,
                                  devices=1))["comm"]
    assert one["bytes"] == 0.0 and one["seconds"] == 0.0

    prev_bytes, prev_secs = 0.0, 0.0
    for D in (2, 4, 8, 16):
        comm = cost_components(
            "blocked", Problem(256, 64, 16, sharded=True,
                               devices=D))["comm"]
        assert comm["bytes"] > prev_bytes, (D, comm)
        assert comm["seconds"] > prev_secs, (D, comm)
        assert comm["hops"] == math.ceil(math.log2(D))
        prev_bytes, prev_secs = comm["bytes"], comm["seconds"]


def test_sharded_plan_cache_key_isolation():
    """Sharded plan keys carry ``("sharded", devices)`` in the legacy
    slot, so plans never transfer between device counts or to
    single-device keys (distinct ``_split_key`` classes)."""
    from repro.core.registry import Problem, _plan_key, _split_key

    k1 = _plan_key(Problem(64, 32, 8))
    k8 = _plan_key(Problem(64, 32, 8, sharded=True, devices=8))
    k4 = _plan_key(Problem(64, 32, 8, sharded=True, devices=4))
    assert k1[6] is False
    assert k8[6] == ("sharded", 8)
    assert k4[6] == ("sharded", 4)

    (_, cls1, _), (_, cls8, _), (_, cls4, _) = map(
        _split_key, (k1, k8, k4))
    assert len({cls1, cls8, cls4}) == 3, (cls1, cls8, cls4)
    # round-trip through the key: same problem -> identical key/class
    assert _plan_key(Problem(64, 32, 8, sharded=True, devices=8)) == k8
    # batch/per-request markers survive alongside the sharded slot
    kb = _plan_key(Problem(64, 32, 8, sharded=True, devices=8, batch=16,
                           shared_sequence=False))
    assert kb[6] == ("sharded", 8) and kb[7] == 16 and kb[8] == "per_req"


def test_column_sharded_comm_bytes_live_window():
    """Per-wave liveness accounting: identity-padded bands are
    exchange-free, so a padded sequence prices fewer live bands than
    the dense grid (the dense default stays backward compatible)."""
    import jax
    from repro.core.rotations import random_sequence
    from repro.dist import column_sharded_comm_bytes

    m_loc, n, k, D, n_b, k_b = 64, 32, 16, 4, 8, 4
    dense = column_sharded_comm_bytes(m_loc, n, k, D, n_b, k_b)
    assert dense["bands"] == 4 and dense["live_bands"] == 4
    # a sequence with only the first 2 of 16 waves live: pad_to tail
    live = random_sequence(jax.random.key(0), n, 2).pad_to(k)
    win = column_sharded_comm_bytes(m_loc, n, k, D, n_b, k_b,
                                    sequence=live)
    assert win["bands"] == 4 and win["live_bands"] == 1, win
    assert win["pipelined"] < dense["pipelined"]
    assert win["allgather"] < dense["allgather"]
    # the static k_live bound gives the same window without the arrays
    bound = column_sharded_comm_bytes(m_loc, n, k, D, n_b, k_b,
                                      live_planes=2 * (n - 1))
    assert bound["live_bands"] == win["live_bands"]
    # shape mismatch is a clear error, not silent dense pricing
    with pytest.raises(ValueError):
        column_sharded_comm_bytes(m_loc, n, k + 1, D, n_b, k_b,
                                  sequence=live)


def test_mini_dryrun_multipod_mesh():
    """(2,2,2) pod/data/model mini-mesh: lower+compile a reduced arch with
    the same code path as the production dry-run."""
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.launch.mesh import make_rules_for_mesh
        from repro.launch.specs import (abstract_opt_state, input_specs,
                                        sharding_trees)
        from repro.models import build_model
        from repro.optim import AdamW
        from repro.parallel.sharding import axis_rules
        from repro.train import make_train_step
        from repro.configs.base import ShapeConfig

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = get_config("smollm-135m").reduced()
        shape = ShapeConfig("mini", 64, 8, "train")
        rules = make_rules_for_mesh(mesh)
        model = build_model(cfg)
        opt = AdamW(lr=1e-4)
        with axis_rules(rules, mesh=mesh):
            trees = sharding_trees(model, cfg, shape, opt, rules, mesh)
            step = make_train_step(model, cfg, opt)
            jf = jax.jit(step,
                         in_shardings=(trees["params"], trees["opt"],
                                       trees["batch"]),
                         out_shardings=(trees["params"], trees["opt"],
                                        None))
            lowered = jf.lower(trees["params_abs"],
                               abstract_opt_state(opt, trees["params_abs"]),
                               input_specs(cfg, shape))
            compiled = lowered.compile()
        assert compiled.memory_analysis().temp_size_in_bytes > 0
        txt = compiled.as_text()
        assert any(c in txt for c in ("all-reduce", "all-gather",
                                      "reduce-scatter")), "no collectives?"
        print("MINI DRYRUN OK")
    """)
    assert "MINI DRYRUN OK" in out


def test_hlo_collectives_accounting():
    """Collective bytes from the loop-aware analyzer: an all-reduce inside
    a scan of length L must count L times."""
    out = _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.launch.hlo_analysis import analyze_hlo
        mesh = jax.make_mesh((8,), ("d",))
        L, M = 5, 64

        def f(x, ws):
            def body(x, w):
                return jnp.tanh(x @ w), None
            x, _ = jax.lax.scan(body, x, ws)
            return x

        sh_x = NamedSharding(mesh, P(None, "d"))
        sh_w = NamedSharding(mesh, P(None, "d", None))
        jf = jax.jit(f, in_shardings=(sh_x, sh_w),
                     out_shardings=sh_x)
        comp = jf.lower(jax.ShapeDtypeStruct((M, M), jnp.float32),
                        jax.ShapeDtypeStruct((L, M, M), jnp.float32)
                        ).compile()
        hc = analyze_hlo(comp.as_text())
        total_coll = sum(hc.collective_bytes.values())
        n_coll = sum(hc.collective_counts.values())
        assert n_coll >= L, (n_coll, hc.collective_counts)
        assert hc.flops >= L * 2 * M * M * (M // 8) * 0.9
        print("HLO COLL OK", hc.collective_counts)
    """)
    assert "HLO COLL OK" in out
