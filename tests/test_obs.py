"""repro.obs: metrics primitives, exact dispatch counters, tracing,
roofline attribution, and the disabled-path bit-identity contract.

The exact-count tests pin the plan-cache counter semantics across the
cold -> warm -> interpolated -> autotune-upgrade lifecycle; the
determinism tests pin the acceptance contract that (a) two identical
runs produce bit-identical snapshots once timing-derived fields are
zeroed, and (b) disabling obs changes neither outputs nor plan-cache
contents.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core.registry import (clear_plan_cache, plan_cache_stats,
                                 select_plan)
from repro.core.rotations import random_sequence
from repro.serve import RotationService
from repro.serve.rotations import synthetic_stream


@pytest.fixture(autouse=True)
def _clean_obs():
    obs.reset()
    clear_plan_cache()
    yield
    obs.reset()
    clear_plan_cache()


# ------------------------------------------------- metrics primitives ----

def test_histogram_buckets_are_a_pure_function_of_the_value():
    from repro.obs import metrics as m
    # log-spaced, 10 buckets per decade, anchored at 1e-7
    assert m.bucket_index(1e-7) == 0
    assert m.bucket_index(1e-6) == 10
    assert m.bucket_index(1e-1) == 60
    # clamped at both ends: zero/negative and absurdly large values
    assert m.bucket_index(0.0) == 0
    assert m.bucket_index(-1.0) == 0
    assert m.bucket_index(1e9) == m.bucket_index(1e12)
    lo, hi = m.bucket_bounds(m.bucket_index(1e-4))
    assert lo <= 1e-4 < hi


def test_histogram_percentiles_are_geometric_bucket_midpoints():
    with obs.override(True):
        for v in (1e-4,) * 9 + (1e-1,):
            obs.observe("lat", v)
    h = obs.snapshot()["histograms"]["lat"]
    assert h["count"] == 10
    assert h["unit"] == "seconds"
    assert h["min"] == 1e-4 and h["max"] == 1e-1
    assert h["p50"] == pytest.approx(1e-4, rel=0.2)
    assert h["p99"] == pytest.approx(1e-1, rel=0.3)


def test_zeroed_timings_zeroes_seconds_histograms_only():
    with obs.override(True):
        obs.observe("t", 0.123)                   # timing-derived
        obs.observe("waves", 7.0, unit="waves")   # deterministic count
        obs.inc("c", 3)
    z = obs.zeroed_timings(obs.snapshot())
    assert z["histograms"]["t"]["count"] == 1     # structure survives
    assert z["histograms"]["t"]["sum"] == 0.0
    assert z["histograms"]["t"]["p99"] == 0.0
    assert z["histograms"]["waves"]["sum"] == 7.0
    assert z["counters"]["c"] == 3


def test_disabled_hooks_record_nothing():
    with obs.override(False):
        obs.inc("c")
        obs.gauge("g", 1.0)
        obs.observe("h", 0.5)
    snap = obs.snapshot()
    assert snap["counters"] == {}
    assert snap["gauges"] == {}
    assert snap["histograms"] == {}


# ------------------------------------------------------------ tracing ----

def test_span_is_null_without_a_trace_path():
    with obs.override(True):
        with obs.span("apply", m=4) as sp:
            sp.set(method="blocked")
    assert obs.trace.events() == []


def test_trace_exports_perfetto_loadable_chrome_events(tmp_path):
    path = str(tmp_path / "trace.jsonl")
    with obs.override(True):
        prev = obs.runtime.set_trace_path(path)
        try:
            with obs.span("apply", m=4) as sp:
                sp.set(method="blocked")
            n = obs.write_trace()
        finally:
            obs.runtime.set_trace_path(prev)
    assert n == 1
    payload = json.loads(open(path).read())
    (ev,) = payload["traceEvents"]
    assert ev["ph"] == "X" and ev["name"] == "apply"
    assert ev["args"] == {"m": 4, "method": "blocked"}
    assert ev["dur"] >= 0 and ev["ts"] >= 0


# ------------------------------------- plan-cache counters, exactly ----

def test_plan_cache_counters_cold_warm_interpolated_upgrade():
    with obs.override(True):
        # cold: one miss, zero hits (autotuned so the entry can donate)
        donor = select_plan(16, 48, 6, platform="cpu", autotune=True,
                            autotune_top=1)
        assert donor.source == "measured"
        c = obs.snapshot()["counters"]
        assert c.get("registry.plan_cache.hits", 0) == 0
        assert c["registry.plan_cache.misses"] == 1

        # warm: exact repeat is a pure hit
        assert select_plan(16, 48, 6, platform="cpu") == donor
        c = obs.snapshot()["counters"]
        assert c["registry.plan_cache.hits"] == 1
        assert c["registry.plan_cache.misses"] == 1

        # nearby unmeasured shape: counted as miss + interpolated borrow
        borrowed = select_plan(20, 64, 8, platform="cpu")
        assert borrowed.source == "interpolated"
        c = obs.snapshot()["counters"]
        assert c["registry.plan_cache.misses"] == 2
        assert c["registry.plan_cache.interpolated"] == 1

        # the borrowed entry is itself warm on repeat
        assert select_plan(20, 64, 8, platform="cpu") == borrowed
        c = obs.snapshot()["counters"]
        assert c["registry.plan_cache.hits"] == 2

        # autotune over a borrowed entry: miss + upgrade, never a hit
        upgraded = select_plan(20, 64, 8, platform="cpu", autotune=True,
                               autotune_top=1)
        assert upgraded.source == "measured"
        c = obs.snapshot()["counters"]
        assert c["registry.plan_cache.hits"] == 2
        assert c["registry.plan_cache.misses"] == 3
        assert c["registry.plan_cache.autotune_upgrade"] == 1
        assert c["registry.plan_cache.interpolated"] == 1


# ------------------------------------------------- dispatch + roofline ----

def test_sequence_dispatch_records_roofline_and_counters():
    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.standard_normal((12, 24)), jnp.float32)
    Ab = jnp.asarray(rng.standard_normal((3, 12, 24)), jnp.float32)
    seq = random_sequence(jax.random.key(1), 24, 6)
    plan = seq.plan(like=A)
    with obs.override(True):
        jax.block_until_ready(plan.apply(A))
        jax.block_until_ready(plan.apply_batched(Ab))
        snap = obs.snapshot()
    assert snap["counters"]["sequence.applies"] == 2
    assert snap["histograms"]["sequence.apply_seconds"]["count"] == 2
    roof = snap["roofline"]
    assert len(roof["dispatches"]) == 2
    for agg in roof["by_backend"].values():
        assert agg["predicted_flops"] > 0
        assert agg["predicted_bytes"] > 0
        assert agg["measured_s"] > 0
        assert agg["model_fraction"] > 0


def test_disabled_obs_outputs_bit_identical_and_no_new_cache_keys():
    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.standard_normal((12, 24)), jnp.float32)
    Ab = jnp.asarray(rng.standard_normal((3, 12, 24)), jnp.float32)
    seq = random_sequence(jax.random.key(1), 24, 6)
    plan = seq.plan(like=A)
    with obs.override(False):
        off_single = plan.apply(A)
        off_batched = plan.apply_batched(Ab)
    size0 = plan_cache_stats()["size"]
    with obs.override(True):
        on_single = plan.apply(A)
        on_batched = plan.apply_batched(Ab)
    # instrumentation must not add plan-cache keys ...
    assert plan_cache_stats()["size"] == size0
    # ... nor change a single bit of the outputs
    np.testing.assert_array_equal(np.asarray(off_single),
                                  np.asarray(on_single))
    np.testing.assert_array_equal(np.asarray(off_batched),
                                  np.asarray(on_batched))


def test_instrumented_apply_stays_differentiable():
    # the tracer guard: jax.grad drives apply with abstract values, and
    # the host-side instrumentation must stand aside rather than crash
    rng = np.random.default_rng(2)
    A = jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
    seq = random_sequence(jax.random.key(0), 16, 4)
    plan = seq.plan(like=A, method="blocked")
    with obs.override(True):
        g = jax.grad(lambda a: (plan.apply(a) ** 2).sum())(A)
        snap = obs.snapshot()
    assert g.shape == A.shape
    # the traced inner call records nothing (no concrete wall time)
    assert snap["roofline"]["dispatches"] == []


# --------------------------------------------------- serving + kernels ----

def test_service_metrics_account_pad_slots_and_latency():
    requests = synthetic_stream(8, seed=3)
    with obs.override(True):
        svc = RotationService(slots=4, store=False)
        outs = svc.apply_many(requests)
        jax.block_until_ready(outs[-1])
        snap = obs.snapshot()
    c = snap["counters"]
    assert c["serve.requests"] == 8
    # pad-slot accounting: executed slots split into real vs identity
    assert c["serve.slots_executed"] == svc.stats["slots_executed"]
    assert c.get("serve.pad_slots", 0) == svc.stats["padded_slots"]
    pad_fraction = snap["gauges"]["serve.pad_slot_fraction"]
    assert 0.0 <= pad_fraction < 1.0
    lat = snap["histograms"]["serve.request_latency_seconds"]
    assert lat["count"] == 8
    assert lat["p99"] >= lat["p50"] > 0


def test_service_snapshot_bit_identical_across_runs():
    def run() -> str:
        clear_plan_cache()
        obs.reset()
        svc = RotationService(slots=4, store=False)
        outs = svc.apply_many(synthetic_stream(8, seed=3))
        jax.block_until_ready(outs[-1])
        return json.dumps(obs.zeroed_timings(obs.snapshot()),
                          sort_keys=True)
    with obs.override(True):
        first = run()
        second = run()
    assert first == second


def test_fused_kernel_accounting_counts_skipped_planes():
    from repro.kernels.rotseq_batched.ops import count_live_planes
    rng = np.random.default_rng(0)
    b, m, n, k_req, k_pad = 4, 8, 16, 3, 8
    A = jnp.asarray(rng.standard_normal((b, m, n)), jnp.float32)
    seqs = [random_sequence(jax.random.key(i), n, k_req).pad_to(k_pad)
            for i in range(b)]
    plan = seqs[0].plan(like=A, method="rotseq_batched")
    with obs.override(True):
        jax.block_until_ready(plan.apply_batched(A, sequences=seqs))
        c = obs.snapshot()["counters"]
    live = sum(count_live_planes(s) for s in seqs)
    assert c["kernels.rotseq_batched.launches"] == 1
    assert c["kernels.rotseq_batched.planes_applied"] == live
    assert c["kernels.rotseq_batched.planes_skipped"] == \
        (n - 1) * k_pad * b - live
    assert c["kernels.rotseq_batched.bytes_moved"] > 0


def test_eig_flush_waves_histogram():
    from repro.eig import eigh_givens
    rng = np.random.default_rng(0)
    X = rng.standard_normal((12, 12)).astype(np.float32)
    H = jnp.asarray(X + X.T) / 2
    with obs.override(True):
        w, V = eigh_givens(H, method="qr", k_delay=4)
        jax.block_until_ready(V)
        snap = obs.snapshot()
    flushes = snap["counters"]["eig.flushes"]
    h = snap["histograms"]["eig.waves_per_flush"]
    assert flushes >= 1
    assert h["unit"] == "waves"
    assert h["count"] == flushes
    assert h["max"] <= 4  # the delay bound caps every flush


# --------------------------------------------------------- artifacts ----

def test_write_metrics_json_roundtrip(tmp_path):
    path = str(tmp_path / "OBS_metrics.json")
    with obs.override(True):
        obs.inc("x", 2)
        snap = obs.write_metrics_json(path, extra={"mode": "test"})
    on_disk = json.loads(open(path).read())
    assert on_disk == json.loads(json.dumps(snap))
    assert on_disk["counters"]["x"] == 2
    assert on_disk["meta"] == {"mode": "test"}
    assert "roofline" in on_disk


# ------------------------------------------------------ thread safety ----

def test_metrics_are_thread_safe_under_contention():
    """The stream engine mutates counters/histograms from three threads
    (caller, scheduler, dispatcher).  N threads hammering the same
    metrics must lose zero increments and keep histogram count/sum
    consistent — ``value += d`` without the registry lock drops both."""
    import threading

    n_threads, per_thread = 8, 2000
    with obs.override(True):
        def work():
            for i in range(per_thread):
                obs.inc("ts.counter")
                obs.observe("ts.hist", 1e-3)
                obs.gauge("ts.gauge", i)

        threads = [threading.Thread(target=work) for _ in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        snap = obs.snapshot()
    total = n_threads * per_thread
    assert snap["counters"]["ts.counter"] == total
    h = snap["histograms"]["ts.hist"]
    assert h["count"] == total
    assert h["sum"] == pytest.approx(total * 1e-3)
    assert sum(h["buckets"].values()) == total
    assert snap["gauges"]["ts.gauge"] == per_thread - 1
