"""Batched plan-once/apply-many serving: SequencePlan.apply_batched,
plan serialization, the shape-bucketed RotationService, the batch-aware
cost model, and the persisted-plan merge path."""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import registry
from repro.core.registry import clear_plan_cache, plan_cache_stats, select_plan
from repro.core.rotations import random_sequence
from repro.core.sequence import RotationSequence, SequencePlan
from repro.serve import RotationService, serve_plan_store_path


def _stream(n_requests=24, seed=0, shapes=None):
    """Mixed-shape request stream covering >= 3 buckets."""
    from repro.serve.rotations import DEMO_SHAPES, synthetic_stream

    return synthetic_stream(n_requests, seed=seed,
                            shapes=shapes or DEMO_SHAPES)


# ------------------------------------------------ apply_batched (core) ----

@pytest.mark.parametrize("method,kw", [
    ("unoptimized", {}), ("wavefront", {}),
    ("blocked", dict(n_b=8, k_b=4)), ("accumulated", dict(n_b=8, k_b=4)),
])
def test_apply_batched_shared_sequence_bitwise(method, kw):
    """One sequence, batched targets: flatten/vmap must equal b separate
    applies bit-for-bit (rotations act row-wise)."""
    rng = np.random.default_rng(1)
    b, m, n, k = 4, 8, 12, 6
    A = jnp.asarray(rng.standard_normal((b, m, n)), jnp.float32)
    seq = random_sequence(jax.random.key(0), n, k)
    plan = seq.plan(like=A, method=method, **kw)
    out = plan.apply_batched(A)
    ref = jnp.stack([plan.apply(A[i]) for i in range(b)])
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_apply_batched_per_request_sequences_bitwise():
    """Each batch element with its own waves == per-request application."""
    rng = np.random.default_rng(2)
    b, m, n, k = 6, 8, 12, 6
    A = jnp.asarray(rng.standard_normal((b, m, n)), jnp.float32)
    seqs = [random_sequence(jax.random.key(i), n, k) for i in range(b)]
    plan = seqs[0].plan(like=A, method="blocked", n_b=8, k_b=4)
    out = plan.apply_batched(A, sequences=seqs)
    ref = jnp.stack([
        s.plan(like=A[i], method="blocked", n_b=8, k_b=4).apply(A[i])
        for i, s in enumerate(seqs)])
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_apply_batched_grad_through_flatten():
    rng = np.random.default_rng(3)
    b, m, n, k = 3, 5, 9, 4
    A = jnp.asarray(rng.standard_normal((b, m, n)), jnp.float32)
    seq = random_sequence(jax.random.key(0), n, k)
    plan = seq.plan(like=A, method="blocked", n_b=8, k_b=4)
    g = jax.grad(lambda x: (plan.apply_batched(x) ** 2).sum())(A)
    eps = 1e-3
    d = jnp.zeros_like(A).at[1, 2, 3].set(eps)
    f = lambda x: float((plan.apply_batched(x) ** 2).sum())
    fd = (f(A + d) - f(A - d)) / (2 * eps)
    assert abs(fd - float(g[1, 2, 3])) < 5e-2 * max(1.0, abs(fd))


def test_apply_batched_validation():
    seq = random_sequence(jax.random.key(0), 8, 4)
    A3 = jnp.zeros((2, 5, 8))
    plan = seq.plan(like=A3)
    with pytest.raises(ValueError, match=r"\(b, m, n\)"):
        plan.apply_batched(jnp.zeros((5, 8)))
    with pytest.raises(ValueError, match="sequences for a batch"):
        plan.apply_batched(A3, sequences=[seq])
    with pytest.raises(ValueError, match="pad_to"):
        plan.apply_batched(
            A3, sequences=[seq, random_sequence(jax.random.key(1), 8, 6)])
    with pytest.raises(ValueError, match="sign/reflect"):
        plan.apply_batched(A3, sequences=[seq, seq.with_signs()])


# ------------------------------------------------ batch-aware planning ----

def test_cost_model_is_batch_aware():
    """Shared-sequence batches amortize the accumulated path's Q_t setup,
    so auto can pick a different backend at batch 64 than at batch 1."""
    clear_plan_cache()
    p1 = select_plan(4, 256, 256, platform="cpu")
    p64 = select_plan(4, 256, 256, platform="cpu", batch=64)
    assert p1.method in ("blocked", "wavefront", "unoptimized")
    assert p64.method == "accumulated"
    # distinct cache keys: batch-64 entry must not shadow batch-1
    before = plan_cache_stats()
    assert select_plan(4, 256, 256, platform="cpu") == p1
    assert select_plan(4, 256, 256, platform="cpu", batch=64) == p64
    after = plan_cache_stats()
    assert after["hits"] == before["hits"] + 2
    assert after["misses"] == before["misses"]
    clear_plan_cache()


def test_plan_accepts_batched_like():
    seq = random_sequence(jax.random.key(0), 16, 4)
    A = jnp.zeros((8, 5, 16))
    plan = seq.plan(like=A)  # 3D like: batch and m inferred
    out = plan.apply_batched(A)
    assert out.shape == A.shape


# --------------------------------------------------- plan serialization ----

def test_sequence_plan_dict_roundtrip_bitwise():
    rng = np.random.default_rng(4)
    m, n, k = 12, 16, 8
    A = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    seq = random_sequence(jax.random.key(0), n, k)
    plan = seq.plan(like=A)
    d = json.loads(json.dumps(plan.to_dict()))  # through real JSON
    plan2 = SequencePlan.from_dict(d, seq)
    assert plan2.method == plan.method
    assert dict(plan2.kwargs) == dict(plan.kwargs)
    np.testing.assert_array_equal(np.asarray(plan2.apply(A)),
                                  np.asarray(plan.apply(A)))


def test_sequence_plan_from_dict_rejects_stale_and_mismatched():
    seq = random_sequence(jax.random.key(0), 16, 8)
    plan = seq.plan(m=8)
    d = plan.to_dict()
    stale = dict(d, jax="0.0.1")
    with pytest.raises(ValueError, match="JAX"):
        SequencePlan.from_dict(stale, seq)
    with pytest.raises(ValueError, match="wave shape"):
        SequencePlan.from_dict(d, seq.pad_to(12))
    with pytest.raises(ValueError, match="sign/reflect"):
        SequencePlan.from_dict(d, seq.with_signs())
    with pytest.raises(ValueError, match="format"):
        SequencePlan.from_dict(dict(d, format=99), seq)
    with pytest.raises(ValueError, match="unknown method"):
        SequencePlan.from_dict(dict(d, method="gone"), seq)


def test_rotation_sequence_dict_roundtrip():
    seq = random_sequence(jax.random.key(5), 10, 3).with_signs()
    d = json.loads(json.dumps(seq.to_dict()))
    back = RotationSequence.from_dict(d)
    np.testing.assert_array_equal(np.asarray(back.cos), np.asarray(seq.cos))
    np.testing.assert_array_equal(np.asarray(back.sin), np.asarray(seq.sin))
    np.testing.assert_array_equal(np.asarray(back.sign),
                                  np.asarray(seq.sign))


# -------------------------------------------------------- the service ----

def test_service_bitwise_and_one_plan_per_bucket():
    """Acceptance: mixed-shape stream (3 buckets, batch 8) bit-identical
    to per-request seq.plan(like=A).apply(A), exactly one registry
    resolution per bucket."""
    clear_plan_cache()
    requests = _stream(24)
    refs = [seq.plan(like=A).apply(A) for seq, A in requests]

    misses0 = plan_cache_stats()["misses"]
    svc = RotationService(slots=8, store=False)
    outs = svc.apply_many(requests)
    assert plan_cache_stats()["misses"] - misses0 == 3  # one per bucket
    assert svc.stats["plans_resolved"] == 3
    assert svc.stats["batches"] == 3
    for out, ref in zip(outs, refs):
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    # steady state: later passes rebind the frozen plans, zero new
    # registry work
    misses1 = plan_cache_stats()["misses"]
    outs2 = svc.apply_many(requests)
    assert plan_cache_stats()["misses"] == misses1
    assert svc.stats["plans_resolved"] == 3
    for out, ref in zip(outs2, refs):
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    clear_plan_cache()


def test_service_partial_batch_pads_slots():
    clear_plan_cache()
    requests = _stream(5, shapes=((16, 32, 8),))  # one bucket, 5 < slots
    refs = [seq.plan(like=A).apply(A) for seq, A in requests]
    svc = RotationService(slots=8, store=False)
    outs = svc.apply_many(requests)
    assert svc.stats["padded_slots"] == 3
    for out, ref in zip(outs, refs):
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    clear_plan_cache()


def test_service_signed_and_reflect_requests():
    """Sign-carrying and reflector sequences bucket separately from plain
    rotations; every request — including all-reflector ones — stays
    **bit-identical** to per-request application: the bit-stable
    reflector normalization makes the bucket's sign-grid execution equal
    the scalar ``reflect=True`` path a lone request takes, to the last
    bit."""
    clear_plan_cache()
    rng = np.random.default_rng(7)
    m, n, k = 16, 24, 8
    requests = []
    for i in range(9):
        A = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
        seq = random_sequence(jax.random.key(i), n, k)
        if i % 3 == 1:
            sign = jnp.where(
                jax.random.bernoulli(jax.random.key(100 + i), 0.5,
                                     seq.cos.shape), 1.0, -1.0)
            seq = RotationSequence(seq.cos, seq.sin, sign)
        elif i % 3 == 2:
            seq = RotationSequence(seq.cos, seq.sin, None, True)
        requests.append((seq, A))
    refs = [seq.plan(like=A).apply(A) for seq, A in requests]
    svc = RotationService(slots=4, store=False)
    outs = svc.apply_many(requests)
    # plain bucket + signed bucket (sign-carrying and reflect share the
    # signed bucket; their structures stay implicit until stacking)
    assert svc.stats["plans_resolved"] == 2
    for out, ref in zip(outs, refs):
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    clear_plan_cache()


def test_service_admission_keeps_signs_implicit():
    """Regression (pad_to/admission memory): padding a plain or
    reflector sequence into a bucket must not materialize dense sign
    grids per queued request — plain stays ``sign=None`` after
    ``pad_to``, reflector requests only materialize at genuine-reflector
    padding, and identity slot-pads stay implicit."""
    clear_plan_cache()
    svc = RotationService(slots=8, store=False)
    rng = np.random.default_rng(9)
    A = jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
    plain = random_sequence(jax.random.key(0), 16, 5)
    svc.submit(plain, A)
    refl = RotationSequence(plain.cos, plain.sin, None, True)
    svc.submit(refl, A)
    queued = [p.seq for q in svc._queues.values() for p in q]
    plain_q = [s for s in queued if not s.reflect and s.sign is None]
    assert plain_q, "plain request must stay implicit (sign=None)"
    # pad_to on a plain sequence keeps the sign implicit and records
    # the live-plane bound the planner skips padding with
    padded = plain.pad_to(8)
    assert padded.sign is None and padded.k_live == 15 * 5
    # the on-demand sign grid is correct when a consumer does need it
    # (the sequence itself stays implicit)
    bcast = padded._sign_array()
    assert bcast.shape == padded.cos.shape
    assert bool((np.asarray(bcast) == -1.0).all())
    assert padded.sign is None
    # genuine reflector padding still materializes (padded reflectors
    # are not no-ops)
    assert refl.pad_to(8).sign is not None
    clear_plan_cache()


def test_service_fused_bucket_execution_bitwise():
    """Bucket drains through the fused one-launch backend must equal
    per-request auto dispatch bit-for-bit (rotation + signed families,
    partial buckets included)."""
    clear_plan_cache()
    requests = _stream(10)  # 3 buckets, partial drains
    refs = [seq.plan(like=A).apply(A) for seq, A in requests]
    svc = RotationService(slots=4, store=False, method="rotseq_batched")
    outs = svc.apply_many(requests)
    assert svc.stats["padded_slots"] > 0  # partial buckets exercised
    for out, ref in zip(outs, refs):
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    clear_plan_cache()


def test_service_wave_padding_buckets_by_pow2():
    clear_plan_cache()
    svc = RotationService(slots=8, store=False)
    rng = np.random.default_rng(8)
    A = jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
    t1 = svc.submit(random_sequence(jax.random.key(0), 16, 5), A)
    t2 = svc.submit(random_sequence(jax.random.key(1), 16, 7), A)
    svc.drain()
    # k=5 and k=7 share the k_pad=8 bucket
    assert svc.stats["plans_resolved"] == 1
    assert svc.stats["padded_waves"] == (8 - 5) + (8 - 7)
    svc.result(t1), svc.result(t2)
    with pytest.raises(KeyError):
        svc.result(t1)  # results are collected exactly once
    clear_plan_cache()


def test_service_warm_restart_zero_resolutions(tmp_path):
    """Acceptance: a warm restart from serialized plans performs zero new
    registry plan resolutions and reproduces results exactly."""
    clear_plan_cache()
    store = str(tmp_path / "serve_plans.json")
    requests = _stream(24)
    svc = RotationService(slots=8, store=store)
    outs = svc.apply_many(requests)
    assert svc.stats["plans_resolved"] == 3
    assert os.path.exists(store)

    # "new process": plan cache cold, service warm from the store.
    # Resolution counts are asserted through the obs metrics — the same
    # counters the OBS_metrics.json artifact exports — not by poking
    # service internals.
    from repro import obs

    clear_plan_cache()
    misses0 = plan_cache_stats()["misses"]
    warm = RotationService(slots=8, store=store)
    with obs.override(True):
        obs.reset()
        outs2 = warm.apply_many(requests)
        counters = obs.snapshot()["counters"]
    assert counters.get("serve.plans_resolved", 0) == 0
    assert counters.get("serve.warm_plans", 0) == 3
    assert counters.get("registry.plan_cache.misses", 0) == 0
    assert warm.stats["plans_resolved"] == 0
    assert warm.stats["warm_plans"] == 3
    assert plan_cache_stats()["misses"] == misses0
    obs.reset()
    for a, b in zip(outs, outs2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    clear_plan_cache()


def test_service_warm_store_rejects_stale_jax(tmp_path):
    clear_plan_cache()
    store = str(tmp_path / "serve_plans.json")
    requests = _stream(8, shapes=((16, 32, 8),))
    RotationService(slots=8, store=store).apply_many(requests)
    payload = json.loads(open(store).read())
    payload["jax"] = "0.0.1"
    open(store, "w").write(json.dumps(payload))
    svc = RotationService(slots=8, store=store)
    svc.apply_many(requests)
    assert svc.stats["warm_plans"] == 0  # stale file ignored wholesale
    assert svc.stats["plans_resolved"] == 1
    clear_plan_cache()


def test_service_warm_store_ignores_corrupt_file(tmp_path):
    store = tmp_path / "serve_plans.json"
    store.write_text("{not json")
    svc = RotationService(slots=4, store=str(store))
    outs = svc.apply_many(_stream(4, shapes=((8, 16, 4),)))
    assert len(outs) == 4


def test_service_functional_with_persistence_off(monkeypatch):
    """REPRO_PLAN_CACHE=off disables the store but not serving."""
    monkeypatch.setenv("REPRO_PLAN_CACHE", "off")
    assert serve_plan_store_path() is None
    clear_plan_cache()
    requests = _stream(12)
    refs = [seq.plan(like=A).apply(A) for seq, A in requests]
    svc = RotationService(slots=4)  # default store resolves to None
    outs = svc.apply_many(requests)
    for out, ref in zip(outs, refs):
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    clear_plan_cache()


def test_service_rejects_bad_requests():
    svc = RotationService(slots=2, store=False)
    seq = random_sequence(jax.random.key(0), 16, 4)
    with pytest.raises(ValueError, match="columns"):
        svc.submit(seq, jnp.zeros((4, 8)))
    with pytest.raises(ValueError, match="2D"):
        svc.submit(seq, jnp.zeros((2, 4, 16)))
    with pytest.raises(ValueError, match="slots"):
        RotationService(slots=0)


# ------------------------------------------- batched delayed buffer ----

def test_delayed_buffer_batched_accumulator_matches_slices():
    from repro.eig.delayed import DelayedRotationBuffer

    rng = np.random.default_rng(9)
    b, m, n = 3, 8, 10
    M = jnp.asarray(rng.standard_normal((b, m, n)), jnp.float32)
    buf = DelayedRotationBuffer(M, k_delay=4)
    slices = [DelayedRotationBuffer(M[i], k_delay=4) for i in range(b)]
    for _ in range(7):  # forces one full flush + one padded flush
        th = rng.standard_normal(n - 1)
        buf.push(np.cos(th), np.sin(th))
        for s in slices:
            s.push(np.cos(th), np.sin(th))
    out = buf.value
    assert buf.flushes == 2
    for i, s in enumerate(slices):
        np.testing.assert_array_equal(np.asarray(out[i]),
                                      np.asarray(s.value))


def test_delayed_buffer_rejects_wrong_rank():
    from repro.eig.delayed import DelayedRotationBuffer

    with pytest.raises(ValueError, match="accumulator"):
        DelayedRotationBuffer(jnp.zeros((2, 3, 4, 5)))


# ------------------------------- persisted plan cache: merge small fix ----

def test_autotune_upgrades_interpolated_and_persists_once(tmp_path,
                                                          monkeypatch):
    """An interpolated entry upgraded by autotune is measured (its tiles
    join the candidate set) and persisted exactly once — no duplicate
    keys on merge, across repeated saves."""
    path = tmp_path / "plans.json"
    monkeypatch.setenv("REPRO_PLAN_CACHE", str(path))
    clear_plan_cache()
    try:
        donor = select_plan(16, 48, 6, platform="cpu", autotune=True,
                            autotune_top=2)
        assert donor.source == "measured"
        borrowed = select_plan(20, 64, 8, platform="cpu")
        assert borrowed.source == "interpolated"
        upgraded = select_plan(20, 64, 8, platform="cpu", autotune=True,
                               autotune_top=1)
        assert upgraded.source == "measured"
        registry.save_plan_cache()
        registry.save_plan_cache()  # idempotent: still one entry per key
        payload = json.loads(path.read_text())
        keys = [tuple(e["key"]) for e in payload["plans"]]
        assert len(keys) == len(set(keys))  # no duplicate keys
        assert (20, 64, 8, "float32", "cpu", False, False) in keys
        # interpolated entries themselves are never persisted
        clear_plan_cache()
        loaded = registry.load_plan_cache()
        assert loaded == 2
        assert all(p.source == "persisted"
                   for p in registry._PLAN_CACHE.values())
    finally:
        clear_plan_cache()


def test_save_merge_concurrent_writers_same_key(tmp_path, monkeypatch):
    """Two writers sharing a key: merge keeps one entry, last writer's
    measurement wins, foreign keys survive."""
    path = tmp_path / "plans.json"
    monkeypatch.setenv("REPRO_PLAN_CACHE", str(path))
    clear_plan_cache()
    try:
        key = (8, 8, 4, "float32", "cpu", False, False)
        other = (16, 16, 8, "float32", "cpu", False, False)
        registry._PLAN_CACHE[key] = registry.Plan(
            method="blocked", n_b=8, k_b=4, est_seconds=1e-6,
            source="measured")
        registry._PLAN_CACHE[other] = registry.Plan(
            method="accumulated", n_b=16, k_b=16, est_seconds=2e-6,
            source="measured")
        registry.save_plan_cache()
        # "writer B": same key, fresh measurement, no knowledge of
        # `other`
        clear_plan_cache()
        registry._PLAN_CACHE[key] = registry.Plan(
            method="blocked", n_b=16, k_b=8, est_seconds=5e-7,
            source="measured")
        registry.save_plan_cache()
        payload = json.loads(path.read_text())
        keys = [tuple(e["key"]) for e in payload["plans"]]
        assert len(keys) == len(set(keys)) == 2  # exactly once per key
        clear_plan_cache()
        assert registry.load_plan_cache() == 2
        assert registry._PLAN_CACHE[key].n_b == 16  # B's write won
        assert registry._PLAN_CACHE[other].method == "accumulated"
    finally:
        clear_plan_cache()


# --------------------------------------------- regression-compare gate ----

def test_compare_baseline_check_semantics():
    import importlib.util
    import pathlib

    spec = importlib.util.spec_from_file_location(
        "compare_baseline",
        pathlib.Path(__file__).parent.parent / "benchmarks"
        / "compare_baseline.py")
    cb = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(cb)

    count = dict(higher_is_better=False, rel_tol=0.0, count=True)
    assert cb._check("c", count, 3, 3)[0]
    assert not cb._check("c", count, 3, 4)[0]
    rate_hi = dict(higher_is_better=True, rel_tol=0.30)
    assert cb._check("r", rate_hi, 100.0, 71.0)[0]
    assert not cb._check("r", rate_hi, 100.0, 69.0)[0]
    assert cb._check("r", rate_hi, 100.0, 250.0)[0]  # improvement
    rate_lo = dict(higher_is_better=False, rel_tol=0.30, abs_floor=500.0)
    assert cb._check("o", rate_lo, 100.0, 129.0)[0]
    assert cb._check("o", rate_lo, 100.0, 400.0)[0]  # under abs floor
    assert not cb._check("o", rate_lo, 100.0, 600.0)[0]


def test_compare_baseline_liveness_floor():
    """Warn-only serving rates absorb noise but hard-fail when the rate
    collapses below the absolute liveness floor (hung-kernel detector),
    and the fused-vs-vmap speedup row gates at the 1.5x acceptance."""
    import importlib.util
    import pathlib

    spec = importlib.util.spec_from_file_location(
        "compare_baseline",
        pathlib.Path(__file__).parent.parent / "benchmarks"
        / "compare_baseline.py")
    cb = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(cb)

    warn = dict(higher_is_better=True, rel_tol=0.30, warn_only=True,
                live_floor=1.0)
    ok, msg = cb._evaluate("w", warn, 100.0, 50.0)   # noisy but alive
    assert ok and "warn-only" in msg
    ok, msg = cb._evaluate("w", warn, 100.0, 0.0)    # collapsed
    assert not ok and "liveness" in msg
    ok, msg = cb._evaluate("w", warn, 100.0, float("nan"))
    assert not ok and "liveness" in msg
    assert cb._evaluate("w", warn, 100.0, 120.0)[0]  # healthy
    # the floor is unconditional: even against a baseline that itself
    # drifted near the floor (relative band satisfied), a collapsed
    # rate fails
    ok, msg = cb._evaluate("w", warn, 1.2, 0.95)
    assert not ok and "liveness" in msg

    # the SPEC rows the satellite is about actually carry the floor
    assert cb.SPEC["serve/bucketed:req_s"]["live_floor"] > 0
    assert cb.SPEC["serve/shared_batch:speedup"]["live_floor"] > 0
    fused = cb.SPEC["serve/fused_vs_vmap:speedup"]
    assert not fused.get("warn_only")          # gating, not warn-only
    assert fused["abs_floor"] == 1.5           # the acceptance bar
    # >=1.5x passes even against a drifted-high baseline; below both
    # the band and the floor fails
    assert cb._evaluate("f", fused, 8.0, 1.6)[0]
    assert not cb._evaluate("f", fused, 8.0, 1.2)[0]
