"""First-class RotationSequence type: pytree/jit/vmap round-trips,
plan-once/apply-many equivalence, composition semantics (transpose,
concatenation, slicing, identity padding), custom_vjp gradients against
finite differences and the linearized reference, and the hoisted
empty-sequence identity across every named backend."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (RotationSequence, SequencePlan,
                        apply_rotation_sequence, random_sequence)
from repro.core.ref import rot_sequence_numpy, rot_sequence_unoptimized

METHODS = ["unoptimized", "wavefront", "blocked", "accumulated",
           "pallas_wave", "pallas_mxu"]


def _kw(method, n_b=8, k_b=4):
    kw = dict(n_b=n_b, k_b=k_b)
    if method.startswith("pallas"):
        kw["m_blk"] = 8
    return kw


def _problem(m, n, k, seed=0):
    rng = np.random.default_rng(seed)
    A = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    seq = random_sequence(jax.random.key(seed + 1), n, k)
    return A, seq


# ------------------------------------------------------------- pytree ----

def test_pytree_roundtrip_preserves_structure():
    _, seq = _problem(4, 9, 3)
    leaves, treedef = jax.tree_util.tree_flatten(seq)
    assert len(leaves) == 2  # cos, sin (sign=None contributes no leaf)
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(back, RotationSequence)
    assert back.reflect == seq.reflect and back.sign is None
    assert (back.cos == seq.cos).all() and (back.sin == seq.sin).all()

    signed = RotationSequence(seq.cos, seq.sin,
                              jnp.full(seq.shape, -1.0), False)
    leaves, treedef = jax.tree_util.tree_flatten(signed)
    assert len(leaves) == 3
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    assert back.sign is not None


def test_sequence_under_jit():
    A, seq = _problem(5, 11, 4)

    @jax.jit
    def f(sq, a):
        return sq.apply(a, method="blocked", n_b=8, k_b=4)

    out = f(seq, A)
    ref = rot_sequence_numpy(A, seq.cos, seq.sin)
    np.testing.assert_allclose(np.asarray(out, np.float64), ref,
                               atol=5e-5, rtol=1e-4)


def test_sequence_under_vmap():
    A, _ = _problem(5, 9, 3)
    seqs = [random_sequence(jax.random.key(i), 9, 3) for i in range(3)]
    batched = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *seqs)
    outs = jax.vmap(lambda sq: sq.apply(A, method="unoptimized"))(batched)
    for i, sq in enumerate(seqs):
        ref = rot_sequence_numpy(A, sq.cos, sq.sin)
        np.testing.assert_allclose(np.asarray(outs[i], np.float64), ref,
                                   atol=5e-5, rtol=1e-4)


# ------------------------------------------------- plan-once/apply-many --

def test_plan_apply_bit_equal_to_dispatch():
    A, seq = _problem(6, 14, 5, seed=3)
    plan = seq.plan(like=A, method="auto")
    assert isinstance(plan, SequencePlan)
    out_plan = plan.apply(A)
    out_wrap = apply_rotation_sequence(A, seq.cos, seq.sin, method="auto")
    np.testing.assert_array_equal(np.asarray(out_plan),
                                  np.asarray(out_wrap))
    # repeated applications reuse the frozen plan with no registry probe
    np.testing.assert_array_equal(np.asarray(plan.apply(A)),
                                  np.asarray(out_plan))


def test_plan_rebind_same_shape():
    A, seq1 = _problem(6, 10, 4, seed=5)
    seq2 = random_sequence(jax.random.key(99), 10, 4)
    plan = seq1.plan(like=A, method="blocked", n_b=8, k_b=4)
    out = plan.rebind(seq2).apply(A)
    ref = rot_sequence_numpy(A, seq2.cos, seq2.sin)
    np.testing.assert_allclose(np.asarray(out, np.float64), ref,
                               atol=5e-5, rtol=1e-4)
    with pytest.raises(ValueError, match="matching wave shape"):
        plan.rebind(random_sequence(jax.random.key(1), 10, 7))


def test_plan_rejects_wrong_width():
    A, seq = _problem(6, 10, 4)
    plan = seq.plan(like=A, method="blocked")
    with pytest.raises(ValueError, match="plan built for"):
        plan.apply(jnp.ones((6, 12)))


def test_named_plan_rejects_signs_on_unblocked():
    _, seq = _problem(4, 8, 2)
    signed = RotationSequence(seq.cos, seq.sin, jnp.full(seq.shape, -1.0))
    with pytest.raises(ValueError, match="per-entry signs"):
        signed.plan(m=4, method="wavefront")


# ---------------------------------------------------------- composition --

@pytest.mark.parametrize("method", ["unoptimized", "blocked", "accumulated"])
def test_transpose_inverts_application(method):
    A, seq = _problem(7, 12, 5, seed=7)
    kw = _kw(method) if method != "unoptimized" else {}
    out = seq.apply(A, method=method, **kw)
    back = seq.T.apply(out, method=method, **kw)
    np.testing.assert_allclose(np.asarray(back), np.asarray(A),
                               atol=2e-6, rtol=1e-5)


def test_transpose_inverts_reflectors_and_mixed_signs():
    A, seq = _problem(6, 10, 4, seed=11)
    refl = RotationSequence(seq.cos, seq.sin, None, True)
    back = refl.T.apply(refl.apply(A, method="blocked"), method="blocked")
    np.testing.assert_allclose(np.asarray(back), np.asarray(A), atol=2e-6)

    G = jnp.where(jax.random.bernoulli(jax.random.key(4), 0.5, seq.shape),
                  1.0, -1.0)
    mixed = RotationSequence(seq.cos, seq.sin, G)
    back = mixed.T.apply(mixed.apply(A, method="blocked"), method="blocked")
    np.testing.assert_allclose(np.asarray(back), np.asarray(A), atol=2e-6)


def test_concat_and_slice_compose():
    A, seq = _problem(5, 9, 6, seed=13)
    s1, s2 = seq[:2], seq[2:]
    assert s1.k == 2 and s2.k == 4
    two_step = s2.apply(s1.apply(A, method="blocked"), method="blocked")
    one_step = (s1 @ s2).apply(A, method="blocked")
    np.testing.assert_array_equal(np.asarray(two_step),
                                  np.asarray(one_step))
    full = seq.apply(A, method="blocked")
    np.testing.assert_array_equal(np.asarray(full), np.asarray(one_step))
    with pytest.raises(TypeError, match="slices"):
        seq[0]


def test_pad_to_is_identity_padding():
    A, seq = _problem(5, 9, 3, seed=17)
    padded = seq.pad_to(8)
    assert padded.k == 8
    np.testing.assert_allclose(
        np.asarray(padded.apply(A, method="blocked", n_b=8, k_b=4)),
        np.asarray(seq.apply(A, method="blocked", n_b=8, k_b=4)),
        atol=1e-6)
    with pytest.raises(ValueError, match="cannot pad"):
        seq.pad_to(2)
    # padding an all-reflector sequence must materialize rotation no-ops
    refl = RotationSequence(seq.cos, seq.sin, None, True)
    rp = refl.pad_to(8)
    assert rp.sign is not None
    np.testing.assert_allclose(
        np.asarray(rp.apply(A, method="blocked", n_b=8, k_b=4)),
        np.asarray(refl.apply(A, method="blocked", n_b=8, k_b=4)),
        atol=1e-6)


# --------------------------------------------------------- constructors --

def test_from_waves_validates_and_normalizes():
    with pytest.raises(ValueError, match="2D"):
        RotationSequence.from_waves(jnp.ones((3,)), jnp.zeros((3,)))
    with pytest.raises(ValueError, match="mismatch"):
        RotationSequence.from_waves(jnp.ones((3, 2)), jnp.zeros((3, 3)))
    with pytest.raises(ValueError, match="sign shape"):
        RotationSequence.from_waves(jnp.ones((3, 2)), jnp.zeros((3, 2)),
                                    jnp.ones((3, 3)))
    # drifted entries are renormalized; exact ones pass through bit-for-bit
    c = jnp.asarray([[1.0, 0.6 * 1.5], [0.0, 1.0]], jnp.float32)
    s = jnp.asarray([[0.0, 0.8 * 1.5], [1.0, 0.0]], jnp.float32)
    seq = RotationSequence.from_waves(c, s)
    r2 = np.asarray(seq.cos) ** 2 + np.asarray(seq.sin) ** 2
    np.testing.assert_allclose(r2, 1.0, atol=1e-6)
    assert float(seq.cos[0, 0]) == 1.0 and float(seq.sin[1, 0]) == 1.0
    untouched = RotationSequence.from_waves(c, s, normalize=False)
    assert float(untouched.cos[0, 1]) == pytest.approx(0.9, abs=1e-7)
    # a (0, 0) pair has no direction: both normalize modes repair it to
    # the identity rotation instead of annihilating columns
    for mode in ("auto", True):
        z = RotationSequence.from_waves(jnp.zeros((3, 2)),
                                        jnp.zeros((3, 2)), normalize=mode)
        np.testing.assert_array_equal(np.asarray(z.cos), 1.0)
        np.testing.assert_array_equal(np.asarray(z.sin), 0.0)


def test_from_pairs_and_identity():
    waves = [(np.array([0.6, 1.0]), np.array([0.8, 0.0])),
             (np.array([1.0, 0.0]), np.array([0.0, 1.0]))]
    seq = RotationSequence.from_pairs(waves)
    assert seq.shape == (2, 2) and seq.sign is None
    ident = RotationSequence.identity(5, 3)
    A, _ = _problem(4, 5, 1)
    np.testing.assert_array_equal(
        np.asarray(ident.apply(A, method="blocked")), np.asarray(A))
    with pytest.raises(ValueError, match="at least one wave"):
        RotationSequence.from_pairs([])


# ------------------------------------------------------------ gradients --

def _reference_apply(A, C, S):
    """Differentiable python-loop oracle (wave-major order)."""
    n = A.shape[1]
    for p in range(C.shape[1]):
        for j in range(n - 1):
            x, y = A[:, j], A[:, j + 1]
            A = A.at[:, j].set(C[j, p] * x + S[j, p] * y)
            A = A.at[:, j + 1].set(-S[j, p] * x + C[j, p] * y)
    return A


@pytest.mark.parametrize("method", ["unoptimized", "blocked", "accumulated",
                                    "auto"])
def test_grad_matches_finite_differences_f32(method):
    A, seq = _problem(4, 7, 3, seed=23)
    kw = {} if method in ("unoptimized", "auto") else _kw(method)
    plan = seq.plan(like=A, method=method, **kw)

    def loss(a):
        return (plan.apply(a) ** 2).sum()

    g = np.asarray(jax.grad(loss)(A), np.float64)
    An = np.asarray(A)
    eps = 1e-2  # central differences: f32 noise floor ~1e-3 on the grad
    for i in range(A.shape[0]):
        for j in range(A.shape[1]):
            e = np.zeros_like(An)
            e[i, j] = eps
            fd = (float(loss(jnp.asarray(An + e)))
                  - float(loss(jnp.asarray(An - e)))) / (2 * eps)
            assert abs(fd - g[i, j]) <= 1e-3 * max(1.0, abs(fd)), \
                (method, i, j, fd, g[i, j])


def test_grad_matches_linearized_reference():
    """custom_vjp cotangent == transpose of jax.linearize on the
    unoptimized reference (which differentiates through the actual
    rotation loop)."""
    A, seq = _problem(5, 8, 3, seed=29)
    plan = seq.plan(like=A, method="accumulated", n_b=8, k_b=4)

    _, f_lin = jax.linearize(
        lambda a: rot_sequence_unoptimized(a, seq.cos, seq.sin), A)
    f_t = jax.linear_transpose(f_lin, A)
    dY = jnp.asarray(
        np.random.default_rng(31).standard_normal(A.shape), jnp.float32)
    (dA_ref,) = f_t(dY)
    _, vjp = jax.vjp(plan.apply, A)
    (dA_plan,) = vjp(dY)
    np.testing.assert_allclose(np.asarray(dA_plan), np.asarray(dA_ref),
                               atol=2e-6, rtol=1e-5)
    # and both agree with grad of the python-loop oracle
    g_oracle = jax.grad(
        lambda a: (_reference_apply(a, seq.cos, seq.sin) ** 2).sum())(A)
    g_plan = jax.grad(lambda a: (plan.apply(a) ** 2).sum())(A)
    np.testing.assert_allclose(np.asarray(g_plan), np.asarray(g_oracle),
                               atol=1e-5, rtol=1e-4)


def test_grad_matches_finite_differences_f64():
    """f64 gradcheck at <=1e-8 needs x64 mode; isolate it in a
    subprocess so the suite's f32 default is untouched."""
    code = textwrap.dedent("""
        import jax
        jax.config.update("jax_enable_x64", True)
        import jax.numpy as jnp
        import numpy as np
        from repro.core import random_sequence

        rng = np.random.default_rng(0)
        A = jnp.asarray(rng.standard_normal((4, 6)), jnp.float64)
        seq = random_sequence(jax.random.key(1), 6, 3, dtype=jnp.float64)
        plan = seq.plan(like=A, method="blocked", n_b=8, k_b=4)
        loss = lambda a: (plan.apply(a) ** 2).sum()
        g = np.asarray(jax.grad(loss)(A))
        An = np.asarray(A)
        eps = 1e-6
        worst = 0.0
        for i in range(4):
            for j in range(6):
                e = np.zeros_like(An); e[i, j] = eps
                fd = (float(loss(jnp.asarray(An + e)))
                      - float(loss(jnp.asarray(An - e)))) / (2 * eps)
                worst = max(worst, abs(fd - g[i, j]) / max(1.0, abs(fd)))
        assert worst <= 1e-8, worst
        print("F64 GRAD OK", worst)
    """)
    import os

    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                      text=True, timeout=600, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "F64 GRAD OK" in r.stdout


def test_grad_with_reflect_through_unblocked_backend():
    """Transposing an all-reflector sequence materializes mixed signs;
    the cotangent must silently reroute through the blocked family."""
    A, seq = _problem(4, 6, 2, seed=37)
    refl = RotationSequence(seq.cos, seq.sin, None, True)
    plan = refl.plan(like=A, method="unoptimized")
    g = jax.grad(lambda a: (plan.apply(a) ** 2).sum())(A)
    g_ref = jax.grad(
        lambda a: (plan.apply(a) ** 2).sum())(A + 0)  # deterministic
    np.testing.assert_array_equal(np.asarray(g), np.asarray(g_ref))
    # value check against the blocked backend's own gradient
    plan_b = refl.plan(like=A, method="blocked", n_b=8, k_b=4)
    g_b = jax.grad(lambda a: (plan_b.apply(a) ** 2).sum())(A)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_b), atol=1e-5)


def test_compat_wrapper_keeps_native_angle_gradients():
    """The raw-array wrapper must keep the seed's autodiff semantics:
    gradients w.r.t. C/S flow through the actual backend computation
    (the typed plan.apply is the path with constant-sequence VJP)."""
    A, seq = _problem(5, 8, 3, seed=43)
    g_wrap = jax.grad(lambda c: (apply_rotation_sequence(
        A, c, seq.sin, method="blocked", n_b=8, k_b=4) ** 2).sum())(seq.cos)
    g_ref = jax.grad(lambda c: (rot_sequence_unoptimized(
        A, c, seq.sin) ** 2).sum())(seq.cos)
    assert float(jnp.abs(g_wrap).max()) > 0  # not silently zeroed
    np.testing.assert_allclose(np.asarray(g_wrap), np.asarray(g_ref),
                               atol=1e-4, rtol=1e-4)
    # the typed plan treats the sequence as a constant, by contract
    plan = seq.plan(like=A, method="blocked", n_b=8, k_b=4)
    g_plan = jax.grad(lambda c: (plan.rebind(
        RotationSequence(c, seq.sin)).apply(A) ** 2).sum())(seq.cos)
    np.testing.assert_array_equal(np.asarray(g_plan), 0.0)


# ------------------------------------------- empty sequences (bugfix) ----

@pytest.mark.parametrize("method", METHODS + ["auto"])
def test_empty_sequences_are_identity_for_every_method(method):
    """Regression: the zero-wave early return used to exist only on the
    method="auto" path; named methods crashed on (n-1, 0) or (0, k)
    wave grids."""
    A = jnp.asarray(np.random.default_rng(0).standard_normal((4, 6)),
                    jnp.float32)
    kw = {} if method in ("unoptimized", "wavefront", "auto") \
        else _kw(method)
    # k = 0: no waves
    out = apply_rotation_sequence(A, jnp.ones((5, 0)), jnp.zeros((5, 0)),
                                  method=method, **kw)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(A))
    # n = 1: no rotation sites
    A1 = A[:, :1]
    out = apply_rotation_sequence(A1, jnp.ones((0, 3)), jnp.zeros((0, 3)),
                                  method=method, **kw)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(A1))
    # typed path
    seq = RotationSequence(jnp.ones((5, 0)), jnp.zeros((5, 0)))
    plan = seq.plan(like=A, method=method, **kw)
    np.testing.assert_array_equal(np.asarray(plan.apply(A)),
                                  np.asarray(A))


def test_empty_sequences_still_validate_method():
    """The empty early return must not swallow method typos or
    capability violations."""
    seq = RotationSequence(jnp.ones((5, 0)), jnp.zeros((5, 0)))
    with pytest.raises(ValueError, match="unknown method"):
        seq.plan(m=4, method="definitely_not_a_backend")
    signed = RotationSequence(jnp.ones((5, 0)), jnp.zeros((5, 0)),
                              jnp.ones((5, 0)))
    with pytest.raises(ValueError, match="per-entry signs"):
        signed.plan(m=4, method="wavefront")


# ----------------------------------------------------------- deprecation --

def test_raw_sign_kwarg_warns_deprecation():
    A, seq = _problem(4, 8, 2, seed=41)
    G = jnp.full(seq.shape, -1.0)
    with pytest.warns(DeprecationWarning, match="RotationSequence"):
        out = apply_rotation_sequence(A, seq.cos, seq.sin, method="blocked",
                                      G=G, n_b=8, k_b=4)
    # all-rotation signs: same result as the typed sign-free sequence
    np.testing.assert_allclose(
        np.asarray(out),
        np.asarray(seq.apply(A, method="blocked", n_b=8, k_b=4)),
        atol=1e-6)
