"""Fused multi-request kernel: one launch per bucket, bitwise parity
with per-request execution, identity-plane skipping (pad_to tails and
seq.T staircases), registry routing, and plan serialization."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.core import registry
from repro.core.registry import clear_plan_cache, select_plan
from repro.core.rotations import random_sequence
from repro.core.sequence import RotationSequence, SequencePlan
from repro.kernels.rotseq_batched.ops import (count_live_planes,
                                              rot_sequence_batched)
from repro.kernels.rotseq_batched.ref import rot_sequence_batched_ref


def _per_request_ref(A, seqs, method="blocked", **kw):
    """The fused contract's oracle: b separate planned applications."""
    return jnp.stack([
        s.plan(like=A[i], method=method, **kw).apply(A[i])
        for i, s in enumerate(seqs)])


# ------------------------------------------------------ bitwise parity ----

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("oracle", ["blocked", "unoptimized"])
def test_fused_per_request_bitwise(dtype, oracle):
    """Per-request wave stacks in one launch == b per-request applies,
    bit-for-bit, on the rotation family."""
    rng = np.random.default_rng(0)
    b, m, n, k = 5, 12, 20, 8
    A = jnp.asarray(rng.standard_normal((b, m, n)), dtype)
    seqs = [random_sequence(jax.random.key(i), n, k, dtype=dtype)
            for i in range(b)]
    plan = seqs[0].plan(like=A, method="rotseq_batched")
    out = plan.apply_batched(A, sequences=seqs)
    ref = _per_request_ref(A, seqs, method=oracle)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_fused_shared_sequence_bitwise():
    rng = np.random.default_rng(1)
    b, m, n, k = 4, 16, 32, 8
    A = jnp.asarray(rng.standard_normal((b, m, n)), jnp.float32)
    seq = random_sequence(jax.random.key(0), n, k)
    plan = seq.plan(like=A, method="rotseq_batched")
    out = plan.apply_batched(A)
    ref = _per_request_ref(A, [seq] * b)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_fused_sign_families_bitwise():
    """Per-entry-sign and all-reflector stacks (incl. mixed batches under
    a sign-carrying plan) stay bit-identical to the per-request loop."""
    rng = np.random.default_rng(2)
    b, m, n, k = 4, 8, 16, 4
    A = jnp.asarray(rng.standard_normal((b, m, n)), jnp.float32)
    base = [random_sequence(jax.random.key(i), n, k) for i in range(b)]
    sgn = jnp.where(rng.random((n - 1, k)) < 0.5, 1.0, -1.0)
    seqs = [
        RotationSequence(base[0].cos, base[0].sin, sgn.astype(jnp.float32)),
        RotationSequence(base[1].cos, base[1].sin, None, True),  # reflector
        base[2],                                                 # plain
        RotationSequence.identity(n, k),                         # slot pad
    ]
    plan = seqs[0].plan(like=A, method="rotseq_batched")
    out = plan.apply_batched(A, sequences=seqs)
    ref = _per_request_ref(A, seqs)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_fused_staircase_and_padded_bitwise():
    """seq.T staircases and pad_to'd sequences — the identity-heavy
    inputs the plane-skip exists for — stay exact."""
    rng = np.random.default_rng(3)
    b, m, n, k = 4, 8, 24, 6
    A = jnp.asarray(rng.standard_normal((b, m, n)), jnp.float32)
    stair = [random_sequence(jax.random.key(i), n, k).T for i in range(b)]
    plan = stair[0].plan(like=A, method="rotseq_batched")
    out = plan.apply_batched(A, sequences=stair)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(_per_request_ref(A, stair)))

    padded = [random_sequence(jax.random.key(10 + i), n, 3).pad_to(8)
              for i in range(b)]
    plan2 = padded[0].plan(like=A, method="rotseq_batched")
    out2 = plan2.apply_batched(A, sequences=padded)
    np.testing.assert_array_equal(np.asarray(out2),
                                  np.asarray(_per_request_ref(A, padded)))


def test_fused_f64_bitwise():
    with compat.enable_x64():
        rng = np.random.default_rng(4)
        b, m, n, k = 3, 8, 12, 4
        A = jnp.asarray(rng.standard_normal((b, m, n)), jnp.float64)
        seqs = [random_sequence(jax.random.key(i), n, k, dtype=jnp.float64)
                for i in range(b)]
        plan = seqs[0].plan(like=A, method="rotseq_batched")
        out = plan.apply_batched(A, sequences=seqs)
        assert out.dtype == jnp.float64
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(_per_request_ref(A, seqs)))


# --------------------------------------------------- plane skipping ----

def test_fused_skips_identity_planes():
    """Acceptance: the kernel processes exactly the live-plane hull —
    pad_to tails and staircase triangles are skipped, not applied."""
    rng = np.random.default_rng(5)
    b, m, n, k_orig, k_pad = 3, 8, 16, 3, 8
    A = jnp.asarray(rng.standard_normal((b, m, n)), jnp.float32)
    seqs = [random_sequence(jax.random.key(i), n, k_orig).pad_to(k_pad)
            for i in range(b)]
    C = jnp.stack([s.cos for s in seqs])
    S = jnp.stack([s.sin for s in seqs])
    out, planes = rot_sequence_batched(A, C, S, m_blk=8, return_planes=True)
    planes = np.asarray(planes)
    total = (n - 1) * k_pad
    for i, s in enumerate(seqs):
        live = count_live_planes(s)
        assert live <= (n - 1) * k_orig < total
        # every m-block of request i reports exactly its live planes
        assert (planes[i] == live).all(), (i, planes[i], live)

    # the seq.T staircase: n+k-2 waves, but only the original planes live
    t = random_sequence(jax.random.key(9), n, k_orig).T
    out_t, planes_t = rot_sequence_batched(A, t.cos, t.sin, m_blk=8,
                                           return_planes=True)
    assert t.k == n + k_orig - 2
    live_t = count_live_planes(t)
    assert live_t == (n - 1) * k_orig  # == t.k_live
    assert t.k_live == live_t
    assert (np.asarray(planes_t) == live_t).all()
    assert live_t < (n - 1) * t.k  # strictly fewer than the padded grid

    # an all-identity stack processes zero planes
    ident = RotationSequence.identity(n, k_pad)
    out_i, planes_i = rot_sequence_batched(A, ident.cos, ident.sin,
                                           m_blk=8, return_planes=True)
    assert (np.asarray(planes_i) == 0).all()
    np.testing.assert_array_equal(np.asarray(out_i), np.asarray(A))


def test_padded_reflector_planes_stay_live():
    """A c=1, s=0 *reflector* is diag(1, -1), not the identity — the
    skip test must key on the sign."""
    n, k = 8, 2
    C = jnp.ones((n - 1, k), jnp.float32)
    S = jnp.zeros((n - 1, k), jnp.float32)
    A = jnp.asarray(np.random.default_rng(0).standard_normal((2, 4, n)),
                    jnp.float32)
    out, planes = rot_sequence_batched(A, C, S, reflect=True, m_blk=8,
                                       return_planes=True)
    assert (np.asarray(planes) == (n - 1) * k).all()
    ref = rot_sequence_batched_ref(A, C, S, reflect=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# ------------------------------------------------ k_live propagation ----

def test_k_live_static_propagation():
    seq = random_sequence(jax.random.key(0), 16, 4)
    J = 15
    assert seq.k_live is None
    assert seq.T.k_live == J * 4
    assert seq.pad_to(8).k_live == J * 4
    assert seq.pad_to(8).T.k_live == J * 4
    assert RotationSequence.identity(16, 4).k_live == 0
    both = seq.pad_to(8) @ seq.pad_to(8)
    assert both.k_live == 2 * J * 4
    assert seq.with_signs().k_live is None
    # pytree round-trip preserves the static aux
    leaves, treedef = jax.tree_util.tree_flatten(seq.T)
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    assert back.k_live == seq.T.k_live
    # serialization carries it
    d = json.loads(json.dumps(seq.T.to_dict()))
    assert RotationSequence.from_dict(d).k_live == seq.T.k_live


def test_registry_routes_staircase_to_fused_on_tpu():
    """seq.T planning: the live-plane-aware cost model sends thin
    staircases to the plane-skipping kernel on TPU while dense grids of
    the same padded shape stay on the GEMM family — and staircases
    whose C/S/G panels exceed the kernel's SMEM budget are priced off
    it (interpret mode would run them; Mosaic could not compile them)."""
    clear_plan_cache()
    thin = select_plan(4096, 96, 102, platform="tpu",
                       live_planes=95 * 8)
    dense = select_plan(4096, 96, 102, platform="tpu")
    assert thin.method == "rotseq_batched"
    assert dense.method != "rotseq_batched"
    # distinct cache keys: the live-plane entry must not shadow dense
    assert select_plan(4096, 96, 102, platform="tpu").method == \
        dense.method
    # (255, 263) panels are ~800KB of SMEM — never routed on TPU
    big = select_plan(4096, 256, 263, platform="tpu",
                      live_planes=255 * 8)
    assert big.method != "rotseq_batched"
    clear_plan_cache()


def test_interpolation_respects_liveness_class():
    """A measured plane-skipping plan keyed with a live-plane count must
    not transfer at distance 0 to the dense grid of the same shape (and
    vice versa) — liveness is part of the interpolation class; nearby
    live-annotated problems may still borrow it."""
    clear_plan_cache()
    p_live = registry.Problem(m=4096, n=96, k=102, platform="tpu",
                              live_planes=95 * 8)
    key = registry._plan_key(p_live)
    registry._PLAN_CACHE[key] = registry.Plan(
        "rotseq_batched", m_blk=256, est_seconds=1e-5, source="measured")
    dense = select_plan(4096, 96, 102, platform="tpu")
    assert dense.method != "rotseq_batched"
    near = select_plan(4096, 96, 102, platform="tpu",
                       live_planes=95 * 10)
    assert near.method == "rotseq_batched"
    assert near.source == "interpolated"
    clear_plan_cache()


# ------------------------------------------------------- autodiff ----

def test_fused_grad_matches_blocked_bitwise():
    """The fused custom_vjp (transposed-stack cotangent) must equal the
    per-target transposed-sequence VJP of the jnp family exactly."""
    rng = np.random.default_rng(6)
    b, m, n, k = 4, 8, 12, 4
    A = jnp.asarray(rng.standard_normal((b, m, n)), jnp.float32)
    shared = random_sequence(jax.random.key(0), n, k)
    plan_f = shared.plan(like=A, method="rotseq_batched")
    plan_b = shared.plan(like=A, method="blocked", n_b=8, k_b=4)
    loss = lambda p: lambda x: (p.apply_batched(x) ** 2).sum()
    g_f = jax.grad(loss(plan_f))(A)
    g_b = jax.grad(loss(plan_b))(A)
    np.testing.assert_array_equal(np.asarray(g_f), np.asarray(g_b))

    # per-request stacks (incl. a signed member under a signed plan)
    sgn = jnp.where(rng.random((n - 1, k)) < 0.5, 1.0, -1.0)
    seqs = [RotationSequence(shared.cos, shared.sin,
                             sgn.astype(jnp.float32)),
            random_sequence(jax.random.key(1), n, k),
            random_sequence(jax.random.key(2), n, k),
            RotationSequence.identity(n, k)]
    plan_fs = seqs[0].plan(like=A, method="rotseq_batched")
    g_fs = jax.grad(
        lambda x: (plan_fs.apply_batched(x, sequences=seqs) ** 2).sum())(A)
    refs = jnp.stack([
        jax.grad(lambda x: (s.plan(
            like=A[i], method="blocked", n_b=8, k_b=4).apply(x) ** 2).sum())
        (A[i]) for i, s in enumerate(seqs)])
    np.testing.assert_array_equal(np.asarray(g_fs), np.asarray(refs))


# ------------------------------------------------- plan round-trip ----

def test_fused_plan_dict_roundtrip():
    """SequencePlan dicts for plans that selected the fused backend
    round-trip through real JSON and reproduce bucket outputs exactly."""
    rng = np.random.default_rng(7)
    b, m, n, k = 3, 8, 16, 4
    A = jnp.asarray(rng.standard_normal((b, m, n)), jnp.float32)
    seqs = [random_sequence(jax.random.key(i), n, k) for i in range(b)]
    plan = seqs[0].plan(like=A, method="rotseq_batched")
    d = json.loads(json.dumps(plan.to_dict()))
    assert d["method"] == "rotseq_batched"
    plan2 = SequencePlan.from_dict(d, seqs[0])
    assert plan2.method == "rotseq_batched"
    np.testing.assert_array_equal(
        np.asarray(plan2.apply_batched(A, sequences=seqs)),
        np.asarray(plan.apply_batched(A, sequences=seqs)))


def test_fused_capability_record():
    spec = registry.get_backend("rotseq_batched")
    assert spec.capability.batch_via == "fused"
    assert spec.capability.supports_signs
    assert spec.capability.needs_pallas and spec.capability.interpret_ok
    # cost model scales with live planes
    p_dense = registry.Problem(m=4096, n=96, k=102, platform="tpu")
    p_live = registry.Problem(m=4096, n=96, k=102, platform="tpu",
                              live_planes=95 * 8)
    plan = registry.Plan("rotseq_batched", m_blk=256)
    assert spec.cost(p_live, plan) < spec.cost(p_dense, plan)
    # and prices out panels beyond the SMEM budget
    p_big = registry.Problem(m=4096, n=256, k=263, platform="tpu",
                             live_planes=255 * 8)
    assert spec.cost(p_big, plan) > 100 * spec.cost(p_live, plan)
