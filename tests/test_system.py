"""End-to-end behaviour tests for the paper's system."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data import DataConfig, SyntheticLM
from repro.models import build_model
from repro.optim import AdamW
from repro.train import TrainLoop, make_train_step


def test_e2e_loss_decreases():
    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
                      head_dim=16, dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    opt = AdamW(lr=3e-3)
    step = jax.jit(make_train_step(model, cfg, opt, remat=False))
    loop = TrainLoop(train_step=step, params=params,
                     opt_state=opt.init(params),
                     data_iter=SyntheticLM(DataConfig(vocab=256, seq_len=32,
                                                      global_batch=8)))
    hist = loop.run(50)
    assert hist["loss"][-1] < hist["loss"][0] * 0.75, hist["loss"][::10]


def test_grad_accum_matches_full_batch():
    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab=64,
                      head_dim=16, dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    opt = AdamW(lr=1e-3, clip_norm=None, weight_decay=0.0)
    s1 = jax.jit(make_train_step(model, cfg, opt, remat=False))
    s4 = jax.jit(make_train_step(model, cfg, opt, remat=False,
                                 grad_accum=4))
    batch = {
        "tokens": jax.random.randint(jax.random.key(2), (8, 16), 0, 64),
        "labels": jax.random.randint(jax.random.key(3), (8, 16), 0, 64),
    }
    p1, _, m1 = s1(params, opt.init(params), batch)
    p4, _, m4 = s4(params, opt.init(params), batch)
    err = max(float(jnp.abs(a - b).max())
              for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)))
    assert err < 5e-6, err
