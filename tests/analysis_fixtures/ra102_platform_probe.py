# repro-lint: fixture-as=benchmarks/bad_probe.py
"""RA102 fixture: platform probed outside compat.py."""
import jax


def which_backend():
    return jax.default_backend()  # expect: RA102


def how_many():
    return len(jax.devices())  # expect: RA102
