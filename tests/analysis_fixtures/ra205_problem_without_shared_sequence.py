# repro-lint: fixture-as=src/repro/serve/bad_pricing.py
"""RA205 fixture: batched Problem priced without saying who owns the
sequence.

A serving-layer helper that builds a ``Problem(batch=64)`` straight
from bucket geometry inherits ``shared_sequence=True`` and tells the
cost model the per-sequence setup is paid once — for a per-request
bucket it is paid 64 times, which is exactly the mispricing that made
``method="auto"`` lose to a pinned kernel on streaming traffic.
"""
from repro.core.registry import Problem
from repro.core import registry


def bad_bucket_pricing(m, n, k, b):
    return Problem(m=m, n=n, k=k, dtype="float32",  # expect: RA205
                   platform="cpu", batch=b)


def bad_qualified_pricing(m, n, k):
    return registry.Problem(m=m, n=n, k=k,  # expect: RA205
                            dtype="float32", platform="cpu", batch=64)


def fine_unit_batch(m, n, k):
    # literally batch=1 — shared vs per-request is the same price
    return Problem(m=m, n=n, k=k, dtype="float32",
                   platform="cpu", batch=1)


def fine_explicit(m, n, k, b):
    # the flag is spelled, whichever value the caller means
    return Problem(m=m, n=n, k=k, dtype="float32",
                   platform="cpu", batch=b, shared_sequence=False)
