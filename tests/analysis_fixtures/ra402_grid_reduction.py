# repro-lint: fixture-as=src/repro/kernels/bad_grid_reduce.py
"""RA402 fixture: jnp reduction over a traced grid index in a kernel."""
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bad_kernel(x_ref, o_ref):
    w = jnp.sum(jnp.arange(8) * pl.program_id(0))  # expect: RA402
    o_ref[...] = x_ref[...] + w


def bad_launch(x):
    return pl.pallas_call(
        _bad_kernel,
        grid=(4,),
        out_shape=x,
    )(x)
