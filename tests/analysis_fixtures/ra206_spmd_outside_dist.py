# repro-lint: fixture-as=src/repro/serve/bad_spmd.py
"""RA206 fixture: SPMD primitives outside the dist layer.

A collective issued from the serve layer is a second distribution path
the comm-extended cost model (and the obs comm-bytes attribution)
never sees — the incident class PR 10's repro.dist refactor closed.
"""
import jax

from jax.lax import ppermute as _pp  # expect: RA206


def bad_allreduce(x):
    return jax.lax.psum(x, "data")  # expect: RA206


def bad_halo_exchange(x, perm):
    return _pp(x, "data", perm)  # expect: RA206
