# repro-lint: fixture-as=src/repro/core/bad_clamp.py
"""RA404 fixture: tile round-up/clamp re-derived instead of imported."""


def _round_up(x: int, mult: int) -> int:  # expect: RA404
    return ((x + mult - 1) // mult) * mult  # expect: RA404


def bad_inline_clamp(m: int, m_blk: int) -> int:
    return min(m_blk, ((max(1, m) + 7) // 8) * 8)  # expect: RA404
