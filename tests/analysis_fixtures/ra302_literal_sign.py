# repro-lint: fixture-as=src/repro/core/bad_sign.py
"""RA302 fixture: fold-prone literal sign in a traced plane_update call.

The PR 5 bug class: a Python scalar ``-1.0`` lets XLA constant-fold
``g * (...)`` into a re-associated contraction, flipping low-order
bits relative to the runtime-array path.
"""
import jax.numpy as jnp

from repro.core.rotations import plane_update


def bad_traced_literal(x, y, c, s):
    return plane_update(jnp.asarray(x), y, c, s, -1.0)  # expect: RA302


def ok_runtime_sign(x, y, c, s, refl):
    g = jnp.where(refl, -1.0, 1.0)
    return plane_update(jnp.asarray(x), y, c, s, g)


def ok_host_numpy(x, y, c, s):
    # host-side recurrence (eig layer): nothing folds it, exempt
    return plane_update(x, y, c, s, -1.0)
