# repro-lint: fixture-as=src/repro/dist/bad_kernel_call.py
"""RA206 fixture: the dist layer importing a kernel directly.

A shard-local kernel launch dodges the registry's SMEM/VMEM budget
guard and the launches-per-shard accounting; repro.dist executes only
through the planned repro.core.sequence hooks.  (RA202 fires too —
kernel imports are confined to core/api.py tree-wide.)
"""
from repro.kernels.rotseq_batched.ops import rot_sequence_batched  # expect: RA206  # expect: RA202


def bad_sharded_apply(A, C, S):
    return rot_sequence_batched(A, C, S)  # expect: RA206  # expect: RA202
