# repro-lint: fixture-as=benchmarks/bench_adhoc.py
"""RA502 fixture: ad-hoc stopwatch code outside ``repro.obs``.

Every hand-rolled ``time.perf_counter()`` pair is a number the roofline
attribution never sees; ``repro.obs.timing`` (re-exported by
``benchmarks.common``) is the single sanctioned clock.  A bare
``import time`` stays legal — ``time.sleep`` is not a clock.
"""
import time
import timeit  # expect: RA502
from time import perf_counter  # expect: RA502


def measure(fn) -> float:
    t0 = time.perf_counter()  # expect: RA502
    fn()
    return time.perf_counter() - t0  # expect: RA502


def stamp() -> float:
    return time.time()  # expect: RA502


def measure_aliased(fn) -> float:
    t0 = perf_counter()  # expect: RA502
    fn()
    return perf_counter() - t0  # expect: RA502


def best_of_three(fn) -> float:
    return min(timeit.repeat(fn, number=1, repeat=3))  # expect: RA502


def backoff() -> None:
    time.sleep(0.01)  # sleeping is not timing: legal
