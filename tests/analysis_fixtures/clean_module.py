# repro-lint: fixture-as=src/repro/serve/good_citizen.py
"""Clean fixture: a serve-layer module using only the typed API.

Must produce zero violations under every rule family.
"""
from repro.core import RotationSequence
from repro.core.rotations import plane_update
from repro.kernels.limits import SMEM_PANEL_BUDGET, clamp_m_blk


def plan_and_apply(seq: RotationSequence, A):
    plan = seq.plan(like=A)
    return plan.apply(A)


def host_stencil(x, y, c, s):
    # canonical stencil via plane_update, host-side sign is fine
    return plane_update(x, y, c, s, -1.0)


def fits_budget(planes: int, itemsize: int) -> bool:
    return 3 * planes * itemsize <= SMEM_PANEL_BUDGET


def tile(m: int) -> int:
    return clamp_m_blk(m, 256)
