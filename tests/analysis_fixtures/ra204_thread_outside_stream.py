# repro-lint: fixture-as=src/repro/serve/bad_worker.py
"""RA204 fixture: concurrency primitives sprouting outside the engine.

A second worker thread next to ``repro.serve.stream`` races the
engine's exactly-once bucket planning and the obs counters; the stream
engine is the serving stack's one concurrent component.
"""
import threading  # expect: RA204
from queue import Queue  # expect: RA204
from concurrent.futures import ThreadPoolExecutor  # expect: RA204


def bad_background_drain(svc, key):
    jobs = Queue()  # expect: RA204

    def worker():
        while True:
            batch = jobs.get()
            if batch is None:
                return
            svc.execute_batch(key, *batch)

    t = threading.Thread(target=worker, daemon=True)  # expect: RA204
    t.start()
    return jobs, t


def bad_pool_drain(svc, key, batches):
    with ThreadPoolExecutor(max_workers=4) as pool:  # expect: RA204
        return list(pool.map(
            lambda b: svc.execute_batch(key, *b), batches))
