# repro-lint: fixture-as=src/repro/serve/bad_raw_apply.py
"""RA201 regression fixture: the seq-gate grep false negative.

The old Makefile gate searched for the literal pattern
``apply_rotation_sequence\\s*\\(`` — this file never spells that, so
grep reports nothing, yet it calls the raw wrapper from the serve
layer.  RA201 resolves the import alias and flags both lines
(tests/test_analysis.py asserts the grep finds zero matches here).
"""
from repro.core.api import apply_rotation_sequence as _ars  # expect: RA201


def sneaky_apply(A, C, S):
    return _ars(A, C, S)  # expect: RA201
