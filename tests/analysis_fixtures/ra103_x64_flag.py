# repro-lint: fixture-as=tests/bad_x64.py
"""RA103 fixture: jax_enable_x64 flipped without the compat context."""
import jax


def leak_x64():
    jax.config.update("jax_enable_x64", True)  # expect: RA103
