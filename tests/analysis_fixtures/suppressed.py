# repro-lint: fixture-as=src/repro/core/suppressed_stencil.py
"""Suppression fixture: inline stencils silenced both ways.

Must produce zero violations — exercises ``disable=`` on the line and
``disable-next=`` on the preceding line.
"""


def quieted_inline(x, y, c, s):
    xn = c * x + s * y
    yn = s * x - c * y  # repro-lint: disable=RA301
    return xn, yn


def quieted_next_line(x, y, c, s):
    xn = c * x + s * y
    # repro-lint: disable-next=RA3
    yn = -s * x + c * y
    return xn, yn
