# repro-lint: fixture-as=src/repro/core/bad_budget.py
"""RA403 fixture: on-chip budget constant redefined outside limits.py.

The PR 5 coupling bug: a second copy of the budget lets the cost model
price a kernel off stale limits.
"""

_SMEM_PANEL_BUDGET = 128 * 2**10  # expect: RA403

VMEM_SLAB_BUDGET = 8 * 2**20  # expect: RA403
