# repro-lint: fixture-as=src/repro/kernels/bad_kernel_host.py
"""RA401 fixture: host round-trips inside a Pallas kernel body.

Interpret mode executes these happily; Mosaic lowering cannot.
"""
import functools

import numpy as np
from jax.experimental import pallas as pl


def _bad_kernel(x_ref, o_ref, *, scale: float):
    v = x_ref[...]
    peek = float(v[0, 0])  # expect: RA401
    probe = v[0, 0].item()  # expect: RA401
    host = np.asarray(v)  # expect: RA401
    del peek, probe, host
    o_ref[...] = v * scale


def bad_launch(x):
    kernel = functools.partial(_bad_kernel, scale=2.0)
    return pl.pallas_call(
        kernel,
        out_shape=x,
    )(x)
