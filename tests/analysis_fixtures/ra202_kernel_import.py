# repro-lint: fixture-as=src/repro/models/bad_kernel_call.py
"""RA202 fixture: rotseq kernel imported outside the dispatch layer.

A direct kernel call skips the registry's SMEM/VMEM budget guard.
"""
from repro.kernels.rotseq_batched.ops import rot_sequence_batched  # expect: RA202


def bad_direct_launch(A, C, S, G):
    return rot_sequence_batched(A[None], C, S, G=G)  # expect: RA202
