# repro-lint: fixture-as=src/repro/core/bad_keys.py
"""RA501 fixture: wall-clock/RNG in cache-key and cost-model paths.

A timestamped plan key makes identical problems hash to different
plans, silently defeating the on-disk plan store.
"""
import random
import time

import numpy as np


def plan_key(problem) -> tuple:
    return (problem.m, problem.n, time.time())  # expect: RA501  # expect: RA502


def cost_flaky(problem, plan) -> float:
    return 6.0 * problem.m * problem.k * random.random()  # expect: RA501


def _bucket_key(seq) -> tuple:
    return (seq.n, np.random.default_rng().integers(10))  # expect: RA501


def _measure_plan(fn):
    # measurement helpers escape RA501 (name is outside the key/cost
    # pattern) but still trip RA502: even measurement code must source
    # its clock from repro.obs.timing
    t0 = time.perf_counter()  # expect: RA502
    fn()
    return time.perf_counter() - t0  # expect: RA502
