# repro-lint: fixture-as=src/repro/eig/bad_backend_pin.py
"""RA203 fixture: eig layer reaching below the typed sequence API.

Pinning one backend here bypasses plan caching and the cost model —
the incident that motivated the original eig-gate.
"""
from repro.core.blocked import rot_sequence_blocked  # expect: RA203


def bad_pinned_apply(A, C, S):
    return rot_sequence_blocked(A, C, S, n_b=128, k_b=64)  # expect: RA203
