# repro-lint: fixture-as=src/repro/parallel/bad_shims.py
"""RA101 fixture: version-sensitive JAX API outside compat.py.

Every spelling here moved or was renamed between jax 0.4.37 and 0.5.x;
all must route through repro.compat.  The aliased forms are the ones
the old compat-gate grep could not see.
"""
import jax
from jax.experimental import shard_map as _smap_mod  # expect: RA101
from jax.experimental.pallas import tpu as pltpu


def bad_direct(f, mesh, specs):
    return jax.shard_map(f, mesh=mesh, in_specs=specs)  # expect: RA101


def bad_aliased(f, mesh, specs):
    return _smap_mod.shard_map(f, mesh=mesh)  # expect: RA101


def bad_typeof(x):
    return jax.typeof(x)  # expect: RA101


def bad_pvary(x):
    return jax.lax.pvary(x, "i")  # expect: RA101


def bad_params():
    return pltpu.CompilerParams(dimension_semantics=())  # expect: RA101
