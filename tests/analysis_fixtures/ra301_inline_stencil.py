# repro-lint: fixture-as=src/repro/core/bad_stencil.py
"""RA301 fixture: hand-inlined 2x2 plane stencils.

Both spellings of the second row (``s*x - c*y`` and ``-s*x + c*y``)
must be caught; XLA contracts them into different multiply orders than
``plane_update``'s canonical ``g * (s*x - c*y)``.
"""
import jax.numpy as jnp


def bad_plain(x, y, c, s):
    xn = c * x + s * y
    yn = s * x - c * y  # expect: RA301
    return xn, yn


def bad_negated(x, y, c, s):
    xn = c * x + s * y
    yn = -s * x + c * y  # expect: RA301
    return jnp.stack([xn, yn])


def ok_sum_difference(x, y, a, b):
    # same pairing on both lines: a plain sum/difference, not a plane
    u = a * x + b * y
    v = a * x - b * y
    return u, v
