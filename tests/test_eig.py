"""`repro.eig` subsystem: recorded-rotation eigensolvers and SVD.

Oracle tests against `{np,jnp}.linalg`, staircase-packing correctness of
the tridiagonal/bidiagonal recordings, delayed-buffer flush equivalence
(bit-for-bit per backend), persisted plan cache round-trip, and the
SOAP-Givens `solver="qr"` consumer.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.core import registry
from repro.core.api import apply_rotation_sequence
from repro.core.ref import rot_sequence_numpy
from repro.core.rotations import random_sequence
from repro.eig import (DelayedRotationBuffer, bidiag_qr, bidiagonalize,
                       eigh_givens, svd_givens, tridiag_qr, tridiagonalize)


def _sym(n, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, n)).astype(dtype)
    return (X + X.T) / 2


# ------------------------------------------------------------ tridiag ----

@pytest.mark.parametrize("n", [2, 5, 33, 64])
def test_tridiagonalize_records_similarity(n):
    """Replaying the recorded staircase waves reproduces Q: Q^T H Q = T."""
    H = _sym(n, seed=n, dtype=np.float64)
    tri = tridiagonalize(H)
    Q = rot_sequence_numpy(np.eye(n), tri.cos, tri.sin)
    np.testing.assert_allclose(Q.T @ Q, np.eye(n), atol=1e-12 * n)
    T = Q.T @ H @ Q
    band = np.abs(np.subtract.outer(np.arange(n), np.arange(n))) > 1
    scale = np.abs(H).max()
    if band.any():
        assert np.abs(T[band]).max() <= 1e-12 * n * scale
    np.testing.assert_allclose(np.diagonal(T), tri.diag,
                               atol=1e-12 * n * scale)
    np.testing.assert_allclose(np.diagonal(T, 1), tri.offdiag,
                               atol=1e-12 * n * scale)


def test_tridiag_qr_eigenvalues_and_sequence():
    """QR waves diagonalize T both as scalars and as a replayed sequence."""
    n = 24
    H = _sym(n, seed=3, dtype=np.float64)
    tri = tridiagonalize(H)
    qr = tridiag_qr(tri.diag, tri.offdiag)
    assert qr.converged
    ref = np.sort(np.linalg.eigvalsh(H))
    np.testing.assert_allclose(np.sort(qr.eigenvalues), ref,
                               atol=1e-12 * n * np.abs(ref).max())
    # replay: U^T T U must be diag(eigenvalues)
    T = np.diag(tri.diag) + np.diag(tri.offdiag, 1) + np.diag(tri.offdiag, -1)
    U = rot_sequence_numpy(np.eye(n), qr.cos, qr.sin)
    np.testing.assert_allclose(U.T @ T @ U, np.diag(qr.eigenvalues),
                               atol=1e-11 * n * np.abs(ref).max())


# --------------------------------------------------------------- eigh ----

@pytest.mark.parametrize("n", [4, 33, 64])
def test_eigh_qr_oracle_f32(n):
    H = _sym(n, seed=n + 1)
    w, V = eigh_givens(jnp.asarray(H), method="qr")
    ref = np.sort(np.linalg.eigvalsh(H.astype(np.float64)))
    scale = np.abs(ref).max()
    assert np.abs(np.asarray(w) - ref).max() <= 1e-4 * scale
    Vn = np.asarray(V, np.float64)
    np.testing.assert_allclose(Vn.T @ Vn, np.eye(n), atol=1e-4)
    resid = np.abs(Vn.T @ H @ Vn - np.diag(np.asarray(w, np.float64))).max()
    assert resid <= 1e-4 * n * scale


def test_eigh_qr_oracle_f32_n256():
    """Acceptance bar: n=256 float32 within 1e-4 relative of the oracle."""
    n = 256
    H = _sym(n, seed=7)
    w, V = eigh_givens(jnp.asarray(H), method="qr")
    ref = np.sort(np.linalg.eigvalsh(H.astype(np.float64)))
    scale = np.abs(ref).max()
    assert np.abs(np.asarray(w) - ref).max() <= 1e-4 * scale
    Vn = np.asarray(V, np.float64)
    assert np.abs(Vn.T @ Vn - np.eye(n)).max() <= 1e-4
    resid = np.abs(Vn.T @ H @ Vn - np.diag(np.asarray(w, np.float64))).max()
    assert resid <= 1e-4 * scale * np.sqrt(n)


def test_eigh_qr_oracle_f64():
    """Acceptance bar: float64 within 1e-10 relative (x64 mode)."""
    with compat.enable_x64():
        n = 48
        H = _sym(n, seed=11, dtype=np.float64)
        w, V = eigh_givens(jnp.asarray(H), method="qr")
        assert w.dtype == jnp.float64 and V.dtype == jnp.float64
        ref = np.sort(np.linalg.eigvalsh(H))
        scale = np.abs(ref).max()
        assert np.abs(np.asarray(w) - ref).max() <= 1e-10 * scale
        Vn = np.asarray(V)
        assert np.abs(Vn.T @ Vn - np.eye(n)).max() <= 1e-10
        resid = np.abs(Vn.T @ H @ Vn - np.diag(np.asarray(w))).max()
        assert resid <= 1e-10 * scale


def test_eigh_jacobi_wrapper_matches_oracle():
    n = 16
    H = _sym(n, seed=5)
    w, V = eigh_givens(jnp.asarray(H), method="jacobi", cycles=8)
    ref = np.sort(np.linalg.eigvalsh(H.astype(np.float64)))
    np.testing.assert_allclose(np.asarray(w), ref, atol=1e-4 * n)
    assert np.all(np.diff(np.asarray(w)) >= -1e-6)  # sorted ascending
    Vn = np.asarray(V, np.float64)
    np.testing.assert_allclose(Vn.T @ Vn, np.eye(n), atol=1e-5 * n)


def test_eigh_methods_agree():
    H = _sym(12, seed=9)
    wq, _ = eigh_givens(jnp.asarray(H), method="qr")
    wj, _ = eigh_givens(jnp.asarray(H), method="jacobi")
    np.testing.assert_allclose(np.asarray(wq), np.asarray(wj), atol=2e-3)


def test_eigh_unknown_method_raises():
    with pytest.raises(ValueError, match="unknown eigh method"):
        eigh_givens(jnp.eye(4), method="householder")


# ---------------------------------------------------------------- svd ----

@pytest.mark.parametrize("shape", [(48, 32), (32, 48), (40, 40), (33, 20)])
def test_svd_oracle_f32(shape):
    rng = np.random.default_rng(sum(shape))
    A = rng.standard_normal(shape).astype(np.float32)
    U, s, Vt = svd_givens(jnp.asarray(A))
    k = min(shape)
    assert U.shape == (shape[0], k) and Vt.shape == (k, shape[1])
    sr = np.linalg.svd(A.astype(np.float64), compute_uv=False)
    scale = sr.max()
    assert np.abs(np.asarray(s) - sr).max() <= 1e-4 * scale
    sn = np.asarray(s)
    assert np.all(sn >= 0) and np.all(np.diff(sn) <= 1e-6)  # descending
    Un, Vn = np.asarray(U, np.float64), np.asarray(Vt, np.float64)
    np.testing.assert_allclose(Un.T @ Un, np.eye(k), atol=1e-4)
    np.testing.assert_allclose(Vn @ Vn.T, np.eye(k), atol=1e-4)
    rec = np.abs(Un @ np.diag(np.asarray(s, np.float64)) @ Vn - A).max()
    assert rec <= 1e-4 * scale


def test_svd_oracle_f64():
    with compat.enable_x64():
        rng = np.random.default_rng(2)
        A = rng.standard_normal((40, 28))
        U, s, Vt = svd_givens(jnp.asarray(A))
        sr = np.linalg.svd(A, compute_uv=False)
        scale = sr.max()
        assert np.abs(np.asarray(s) - sr).max() <= 1e-10 * scale
        rec = np.abs(np.asarray(U) @ np.diag(np.asarray(s)) @ np.asarray(Vt)
                     - A).max()
        assert rec <= 1e-10 * scale


def test_svd_full_matrices():
    rng = np.random.default_rng(4)
    A = jnp.asarray(rng.standard_normal((12, 7)), jnp.float32)
    U, s, Vt = svd_givens(A, full_matrices=True)
    assert U.shape == (12, 12)
    Un = np.asarray(U, np.float64)
    np.testing.assert_allclose(Un.T @ Un, np.eye(12), atol=1e-4)


def test_svd_exactly_zero_diagonal_entries():
    """Zero columns/rows (routine in compressed gradients) must not stall
    the implicit sweep — regression test for the d[lo]==0 stall."""
    import warnings

    with warnings.catch_warnings():
        warnings.simplefilter("error")  # unconverged would warn -> fail
        A = jnp.asarray([[0.0, 1.0], [0.0, 1.0]], jnp.float32)
        U, s, Vt = svd_givens(A)
        np.testing.assert_allclose(np.asarray(s), [np.sqrt(2.0), 0.0],
                                   atol=1e-6)
        rec = np.asarray(U, np.float64) @ np.diag(np.asarray(s, np.float64)) \
            @ np.asarray(Vt, np.float64)
        np.testing.assert_allclose(rec, np.asarray(A), atol=1e-6)
        rng = np.random.default_rng(0)
        B = rng.standard_normal((6, 4)).astype(np.float32)
        B[:, 2] = 0.0
        _, s2, _ = svd_givens(jnp.asarray(B))
        sr = np.linalg.svd(B.astype(np.float64), compute_uv=False)
        np.testing.assert_allclose(np.asarray(s2), sr, atol=1e-5)


def test_truncated_sweep_budget_warns():
    H = _sym(12, seed=13)
    with pytest.warns(RuntimeWarning, match="sweep budget"):
        eigh_givens(jnp.asarray(H), method="qr", max_sweeps=2)


def test_bidiagonalize_records_factors():
    """Replayed left/right recordings reproduce U^T A V = B exactly."""
    rng = np.random.default_rng(6)
    m, n = 14, 9
    A = rng.standard_normal((m, n))
    bd = bidiagonalize(A)
    U = rot_sequence_numpy(np.eye(m), bd.cos_left, bd.sin_left)
    V = rot_sequence_numpy(np.eye(n), bd.cos_right, bd.sin_right)
    B = U.T @ A @ V
    ref = np.zeros((m, n))
    ref[:n, :n] = np.diag(bd.diag) + np.diag(bd.superdiag, 1)
    np.testing.assert_allclose(B, ref, atol=1e-12 * (m + n))


def test_bidiag_qr_diagonalizes():
    rng = np.random.default_rng(8)
    n = 12
    A = rng.standard_normal((n, n))
    bd = bidiagonalize(A)
    qr = bidiag_qr(bd.diag, bd.superdiag)
    assert qr.converged
    B = np.diag(bd.diag) + np.diag(bd.superdiag, 1)
    L = rot_sequence_numpy(np.eye(n), qr.cos_left, qr.sin_left)
    R = rot_sequence_numpy(np.eye(n), qr.cos_right, qr.sin_right)
    np.testing.assert_allclose(L.T @ B @ R, np.diag(qr.values),
                               atol=1e-11 * n * np.abs(bd.diag).max())


# ------------------------------------------------------ delayed buffer ----

@pytest.mark.parametrize("method", ["unoptimized", "wavefront", "blocked",
                                    "accumulated"])
def test_delayed_flush_equivalent_bitwise(method):
    """Delayed (k_delay-batched) application == eager, bit-for-bit.

    k_delay is a multiple of the band depth k_b, so chunked calls hit
    the same band boundaries as one whole-sequence call; identity
    padding of the final partial flush is an exact no-op.
    """
    rng = np.random.default_rng(0)
    n, K = 24, 40  # 40 = 2.5 flushes: exercises the padded partial flush
    M = jnp.asarray(rng.standard_normal((10, n)), jnp.float32)
    seq = random_sequence(jax.random.key(0), n, K)
    buf = DelayedRotationBuffer(M, k_delay=16, method=method)
    buf.push_sequence(np.asarray(seq.cos), np.asarray(seq.sin))
    delayed = np.asarray(buf.value)
    assert buf.flushes == 3 and buf.waves_pushed == K
    eager = np.asarray(apply_rotation_sequence(M, seq.cos, seq.sin,
                                               method=method))
    np.testing.assert_array_equal(delayed, eager)


def test_delayed_flush_auto_matches_oracle():
    rng = np.random.default_rng(1)
    n, K = 17, 23
    M = jnp.asarray(rng.standard_normal((8, n)), jnp.float32)
    seq = random_sequence(jax.random.key(2), n, K)
    buf = DelayedRotationBuffer(M, k_delay=8, method="auto")
    buf.push_sequence(np.asarray(seq.cos), np.asarray(seq.sin))
    ref = rot_sequence_numpy(np.asarray(M), np.asarray(seq.cos),
                             np.asarray(seq.sin))
    np.testing.assert_allclose(np.asarray(buf.value, np.float64), ref,
                               atol=5e-5, rtol=1e-4)


def test_delayed_buffer_validates_wave_shape():
    buf = DelayedRotationBuffer(jnp.eye(5), k_delay=4)
    with pytest.raises(ValueError, match="planes"):
        buf.push(np.ones(7), np.zeros(7))


# ------------------------------------------------- persisted plan cache ----

def test_plan_cache_persistence_roundtrip(tmp_path, monkeypatch):
    path = tmp_path / "plans.json"
    monkeypatch.setenv("REPRO_PLAN_CACHE", str(path))
    registry.clear_plan_cache()
    try:
        plan = registry.select_plan(16, 48, 6, platform="cpu",
                                    autotune=True, autotune_top=2)
        assert plan.source == "measured"
        assert path.exists()  # write-through on measure
        registry.clear_plan_cache()
        assert registry.load_plan_cache() == 1
        again = registry.select_plan(16, 48, 6, platform="cpu",
                                     autotune=True)  # no re-measure
        assert again.source == "persisted"
        assert (again.method, again.n_b, again.k_b) == \
            (plan.method, plan.n_b, plan.k_b)
    finally:
        registry.clear_plan_cache()


def test_plan_cache_persistence_disabled(monkeypatch):
    monkeypatch.setenv("REPRO_PLAN_CACHE", "off")
    assert registry.plan_cache_path() is None
    assert registry.save_plan_cache() is None
    assert registry.load_plan_cache() == 0


def test_plan_cache_ignores_corrupt_file(tmp_path, monkeypatch):
    path = tmp_path / "plans.json"
    path.write_text("{not json")
    monkeypatch.setenv("REPRO_PLAN_CACHE", str(path))
    assert registry.load_plan_cache() == 0


def test_plan_cache_save_merges_foreign_entries(tmp_path, monkeypatch):
    """A writer must not clobber plans another process persisted."""
    path = tmp_path / "plans.json"
    monkeypatch.setenv("REPRO_PLAN_CACHE", str(path))
    registry.clear_plan_cache()
    try:
        key_a = (8, 8, 4, "float32", "cpu", False, False)
        registry._PLAN_CACHE[key_a] = registry.Plan(
            method="blocked", n_b=8, k_b=4, est_seconds=1e-6,
            source="measured")
        registry.save_plan_cache()
        # "another process": different key, same file
        registry.clear_plan_cache()
        key_b = (16, 16, 8, "float32", "cpu", False, False)
        registry._PLAN_CACHE[key_b] = registry.Plan(
            method="accumulated", n_b=16, k_b=16, est_seconds=2e-6,
            source="measured")
        registry.save_plan_cache()
        registry.clear_plan_cache()
        assert registry.load_plan_cache() == 2  # both survive
        assert {k for k in registry._PLAN_CACHE} == {key_a, key_b}
    finally:
        registry.clear_plan_cache()


def test_plan_cache_rejects_other_jax_version(tmp_path, monkeypatch):
    path = tmp_path / "plans.json"
    monkeypatch.setenv("REPRO_PLAN_CACHE", str(path))
    registry.clear_plan_cache()
    try:
        key = (8, 8, 4, "float32", "cpu", False, False)
        registry._PLAN_CACHE[key] = registry.Plan(
            method="blocked", n_b=8, k_b=4, est_seconds=1e-6,
            source="measured")
        assert registry.save_plan_cache() == str(path)
        import json
        payload = json.loads(path.read_text())
        payload["jax"] = "0.0.1"
        path.write_text(json.dumps(payload))
        registry.clear_plan_cache()
        assert registry.load_plan_cache() == 0
    finally:
        registry.clear_plan_cache()


# ----------------------------------------------------------- consumers ----

def test_soap_qr_solver_minimizes_quadratic():
    from repro.optim import SoapGivens

    opt = SoapGivens(lr=0.1, update_freq=3, solver="qr")
    target = jnp.asarray(np.random.default_rng(0).standard_normal((8, 8)),
                         jnp.float32)
    params = {"w": jnp.zeros((8, 8))}
    st = opt.init(params)

    def loss(p):
        return jnp.sum(jnp.square(p["w"] - target))

    for _ in range(60):
        g = jax.grad(loss)(params)
        params, st, _ = opt.update(g, st, params)
    assert float(loss(params)) < 0.1 * float(jnp.sum(jnp.square(target)))


def test_soap_qr_solver_rejects_jit():
    from repro.optim import SoapGivens

    opt = SoapGivens(lr=0.1, update_freq=1, solver="qr")
    params = {"w": jnp.zeros((8, 8))}
    st = opt.init(params)
    g = {"w": jnp.ones((8, 8))}
    with pytest.raises(RuntimeError, match="cannot run under jit"):
        jax.jit(lambda g, s, p: opt.update(g, s, p))(g, st, params)
