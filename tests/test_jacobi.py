"""Jacobi eigensolver (rotation-sequence consumer) correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: deterministic seeded fallback
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import jacobi_apply_basis, jacobi_eigh


@pytest.mark.parametrize("n", [4, 16, 33])
@pytest.mark.parametrize("method", ["blocked", "accumulated"])
def test_eigh_and_basis(n, method):
    rng = np.random.default_rng(n)
    X = rng.standard_normal((n, n)).astype(np.float32)
    H = (X + X.T) / 2
    res = jacobi_eigh(jnp.array(H), cycles=8)
    ev = np.sort(np.asarray(res.eigenvalues))
    ref = np.sort(np.linalg.eigvalsh(H.astype(np.float64)))
    np.testing.assert_allclose(ev, ref, atol=1e-4 * n)
    V = np.asarray(jacobi_apply_basis(res, method=method))
    np.testing.assert_allclose(V.T @ V, np.eye(n), atol=1e-5 * n)
    np.testing.assert_allclose(
        V.T @ H @ V, np.diag(np.asarray(res.eigenvalues)), atol=2e-4 * n)


def test_apply_basis_auto_dispatch():
    """Default method='auto' routes through the registry and matches the
    explicitly-dispatched blocked-family result exactly (the sign-carrying
    sequence restricts auto to the blocked family)."""
    n = 16
    rng = np.random.default_rng(2)
    X = rng.standard_normal((n, n)).astype(np.float32)
    H = (X + X.T) / 2
    res = jacobi_eigh(jnp.array(H), cycles=8)
    V_auto = np.asarray(jacobi_apply_basis(res))  # method="auto" default
    V_named = np.asarray(jacobi_apply_basis(res, method="blocked"))
    np.testing.assert_allclose(V_auto, V_named, atol=1e-6)
    np.testing.assert_allclose(V_auto.T @ V_auto, np.eye(n), atol=1e-5 * n)


def test_delayed_sequence_application():
    """G @ V without forming V — the paper's 'delayed sequence' use."""
    n = 12
    rng = np.random.default_rng(1)
    X = rng.standard_normal((n, n)).astype(np.float32)
    H = (X + X.T) / 2
    res = jacobi_eigh(jnp.array(H), cycles=8)
    V = np.asarray(jacobi_apply_basis(res))
    G = rng.standard_normal((5, n)).astype(np.float32)
    GV = np.asarray(jacobi_apply_basis(res, jnp.array(G)))
    np.testing.assert_allclose(GV, G @ V, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(3, 24), seed=st.integers(0, 2**31 - 1))
def test_property_offdiag_shrinks(n, seed):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, n)).astype(np.float32)
    H = (X + X.T) / 2
    res = jacobi_eigh(jnp.array(H), cycles=8)
    off0 = np.linalg.norm(H - np.diag(np.diag(H)))
    assert float(res.off_norm) < max(1e-3, 1e-3 * off0)
