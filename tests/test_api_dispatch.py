"""Backend-registry dispatch: capability records, cost-model plans,
plan caching, autotune, and oracle agreement of every registered method."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import apply_rotation_sequence, random_sequence
from repro.core import registry
from repro.core.ref import rot_sequence_numpy
from repro.core.registry import (clear_plan_cache, eligible_backends,
                                 get_backend, plan_cache_stats, select_plan,
                                 Problem)
from repro.configs import ARCHS, get_config
from repro.configs.rotseq_paper import CONFIG as ROTSEQ_CFG

EXPECTED = {"unoptimized", "wavefront", "blocked", "accumulated",
            "pallas_wave", "pallas_mxu", "rotseq_batched"}

# shared case grid for oracle agreement
CASES = [(5, 8, 3), (12, 17, 6), (9, 33, 4)]


def _problem(m, n, k, seed=0):
    rng = np.random.default_rng(seed)
    A = rng.standard_normal((m, n)).astype(np.float32)
    seq = random_sequence(jax.random.key(seed + 1), n, k)
    return A, seq


def test_all_backends_registered():
    assert set(registry.registered_methods()) == EXPECTED


@pytest.mark.parametrize("m,n,k", CASES)
@pytest.mark.parametrize("method", sorted(EXPECTED))
def test_registered_methods_agree_with_oracle(method, m, n, k):
    A, seq = _problem(m, n, k, seed=m + n + k)
    ref = rot_sequence_numpy(A, seq.cos, seq.sin)
    kw = dict(n_b=8, k_b=4)
    if method.startswith("pallas"):
        kw["m_blk"] = 8
    out = apply_rotation_sequence(jnp.array(A), seq.cos, seq.sin,
                                  method=method, **kw)
    np.testing.assert_allclose(np.asarray(out, np.float64), ref,
                               atol=5e-5, rtol=1e-4)


def test_unknown_method_raises():
    A, seq = _problem(4, 6, 2)
    with pytest.raises(ValueError, match="unknown method"):
        apply_rotation_sequence(jnp.array(A), seq.cos, seq.sin,
                                method="does_not_exist")
    with pytest.raises(ValueError, match="unknown method"):
        get_backend("also_missing")


def test_capability_records():
    for name in ("pallas_wave", "pallas_mxu"):
        cap = get_backend(name).capability
        assert cap.needs_pallas and cap.platforms == ("tpu",)
    for name in ("unoptimized", "wavefront"):
        cap = get_backend(name).capability
        assert not cap.supports_signs
    for name in ("blocked", "accumulated"):
        cap = get_backend(name).capability
        assert cap.supports_signs and cap.supports_sharding


def test_signs_filter_eligibility():
    p = Problem(m=8, n=16, k=4, signs=True, platform="cpu")
    names = {s.name for s in eligible_backends(p)}
    assert "unoptimized" not in names and "wavefront" not in names
    assert {"blocked", "accumulated"} <= names


def test_signs_rejected_on_unblocked_methods():
    A, seq = _problem(4, 6, 2)
    G = jnp.full(seq.cos.shape, -1.0)
    for method in ("unoptimized", "wavefront"):
        with pytest.raises(ValueError, match="per-entry signs"):
            apply_rotation_sequence(jnp.array(A), seq.cos, seq.sin,
                                    method=method, G=G)


def test_sharded_plans_exclude_non_shardable_backends():
    """Even on TPU, sharded auto-plans must stay shard_map-traceable."""
    clear_plan_cache()
    for (m, n, k) in [(8, 32, 4), (1024, 4096, 64)]:
        plan = select_plan(m, n, k, platform="tpu", sharded=True)
        assert get_backend(plan.method).capability.supports_sharding, plan
        assert not plan.method.startswith("pallas"), plan


def test_degenerate_shapes_are_identity_under_auto():
    plan = select_plan(4, 1, 3)  # n=1: zero rotation sites
    assert plan.method in registry.registered_methods()
    A, _ = _problem(4, 2, 1)
    out = apply_rotation_sequence(jnp.array(A[:, :1]),
                                  jnp.zeros((0, 1)), jnp.zeros((0, 1)),
                                  method="auto")
    np.testing.assert_array_equal(np.asarray(out), A[:, :1])


def test_float16_eligible_for_auto():
    p = Problem(m=8, n=16, k=4, dtype="float16", platform="cpu")
    assert eligible_backends(p), "float16 must have eligible backends"


def test_auto_plan_for_all_configs():
    """method='auto' must produce a valid, capability-legal plan for the
    paper workload config and every LM architecture config."""
    clear_plan_cache()
    shapes = [(n, n, ROTSEQ_CFG.k) for n in ROTSEQ_CFG.sizes]
    # SOAP-Givens-style basis application on each arch's d_model
    shapes += [(get_config(a).d_model, get_config(a).d_model, 16)
               for a in ARCHS]
    for platform in ("cpu", "gpu", "tpu"):
        for (m, n, k) in shapes:
            plan = select_plan(m, n, k, platform=platform)
            assert plan.method in registry.registered_methods()
            spec = get_backend(plan.method)
            assert platform in spec.capability.platforms
            if platform != "tpu":
                assert not plan.method.startswith("pallas"), plan
            if plan.n_b is not None:
                assert plan.n_b >= 1 and plan.k_b >= 1


def test_plan_cache_hits_on_second_call():
    clear_plan_cache()
    p1 = select_plan(64, 256, 12, platform="cpu")
    before = plan_cache_stats()
    p2 = select_plan(64, 256, 12, platform="cpu")
    after = plan_cache_stats()
    assert p1 == p2
    assert after["hits"] == before["hits"] + 1
    assert after["misses"] == before["misses"]


def test_auto_matches_oracle():
    A, seq = _problem(10, 24, 5, seed=7)
    ref = rot_sequence_numpy(A, seq.cos, seq.sin)
    out = apply_rotation_sequence(jnp.array(A), seq.cos, seq.sin,
                                  method="auto")
    np.testing.assert_allclose(np.asarray(out, np.float64), ref,
                               atol=5e-5, rtol=1e-4)


def test_auto_with_signs_uses_sign_capable_backend():
    """G-carrying problems must dispatch to a blocked-family backend."""
    m, n, k = 6, 12, 4
    A, seq = _problem(m, n, k, seed=3)
    G = jnp.where(jax.random.bernoulli(jax.random.key(4), 0.5,
                                       seq.cos.shape), 1.0, -1.0)
    out = apply_rotation_sequence(jnp.array(A), seq.cos, seq.sin,
                                  method="auto", G=G)
    # oracle: elementwise unified update
    Anp = np.array(A, np.float64)
    C = np.asarray(seq.cos, np.float64)
    S = np.asarray(seq.sin, np.float64)
    Gn = np.asarray(G, np.float64)
    for p in range(k):
        for j in range(n - 1):
            x, y = Anp[:, j].copy(), Anp[:, j + 1].copy()
            Anp[:, j] = C[j, p] * x + S[j, p] * y
            Anp[:, j + 1] = Gn[j, p] * (S[j, p] * x - C[j, p] * y)
    np.testing.assert_allclose(np.asarray(out, np.float64), Anp,
                               atol=5e-5, rtol=1e-4)


def test_explicit_tiles_override_auto_plan():
    A, seq = _problem(9, 20, 4, seed=11)
    ref = rot_sequence_numpy(A, seq.cos, seq.sin)
    out = apply_rotation_sequence(jnp.array(A), seq.cos, seq.sin,
                                  method="auto", n_b=8, k_b=2)
    np.testing.assert_allclose(np.asarray(out, np.float64), ref,
                               atol=5e-5, rtol=1e-4)


def test_cross_shape_plan_interpolation():
    """An unmeasured shape borrows the nearest measured plan of its
    eligibility class before the cost model is re-run."""
    clear_plan_cache()
    donor = select_plan(16, 48, 6, platform="cpu", autotune=True,
                        autotune_top=2)
    assert donor.source == "measured"
    # nearby unmeasured shape: borrowed, not model-ranked
    borrowed = select_plan(20, 64, 8, platform="cpu")
    assert borrowed.source == "interpolated"
    assert borrowed.method == donor.method
    assert (borrowed.n_b, borrowed.k_b) == (donor.n_b, donor.k_b)
    # cached under its own key afterwards
    stats0 = plan_cache_stats()["hits"]
    assert select_plan(20, 64, 8, platform="cpu") == borrowed
    assert plan_cache_stats()["hits"] == stats0 + 1
    # a different eligibility class (signs) must NOT borrow it
    other = select_plan(20, 64, 8, platform="cpu", signs=True)
    assert other.source == "model"
    # nearest-donor selection: seed a second, farther measured plan and
    # check log-distance picks the close one
    clear_plan_cache()
    import dataclasses as _dc
    near_key = (16, 48, 6, "float32", "cpu", False, False)
    far_key = (1024, 4096, 128, "float32", "cpu", False, False)
    registry._PLAN_CACHE[near_key] = _dc.replace(donor, source="measured")
    registry._PLAN_CACHE[far_key] = _dc.replace(
        donor, method="accumulated", n_b=96, k_b=96, source="measured")
    pick = select_plan(20, 64, 8, platform="cpu")
    assert pick.source == "interpolated"
    assert pick.method == donor.method and pick.n_b == donor.n_b
    # ... but a shape beyond the log-distance cap must NOT borrow: the
    # cost model is the better guess across regime changes
    far_pick = select_plan(16384, 16384, 2048, platform="cpu")
    assert far_pick.source == "model"
    # autotune=True ignores the borrowed entry and measures for real
    measured = select_plan(20, 64, 8, platform="cpu", autotune=True,
                           autotune_top=1)
    assert measured.source == "measured"
    clear_plan_cache()


def test_autotune_measures_and_caches():
    clear_plan_cache()
    plan = select_plan(16, 48, 6, platform="cpu", autotune=True,
                       autotune_top=2)
    assert plan.source == "measured"
    assert plan.est_seconds > 0
    again = select_plan(16, 48, 6, platform="cpu", autotune=True,
                        autotune_top=2)
    assert again == plan
    assert plan_cache_stats()["hits"] >= 1
    # a measured plan is reused by plain (non-autotune) auto calls ...
    assert select_plan(16, 48, 6, platform="cpu") == plan
    # ... and autotune=True upgrades an existing model-ranked entry
    clear_plan_cache()
    modeled = select_plan(16, 48, 6, platform="cpu")
    assert modeled.source == "model"
    measured = select_plan(16, 48, 6, platform="cpu", autotune=True,
                           autotune_top=2)
    assert measured.source == "measured"
