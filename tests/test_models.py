"""Per-architecture smoke tests: reduced configs, forward + train step on
CPU, output shapes + finiteness; decode/forward consistency per family."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import build_model
from repro.optim import AdamW
from repro.train import make_train_step


def _batch(cfg, B=2, S=16):
    key = jax.random.key(0)
    if cfg.is_encdec:
        D = min(cfg.dec_len, S)
        return {
            "frames": jax.random.normal(key, (B, S, cfg.d_model)),
            "dec_tokens": jax.random.randint(key, (B, D), 0, cfg.vocab),
            "labels": jax.random.randint(key, (B, D), 0, cfg.vocab),
        }
    return {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab),
    }


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    batch = _batch(cfg)
    B = batch["tokens"].shape[0] if "tokens" in batch \
        else batch["frames"].shape[0]

    if cfg.is_encdec:
        logits = model.forward(params, batch["frames"],
                               batch["dec_tokens"], remat=False)
        S_out = batch["dec_tokens"].shape[1]
    else:
        logits = model.forward(params, batch["tokens"], remat=False)
        S_out = batch["tokens"].shape[1]
    assert logits.shape == (B, S_out, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"

    opt = AdamW(lr=1e-3)
    step = jax.jit(make_train_step(model, cfg, opt, remat=False))
    st = opt.init(params)
    p2, st2, metrics = step(params, st, batch)
    assert bool(jnp.isfinite(metrics["loss"])), f"{arch}: non-finite loss"
    # params changed
    d = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), params, p2)
    assert max(jax.tree.leaves(d)) > 0.0


@pytest.mark.parametrize("arch", ["smollm-135m", "gemma3-4b",
                                  "deepseek-v2-lite-16b", "mamba2-370m",
                                  "recurrentgemma-9b", "whisper-large-v3"])
def test_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(2))
    B, S = 2, 12
    key = jax.random.key(3)
    if cfg.is_encdec:
        frames = jax.random.normal(key, (B, 10, cfg.d_model))
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
        full = model.forward(params, frames, toks, remat=False)
        cache = model.init_cache(params, frames, S)
    else:
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
        full = model.forward(params, toks, remat=False)
        cache = model.init_cache(B, S, dtype=jnp.float32)
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, cache, toks[:, t:t + 1])
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               atol=5e-5, rtol=1e-4)


def test_grouped_scan_matches_unrolled_pattern():
    """gemma3-style 5:1 pattern: grouped scan == per-layer semantics.

    The grouped representation must place the global-attention layer at
    slot 5 of every period; verify by checking the groups bookkeeping.
    """
    cfg = get_config("gemma3-4b")
    model = build_model(cfg)
    kinds = [k for (s, c, sk) in model.groups for k in sk * (c // len(sk))]
    assert len(kinds) == cfg.n_layers
    for i, (attn, mlp) in enumerate(kinds):
        expected = "global" if (i % 6) == 5 else "local"
        assert attn == expected, (i, attn)


def test_param_counts_match_published():
    targets = {
        "starcoder2-3b": 3.0e9, "smollm-135m": 1.35e8,
        "llama3-405b": 4.05e11, "gemma3-4b": 3.9e9,
        "recurrentgemma-9b": 9.4e9, "chameleon-34b": 3.4e10,
        "deepseek-v2-lite-16b": 1.57e10, "kimi-k2-1t-a32b": 1.03e12,
        "mamba2-370m": 3.7e8, "whisper-large-v3": 1.54e9,
    }
    for arch, target in targets.items():
        cfg = get_config(arch)
        model = build_model(cfg)
        shapes = jax.eval_shape(lambda k: model.init(k), jax.random.key(0))
        n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))
        assert abs(n - target) / target < 0.08, (arch, n, target)
