"""Deterministic stand-in for ``hypothesis`` when it is not installed.

Implements exactly the subset the test-suite uses — ``@given`` with
keyword ``strategies.integers(lo, hi)`` arguments and
``@settings(max_examples=..., deadline=...)`` — as seeded-random
parameter sweeps.  Draws are deterministic per test (seeded by a CRC of
the test name), so failures reproduce across runs.  With ``hypothesis``
installed (see ``requirements-dev.txt``) the real library is used
instead and adds shrinking + adaptive search; this fallback only keeps
the suite collectable and meaningful without it.
"""
from __future__ import annotations

import functools
import inspect
import zlib
from dataclasses import dataclass

import numpy as np

__all__ = ["given", "settings", "strategies", "st"]

_DEFAULT_EXAMPLES = 20


@dataclass(frozen=True)
class _Integers:
    lo: int
    hi: int

    def draw(self, rng: np.random.Generator) -> int:
        return int(rng.integers(self.lo, self.hi + 1))


class _Strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Integers:
        return _Integers(min_value, max_value)


strategies = st = _Strategies()


def settings(max_examples: int = _DEFAULT_EXAMPLES, **_ignored):
    """Accepts (and mostly ignores) hypothesis settings kwargs."""
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(**strats):
    """Run the test once per seeded draw of the keyword strategies."""
    for name, strat in strats.items():
        if not isinstance(strat, _Integers):
            raise TypeError(
                f"fallback strategy for {name!r} must be st.integers(...)"
            )

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_fallback_max_examples",
                        getattr(fn, "_fallback_max_examples",
                                _DEFAULT_EXAMPLES))
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = np.random.default_rng(seed)
            for i in range(n):
                draw = {k: s.draw(rng) for k, s in strats.items()}
                try:
                    fn(*args, **kwargs, **draw)
                except Exception as e:
                    raise AssertionError(
                        f"{fn.__name__} failed on fallback example "
                        f"{i + 1}/{n}: {draw}"
                    ) from e

        # hide the strategy params from pytest's fixture resolution
        wrapper.__dict__.pop("__wrapped__", None)
        wrapper.__signature__ = inspect.Signature()
        return wrapper
    return deco
