"""Pallas kernels: interpret=True vs pure-jnp oracles, shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import random_sequence
from repro.core.ref import rot_sequence_numpy
from repro.kernels.rope.ops import apply_rope, rope_tables
from repro.kernels.rotseq.ops import rot_sequence_wave
from repro.kernels.rotseq.ref import rot_sequence_ref
from repro.kernels.rotseq_mxu.ops import rot_sequence_mxu
from repro.kernels.rotseq_mxu.ref import rot_sequence_mxu_ref

SHAPES = [(4, 6, 2, 4, 2, 4), (16, 33, 7, 8, 3, 8), (9, 14, 9, 8, 8, 16),
          (32, 64, 5, 16, 4, 8), (8, 20, 3, 64, 16, 256)]


@pytest.mark.parametrize("m,n,k,n_b,k_b,m_blk", SHAPES)
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 5e-5),
                                       (jnp.bfloat16, 5e-2)])
def test_wave_kernel_vs_oracle(m, n, k, n_b, k_b, m_blk, dtype, tol):
    rng = np.random.default_rng(m * n + k)
    A = jnp.asarray(rng.standard_normal((m, n)), dtype)
    seq = random_sequence(jax.random.key(k), n, k, dtype=dtype)
    ref = rot_sequence_numpy(np.asarray(A, np.float64),
                             np.asarray(seq.cos, np.float64),
                             np.asarray(seq.sin, np.float64))
    out = rot_sequence_wave(A, seq.cos, seq.sin, n_b=n_b, k_b=k_b,
                            m_blk=m_blk)
    np.testing.assert_allclose(np.asarray(out, np.float64), ref,
                               atol=tol * max(1, k), rtol=tol)


@pytest.mark.parametrize("m,n,k,n_b,k_b,m_blk", SHAPES)
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 5e-5),
                                       (jnp.bfloat16, 7e-2)])
def test_mxu_kernel_vs_oracle(m, n, k, n_b, k_b, m_blk, dtype, tol):
    rng = np.random.default_rng(m + n * k)
    A = jnp.asarray(rng.standard_normal((m, n)), dtype)
    seq = random_sequence(jax.random.key(k + 1), n, k, dtype=dtype)
    ref = rot_sequence_numpy(np.asarray(A, np.float64),
                             np.asarray(seq.cos, np.float64),
                             np.asarray(seq.sin, np.float64))
    out = rot_sequence_mxu(A, seq.cos, seq.sin, n_b=n_b, k_b=k_b,
                           m_blk=m_blk)
    np.testing.assert_allclose(np.asarray(out, np.float64), ref,
                               atol=tol * max(1, k), rtol=tol)


def test_kernels_match_their_refs():
    """ops vs the ref.py modules shipped beside each kernel."""
    rng = np.random.default_rng(7)
    A = jnp.asarray(rng.standard_normal((12, 26)), jnp.float32)
    seq = random_sequence(jax.random.key(2), 26, 6)
    r1 = rot_sequence_ref(A, seq.cos, seq.sin, n_b=8, k_b=4)
    o1 = rot_sequence_wave(A, seq.cos, seq.sin, n_b=8, k_b=4, m_blk=8)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(r1), atol=3e-5)
    r2 = rot_sequence_mxu_ref(A, seq.cos, seq.sin, n_b=8, k_b=4)
    o2 = rot_sequence_mxu(A, seq.cos, seq.sin, n_b=8, k_b=4, m_blk=8)
    np.testing.assert_allclose(np.asarray(o2), np.asarray(r2), atol=3e-5)


@pytest.mark.parametrize("B,S,Hq,Hk,D", [(2, 16, 4, 2, 8), (1, 256, 2, 1, 16),
                                         (3, 32, 9, 3, 64)])
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-6),
                                       (jnp.bfloat16, 2e-2)])
def test_rope_kernel_vs_ref(B, S, Hq, Hk, D, dtype, tol):
    rng = np.random.default_rng(B * S)
    q = jnp.asarray(rng.standard_normal((B, S, Hq, D)), dtype)
    k = jnp.asarray(rng.standard_normal((B, S, Hk, D)), dtype)
    cos, sin = rope_tables(jnp.arange(S), D, dtype=dtype)
    q1, k1 = apply_rope(q, k, cos, sin, use_kernel=False)
    q2, k2 = apply_rope(q, k, cos, sin, use_kernel=True)
    np.testing.assert_allclose(np.asarray(q1, np.float32),
                               np.asarray(q2, np.float32), atol=tol)
    np.testing.assert_allclose(np.asarray(k1, np.float32),
                               np.asarray(k2, np.float32), atol=tol)
