"""Core rotation-sequence correctness: all appliers vs the numpy oracle,
plus hypothesis property tests on the library's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: deterministic seeded fallback
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import apply_rotation_sequence, random_sequence, \
    sequence_to_dense
from repro.core.ref import reflector_sequence_numpy, rot_sequence_numpy

METHODS = ["unoptimized", "wavefront", "blocked", "accumulated",
           "pallas_wave", "pallas_mxu"]


def _kw(method, n_b=8, k_b=4):
    kw = dict(n_b=n_b, k_b=k_b)
    if method.startswith("pallas"):
        kw["m_blk"] = 8
    return kw


@pytest.mark.parametrize("method", METHODS)
@pytest.mark.parametrize("m,n,k", [(7, 9, 4), (16, 33, 7), (3, 2, 1),
                                   (12, 50, 13)])
def test_method_matches_oracle(method, m, n, k):
    rng = np.random.default_rng(m * n * k)
    A = rng.standard_normal((m, n)).astype(np.float32)
    seq = random_sequence(jax.random.key(m + n + k), n, k)
    ref = rot_sequence_numpy(A, seq.cos, seq.sin)
    out = apply_rotation_sequence(jnp.array(A), seq.cos, seq.sin,
                                  method=method, **_kw(method))
    np.testing.assert_allclose(np.asarray(out, np.float64), ref,
                               atol=5e-5, rtol=1e-4)


@pytest.mark.parametrize("method", METHODS)
def test_reflectors_match_oracle(method, m=9, n=17, k=5):
    rng = np.random.default_rng(0)
    A = rng.standard_normal((m, n)).astype(np.float32)
    seq = random_sequence(jax.random.key(3), n, k)
    ref = reflector_sequence_numpy(A, seq.cos, seq.sin)
    out = apply_rotation_sequence(jnp.array(A), seq.cos, seq.sin,
                                  method=method, reflect=True, **_kw(method))
    np.testing.assert_allclose(np.asarray(out, np.float64), ref,
                               atol=5e-5, rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64,
                                   jnp.bfloat16])
@pytest.mark.parametrize("method", ["unoptimized", "wavefront", "blocked",
                                    "rotseq_batched"])
def test_reflector_sign_grid_bit_parity(method, dtype, m=9, n=17, k=5):
    """Acceptance (headline bugfix): sign-grid reflector application is
    bit-identical to the scalar ``reflect=True`` path.  Every backend
    evaluates the canonical ``plane_update`` order with a runtime sign
    array, so each method's scalar-reflect output equals the blocked
    family's ``G = +1`` grid output (the exact pair the ROADMAP flagged
    as divergent in low-order bits — what a signed serve bucket runs
    vs what a lone reflector request runs), per backend and dtype."""
    from repro import compat
    from repro.core.sequence import RotationSequence

    with compat.enable_x64(dtype == jnp.float64):
        rng = np.random.default_rng(0)
        A = jnp.asarray(rng.standard_normal((m, n)), dtype)
        seq = random_sequence(jax.random.key(3), n, k, dtype=dtype)
        refl = RotationSequence(seq.cos, seq.sin, None, True)
        grid = refl.with_signs()
        assert grid.sign is not None
        kw = _kw(method) if method != "rotseq_batched" else {"m_blk": 8}
        out_scalar = refl.plan(like=A, method=method, **kw).apply(A)
        # the sign-grid path signed buckets execute (blocked family +
        # the fused kernel, the sign-capable backends)
        for grid_method, gkw in [("blocked", _kw("blocked")),
                                 ("rotseq_batched", {"m_blk": 8})]:
            out_grid = grid.plan(like=A, method=grid_method,
                                 **gkw).apply(A)
            np.testing.assert_array_equal(np.asarray(out_scalar),
                                          np.asarray(out_grid))


@pytest.mark.parametrize("method", ["blocked", "accumulated"])
def test_mixed_sign_sequences(method, m=6, n=12, k=4):
    """Per-entry rotation/reflector mixing (G array)."""
    rng = np.random.default_rng(1)
    A = rng.standard_normal((m, n)).astype(np.float32)
    seq = random_sequence(jax.random.key(5), n, k)
    G = jnp.where(jax.random.bernoulli(jax.random.key(6), 0.5,
                                       seq.cos.shape), 1.0, -1.0)
    # oracle: elementwise unified update
    Anp = np.array(A, np.float64)
    C = np.asarray(seq.cos, np.float64)
    S = np.asarray(seq.sin, np.float64)
    Gn = np.asarray(G, np.float64)
    for p in range(k):
        for j in range(n - 1):
            x, y = Anp[:, j].copy(), Anp[:, j + 1].copy()
            Anp[:, j] = C[j, p] * x + S[j, p] * y
            Anp[:, j + 1] = Gn[j, p] * (S[j, p] * x - C[j, p] * y)
    out = apply_rotation_sequence(jnp.array(A), seq.cos, seq.sin,
                                  method=method, G=G, n_b=8, k_b=4)
    np.testing.assert_allclose(np.asarray(out, np.float64), Anp,
                               atol=5e-5, rtol=1e-4)


@settings(max_examples=25, deadline=None)
@given(m=st.integers(1, 12), n=st.integers(2, 24), k=st.integers(1, 8),
       n_b=st.integers(2, 10), k_b=st.integers(1, 6),
       seed=st.integers(0, 2**31 - 1))
def test_property_blocked_equals_oracle(m, n, k, n_b, k_b, seed):
    rng = np.random.default_rng(seed)
    A = rng.standard_normal((m, n)).astype(np.float32)
    seq = random_sequence(jax.random.key(seed), n, k)
    ref = rot_sequence_numpy(A, seq.cos, seq.sin)
    out = apply_rotation_sequence(jnp.array(A), seq.cos, seq.sin,
                                  method="blocked", n_b=n_b, k_b=k_b)
    np.testing.assert_allclose(np.asarray(out, np.float64), ref,
                               atol=1e-4, rtol=1e-3)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 24), k=st.integers(1, 10),
       seed=st.integers(0, 2**31 - 1))
def test_property_norm_preservation(n, k, seed):
    """Orthogonal invariant: rotations preserve row norms of A."""
    rng = np.random.default_rng(seed)
    A = rng.standard_normal((5, n)).astype(np.float32)
    seq = random_sequence(jax.random.key(seed), n, k)
    out = apply_rotation_sequence(jnp.array(A), seq.cos, seq.sin,
                                  method="accumulated", n_b=8, k_b=4)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(out), axis=1),
        np.linalg.norm(A, axis=1), rtol=2e-4)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 16), k=st.integers(1, 6),
       seed=st.integers(0, 2**31 - 1))
def test_property_dense_factor_orthogonal(n, k, seed):
    """The accumulated dense factor is orthogonal with det +1.

    Tolerance scales with n*k: the f32 (c, s) pairs satisfy
    c^2 + s^2 = 1 only to ~1e-7 each, and the error compounds per
    applied rotation.
    """
    seq = random_sequence(jax.random.key(seed), n, k)
    Q = sequence_to_dense(seq)
    tol = 5e-7 * n * k + 1e-9
    np.testing.assert_allclose(Q.T @ Q, np.eye(n), atol=tol)
    np.testing.assert_allclose(np.linalg.det(Q), 1.0, atol=tol)


def test_identity_padding_is_noop():
    """k_b much larger than k: padding waves must not change the result."""
    rng = np.random.default_rng(2)
    A = rng.standard_normal((4, 10)).astype(np.float32)
    seq = random_sequence(jax.random.key(9), 10, 2)
    ref = rot_sequence_numpy(A, seq.cos, seq.sin)
    out = apply_rotation_sequence(jnp.array(A), seq.cos, seq.sin,
                                  method="blocked", n_b=4, k_b=16)
    np.testing.assert_allclose(np.asarray(out, np.float64), ref, atol=5e-5)
