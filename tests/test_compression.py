"""Gradient compression (int8 wire format + error feedback)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.compression import (dequantize_after_allreduce,
                                        error_feedback_update,
                                        quantize_for_allreduce, wire_bytes)


def test_wire_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((777,)) * 3, jnp.float32)
    q, s = quantize_for_allreduce(x)
    y = dequantize_after_allreduce(q, s, x.shape)
    err = np.abs(np.asarray(y - x))
    assert err.max() <= float(jnp.abs(x).max()) / 127 + 1e-6


def test_wire_bytes_4x_smaller():
    x = jnp.zeros((1 << 20,), jnp.float32)
    assert wire_bytes(x) < x.size * 4 / 3.8


def test_error_feedback_converges():
    """EF compensates quantization bias: the cumulative applied update
    tracks the cumulative true gradient."""
    rng = np.random.default_rng(1)
    residual = jnp.zeros((512,))
    total_true = np.zeros((512,))
    total_sent = np.zeros((512,))
    for i in range(50):
        g = jnp.asarray(rng.standard_normal((512,)) * 0.01, jnp.float32)
        sent, residual = error_feedback_update(g, residual)
        total_true += np.asarray(g)
        total_sent += np.asarray(sent)
    # residual bounds the cumulative error
    drift = np.abs(total_true - total_sent).max()
    assert drift <= float(jnp.abs(residual).max()) + 1e-6


# --------------------------------------------------------------- low-rank --

def test_lowrank_exact_on_lowrank_input():
    """A rank-r matrix round-trips through the rank-r wire format."""
    from repro.parallel.compression import (compress_lowrank,
                                            decompress_lowrank,
                                            lowrank_wire_bytes)

    rng = np.random.default_rng(3)
    W = rng.standard_normal((24, 4)) @ rng.standard_normal((4, 18))
    W = jnp.asarray(W, jnp.float32)
    P, Q = compress_lowrank(W, 4)
    assert P.shape == (24, 4) and Q.shape == (4, 18)
    np.testing.assert_allclose(np.asarray(decompress_lowrank(P, Q)),
                               np.asarray(W), atol=1e-4)
    assert lowrank_wire_bytes(W.shape, 4) < W.size * 4


def test_lowrank_truncation_is_best_approximation():
    """Truncated svd_givens matches numpy's optimal rank-r error."""
    from repro.parallel.compression import svd_lowrank

    rng = np.random.default_rng(5)
    W = rng.standard_normal((20, 15)).astype(np.float32)
    r = 5
    U, s, Vt = svd_lowrank(jnp.asarray(W), r)
    approx = np.asarray(U, np.float64) @ np.diag(np.asarray(s, np.float64)) \
        @ np.asarray(Vt, np.float64)
    sr = np.linalg.svd(W.astype(np.float64), compute_uv=False)
    err = np.linalg.norm(W - approx)
    best = np.linalg.norm(sr[r:])
    assert err <= best * (1 + 1e-3) + 1e-5


def test_lowrank_error_feedback_tracks_gradient():
    from repro.parallel.compression import lowrank_error_feedback

    rng = np.random.default_rng(7)
    residual = jnp.zeros((16, 12))
    total_true = np.zeros((16, 12))
    total_sent = np.zeros((16, 12))
    for _ in range(10):
        g = jnp.asarray(rng.standard_normal((16, 12)) * 0.1, jnp.float32)
        sent, residual = lowrank_error_feedback(g, residual, rank=3)
        total_true += np.asarray(g)
        total_sent += np.asarray(sent)
    drift = np.abs(total_true - total_sent).max()
    assert drift <= float(jnp.abs(residual).max()) + 1e-5
