"""Gradient compression (int8 wire format + error feedback)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.compression import (dequantize_after_allreduce,
                                        error_feedback_update,
                                        quantize_for_allreduce, wire_bytes)


def test_wire_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((777,)) * 3, jnp.float32)
    q, s = quantize_for_allreduce(x)
    y = dequantize_after_allreduce(q, s, x.shape)
    err = np.abs(np.asarray(y - x))
    assert err.max() <= float(jnp.abs(x).max()) / 127 + 1e-6


def test_wire_bytes_4x_smaller():
    x = jnp.zeros((1 << 20,), jnp.float32)
    assert wire_bytes(x) < x.size * 4 / 3.8


def test_error_feedback_converges():
    """EF compensates quantization bias: the cumulative applied update
    tracks the cumulative true gradient."""
    rng = np.random.default_rng(1)
    residual = jnp.zeros((512,))
    total_true = np.zeros((512,))
    total_sent = np.zeros((512,))
    for i in range(50):
        g = jnp.asarray(rng.standard_normal((512,)) * 0.01, jnp.float32)
        sent, residual = error_feedback_update(g, residual)
        total_true += np.asarray(g)
        total_sent += np.asarray(sent)
    # residual bounds the cumulative error
    drift = np.abs(total_true - total_sent).max()
    assert drift <= float(jnp.abs(residual).max()) + 1e-6
