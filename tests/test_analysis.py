"""Tests for the repro.analysis static invariant analyzer.

Fixture-driven: every file under ``tests/analysis_fixtures/`` carries
``# expect: RAxxx`` markers; each rule's violations must match its
fixture's marked (line, rule-id) set exactly — ids *and* line numbers.
Plus: the grep-false-negative regression (seq-gate semantics vs RA201),
suppression, baseline workflow, the mtime cache, the CLI, and the
whole-tree zero-violation gate.
"""
import json
import os
import re
import subprocess
import sys

import pytest

from repro.analysis import (all_rules, analyze_file, analyze_paths,
                            baseline_key, load_baseline, rules_matching,
                            write_baseline)
from repro.analysis.engine import ModuleInfo, default_roots, repo_root

FIXTURES = os.path.join(os.path.dirname(__file__), "analysis_fixtures")

_EXPECT_RE = re.compile(r"#\s*expect:\s*(RA\d+)")


def expected_marks(path):
    """(line, rule-id) pairs from ``# expect:`` markers in a fixture."""
    out = set()
    with open(path) as f:
        for lineno, line in enumerate(f, start=1):
            for m in _EXPECT_RE.finditer(line):
                out.add((lineno, m.group(1)))
    return out


def fixture(name):
    return os.path.join(FIXTURES, name)


FIXTURE_FILES = sorted(
    fn for fn in os.listdir(FIXTURES)
    if fn.endswith(".py")
)


# --------------------------------------------------------------------------
# per-fixture: violations == expect markers, ids and line numbers
# --------------------------------------------------------------------------

@pytest.mark.parametrize("name", FIXTURE_FILES)
def test_fixture_matches_expect_markers(name):
    path = fixture(name)
    got = {(v.line, v.rule)
           for v in analyze_file(path, all_rules(), explicit=True)}
    assert got == expected_marks(path), (
        f"{name}: analyzer reported {sorted(got)}, "
        f"markers say {sorted(expected_marks(path))}")


def test_every_rule_has_a_failing_fixture():
    covered = set()
    for name in FIXTURE_FILES:
        covered |= {rule for _, rule in expected_marks(fixture(name))}
    all_ids = {r.id for r in all_rules()}
    assert all_ids <= covered, f"rules without fixtures: {all_ids - covered}"


def test_clean_and_suppressed_fixtures_are_clean():
    for name in ("clean_module.py", "suppressed.py"):
        vs = analyze_file(fixture(name), all_rules(), explicit=True)
        assert vs == [], [v.format() for v in vs]


# --------------------------------------------------------------------------
# the grep false negative (satellite: seq-gate regression)
# --------------------------------------------------------------------------

def test_grep_misses_aliased_import_but_ra201_catches_it():
    """The exact seq-gate regex finds nothing in the aliased fixture."""
    path = fixture("ra201_aliased_import.py")
    with open(path) as f:
        source = f.read()
    # the old Makefile seq-gate pattern, verbatim
    assert not re.search(r"apply_rotation_sequence\s*\(", source)
    got = {v.rule for v in analyze_file(path, rules_matching(["RA201"]),
                                        explicit=True)}
    assert got == {"RA201"}


def test_ra201_resolves_alias_to_both_import_and_call():
    path = fixture("ra201_aliased_import.py")
    vs = analyze_file(path, rules_matching(["RA201"]), explicit=True)
    assert len(vs) == 2  # the import line and the call line


# --------------------------------------------------------------------------
# scoping and engine mechanics
# --------------------------------------------------------------------------

def test_fixture_as_pragma_sets_logical_module():
    mi = ModuleInfo(fixture("ra203_layer_bypass.py"),
                    open(fixture("ra203_layer_bypass.py")).read(),
                    "src/repro/eig/bad_backend_pin.py")
    assert mi.module == "repro.eig.bad_backend_pin"


def test_fixtures_are_skipped_in_tree_walks():
    vs = analyze_paths([FIXTURES], all_rules(), use_cache=False)
    assert vs == []


def test_rules_matching_selects_families():
    assert {r.id for r in rules_matching(["RA2"])} == \
        {"RA201", "RA202", "RA203", "RA204", "RA205", "RA206"}
    assert [r.id for r in rules_matching(["RA301"])] == ["RA301"]
    assert rules_matching(["RA9"]) == []


def test_layer_scoped_rules_ignore_test_modules(tmp_path):
    # same offending code, but logically under tests/: RA2 is
    # library-scoped, so this must be clean
    p = tmp_path / "probe.py"
    p.write_text(
        "# repro-lint: fixture-as=tests/probe.py\n"
        "from repro.kernels.rotseq_batched.ops import "
        "rot_sequence_batched\n")
    assert analyze_file(str(p), rules_matching(["RA202"]),
                        explicit=True) == []


# --------------------------------------------------------------------------
# baseline workflow
# --------------------------------------------------------------------------

def test_baseline_roundtrip_grandfathers_by_content_not_line(tmp_path):
    path = fixture("ra403_budget_copy.py")
    vs = analyze_file(path, rules_matching(["RA403"]), explicit=True)
    assert vs
    bl = tmp_path / "baseline.json"
    write_baseline(vs, str(bl))
    entries = load_baseline(str(bl))
    assert all(baseline_key(v) in entries for v in vs)
    # keys are line-independent: unrelated edits above must not
    # un-baseline an entry
    assert not any(f"::{v.line}::" in k
                   for v in vs for k in entries)


def test_missing_baseline_is_empty():
    assert load_baseline("/nonexistent/baseline.json") == set()


# --------------------------------------------------------------------------
# mtime cache
# --------------------------------------------------------------------------

def test_cache_hits_and_invalidates_on_edit(tmp_path, monkeypatch):
    cache = tmp_path / "lint_cache.json"
    monkeypatch.setenv("REPRO_LINT_CACHE", str(cache))
    target = tmp_path / "mod.py"
    target.write_text(
        "# repro-lint: fixture-as=src/repro/core/tmp_mod.py\n"
        "_SMEM_PANEL_BUDGET = 1\n")
    rules = rules_matching(["RA403"])

    first = analyze_paths([str(target)], rules, explicit_fixtures=True)
    assert [v.rule for v in first] == ["RA403"]
    assert cache.exists()

    # warm hit: same result without re-analysis
    second = analyze_paths([str(target)], rules, explicit_fixtures=True)
    assert [(v.rule, v.line) for v in second] == \
        [(v.rule, v.line) for v in first]

    # edit the file (bump mtime + size): violation disappears
    target.write_text(
        "# repro-lint: fixture-as=src/repro/core/tmp_mod.py\n"
        "from repro.kernels.limits import SMEM_PANEL_BUDGET\n")
    os.utime(target, (os.path.getmtime(target) + 5,) * 2)
    third = analyze_paths([str(target)], rules, explicit_fixtures=True)
    assert third == []


def test_cache_off_env(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_LINT_CACHE", "off")
    vs = analyze_paths([fixture("ra403_budget_copy.py")],
                       rules_matching(["RA403"]), explicit_fixtures=True)
    assert [v.rule for v in vs] == ["RA403", "RA403"]


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------

def _run_cli(*argv, env_extra=None):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(repo_root(), "src"),
               REPRO_LINT_CACHE="off")
    env.update(env_extra or {})
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *argv],
        capture_output=True, text=True, env=env, cwd=repo_root())


def test_cli_fixture_fails_with_exit_1_and_ids():
    res = _run_cli(os.path.join("tests", "analysis_fixtures",
                                "ra201_aliased_import.py"))
    assert res.returncode == 1, res.stdout + res.stderr
    assert "RA201" in res.stdout


def test_cli_json_output():
    res = _run_cli("--json", os.path.join("tests", "analysis_fixtures",
                                          "ra403_budget_copy.py"))
    payload = json.loads(res.stdout)
    assert [v["rule"] for v in payload["violations"]] == \
        ["RA403", "RA403"]


def test_cli_list_rules_names_every_family():
    res = _run_cli("--list-rules")
    assert res.returncode == 0
    for rid in ("RA101", "RA201", "RA301", "RA401", "RA501"):
        assert rid in res.stdout


def test_cli_unknown_rule_selector_errors():
    res = _run_cli("--rules", "RA9")
    assert res.returncode == 2


# --------------------------------------------------------------------------
# the gate itself: whole tree is clean
# --------------------------------------------------------------------------

def test_whole_tree_has_zero_nonbaselined_violations():
    baseline = load_baseline()
    vs = [v for v in analyze_paths(default_roots(), all_rules(),
                                   use_cache=False)
          if baseline_key(v) not in baseline]
    assert vs == [], "\n".join(v.format() for v in vs)
