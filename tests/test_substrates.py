"""Data pipeline, optimizers, checkpointing, train loop, serving."""
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager
from repro.configs.base import ModelConfig
from repro.data import DataConfig, SyntheticLM, make_batch
from repro.models import build_model
from repro.optim import AdamW, SoapGivens, dequantize_q8, quantize_q8, \
    warmup_cosine
from repro.serve import ServeEngine
from repro.train import StragglerMonitor, TrainLoop, make_train_step

TINY = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
                   head_dim=16, dtype="float32")


# ------------------------------------------------------------- data ----

def test_data_determinism_and_host_slicing():
    cfg = DataConfig(vocab=128, seq_len=16, global_batch=8)
    b1 = make_batch(cfg, step=3)
    b2 = make_batch(cfg, step=3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # two hosts regenerate exactly their slice of the global batch
    h0 = make_batch(cfg, step=3, start=0, count=4)
    h1 = make_batch(cfg, step=3, start=4, count=4)
    np.testing.assert_array_equal(
        np.concatenate([h0["tokens"], h1["tokens"]]), b1["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_data_iterator_restart():
    cfg = DataConfig(vocab=64, seq_len=8, global_batch=4)
    it = SyntheticLM(cfg)
    batches = [next(it) for _ in range(5)]
    it2 = SyntheticLM(cfg, start_step=3)
    np.testing.assert_array_equal(next(it2)["tokens"],
                                  batches[3]["tokens"])


# ------------------------------------------------------------ optim ----

def test_q8_roundtrip_error():
    rng = np.random.default_rng(0)
    for shape in [(7,), (300,), (13, 57)]:
        x = jnp.asarray(rng.standard_normal(shape) * 10, jnp.float32)
        q = quantize_q8(x)
        y = dequantize_q8(q, x.shape)
        err = np.abs(np.asarray(y - x))
        bound = np.abs(np.asarray(x)).max() / 127 + 1e-6
        assert err.max() <= bound * 1.01


@pytest.mark.parametrize("opt", [AdamW(lr=0.1), AdamW(lr=0.1, quantized=True),
                                 SoapGivens(lr=0.1, update_freq=3,
                                            jacobi_cycles=3)])
def test_optimizers_minimize_quadratic(opt):
    target = jnp.asarray(np.random.default_rng(0).standard_normal((8, 8)),
                         jnp.float32)
    params = {"w": jnp.zeros((8, 8))}
    st = opt.init(params)

    def loss(p):
        return jnp.sum(jnp.square(p["w"] - target))

    for _ in range(60):
        g = jax.grad(loss)(params)
        params, st, _ = opt.update(g, st, params)
    assert float(loss(params)) < 0.1 * float(jnp.sum(jnp.square(target)))


def test_warmup_cosine_schedule():
    f = warmup_cosine(1.0, warmup=10, total=100)
    assert float(f(jnp.asarray(0))) == 0.0
    assert abs(float(f(jnp.asarray(10))) - 1.0) < 1e-6
    assert float(f(jnp.asarray(100))) <= 0.11


# ------------------------------------------------------------- ckpt ----

def test_ckpt_roundtrip_and_retention():
    tree = {"a": jnp.arange(5), "b": {"c": jnp.ones((2, 3))},
            "q": quantize_q8(jnp.linspace(-1, 1, 300))}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        for s in (1, 2, 3):
            mgr.save(s, tree)
        mgr.wait()
        assert mgr.all_steps() == [2, 3]  # retention
        out = mgr.restore(3, tree)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ckpt_atomicity_tmp_never_visible():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(7, {"x": jnp.zeros((1000, 100))}, blocking=True)
        assert mgr.latest_step() == 7
        assert not any(n.endswith(".tmp") for n in os.listdir(d))


def test_train_resume_bitwise():
    model = build_model(TINY)
    params = model.init(jax.random.key(0))
    opt = AdamW(lr=3e-3)
    step = jax.jit(make_train_step(model, TINY, opt, remat=False))
    dcfg = DataConfig(vocab=256, seq_len=16, global_batch=4)
    with tempfile.TemporaryDirectory() as d:
        l1 = TrainLoop(train_step=step, params=params,
                       opt_state=opt.init(params),
                       data_iter=SyntheticLM(dcfg), ckpt_dir=d,
                       ckpt_every=5)
        l1.run(10)
        l2 = TrainLoop(train_step=step, params=params,
                       opt_state=opt.init(params),
                       data_iter=SyntheticLM(dcfg), ckpt_dir=d)
        start = l2.maybe_restore()
        assert start == 10
        h2 = l2.run(3)
        l3 = TrainLoop(train_step=step, params=params,
                       opt_state=opt.init(params),
                       data_iter=SyntheticLM(dcfg))
        h3 = l3.run(13)
        assert abs(h2["loss"][-1] - h3["loss"][-1]) < 1e-6


# ------------------------------------------------------- straggler ----

def test_straggler_monitor_flags_slow_step():
    mon = StragglerMonitor(threshold=3.0)
    events = []
    mon.on_straggler = lambda s, dt, med: events.append((s, dt, med))
    for i in range(20):
        mon.record(i, 0.1)
    assert mon.record(20, 1.0)  # 10x median
    assert mon.flagged == 1 and events


# ----------------------------------------------------------- serve ----

def test_serve_engine_batched_greedy():
    model = build_model(TINY)
    params = model.init(jax.random.key(4))
    eng = ServeEngine(model, TINY, params, batch=4, max_len=32)
    prompts = [[1, 2, 3], [7, 8], [9]]
    outs = eng.generate(prompts, max_new=5)
    assert len(outs) == 3 and all(len(o) == 5 for o in outs)
    # greedy decode must equal argmax of teacher-forced forward
    p = prompts[0]
    seq = list(p)
    for _ in range(5):
        lg = model.forward(params,
                           jnp.asarray([seq]), remat=False)
        seq.append(int(jnp.argmax(lg[0, -1])))
    assert outs[0] == seq[len(p):]
