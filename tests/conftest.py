"""Suite-wide hermeticity.

The plan-cache persistence layer loads ``~/.cache/repro/plans.json`` at
import time; a developer's locally autotuned plans would otherwise leak
into ``method="auto"`` dispatch assertions (machine-local flakes).  Off
by default here; the persistence tests opt back in via ``monkeypatch``.
"""
import os

os.environ.setdefault("REPRO_PLAN_CACHE", "off")
