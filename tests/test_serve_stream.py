"""repro.serve.stream: the async continuous-batching engine.

The acceptance contract: streamed results are **bit-identical** to
synchronous ``RotationService`` drains (plain/signed/reflector, mixed
shapes) because both run the same ``assemble_batch``/``execute_batch``
code path; each bucket is planned exactly once (warm-startable from the
serialized store); the close policy fires on size *or* age; the
backpressure policies block / fail / shed as selected; weighted
round-robin keeps a cold bucket from starving behind a hot one; and a
graceful shutdown drains every queued request.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import obs
from repro.core.registry import clear_plan_cache, plan_cache_stats
from repro.core.rotations import random_sequence
from repro.core.sequence import RotationSequence
from repro.serve import (Backpressure, DeadlineExceeded, EngineClosed,
                         RotationService, StreamEngine)
from repro.serve.rotations import synthetic_stream

TIMEOUT = 60.0  # generous per-result bound: CI interpret mode is slow


@pytest.fixture(autouse=True)
def _clean():
    clear_plan_cache()
    obs.reset()
    yield
    obs.reset()
    clear_plan_cache()


def _run_stream(engine, requests, **submit_kw):
    tickets = [engine.submit(seq, A, **submit_kw) for seq, A in requests]
    engine.close(drain=True)
    return [t.result(timeout=TIMEOUT) for t in tickets]


# ------------------------------------------------- bitwise acceptance ----

def test_stream_bitwise_equals_sync_mixed_shapes():
    """Streamed == synchronous RotationService, bit for bit, across the
    canonical mixed-shape stream (odd count: partial buckets drain)."""
    requests = synthetic_stream(14, seed=5)
    refs = RotationService(slots=4, store=False).apply_many(requests)
    eng = StreamEngine(slots=4, store=False)
    outs = _run_stream(eng, requests)
    for ref, out in zip(refs, outs):
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))
    assert eng.stats["completed"] == 14


def test_stream_bitwise_signed_and_reflector():
    """Sign-carrying and all-reflector sequences stream bit-identically
    to per-request application (the PR 5 bit-stable normalization)."""
    rng = np.random.default_rng(7)
    m, n, k = 16, 24, 8
    requests = []
    for i in range(9):
        A = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
        seq = random_sequence(jax.random.key(i), n, k)
        if i % 3 == 1:
            sign = jnp.where(
                jax.random.bernoulli(jax.random.key(100 + i), 0.5,
                                     seq.cos.shape), 1.0, -1.0)
            seq = RotationSequence(seq.cos, seq.sin, sign)
        elif i % 3 == 2:
            seq = RotationSequence(seq.cos, seq.sin, None, True)
        requests.append((seq, A))
    refs = [seq.plan(like=A).apply(A) for seq, A in requests]
    sync = RotationService(slots=4, store=False).apply_many(requests)
    outs = _run_stream(StreamEngine(slots=4, store=False), requests)
    for ref, s, out in zip(refs, sync, outs):
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))
        np.testing.assert_array_equal(np.asarray(s), np.asarray(out))


# ----------------------------------------------------- close policies ----

def test_age_close_fires_on_partial_bucket():
    """A partial bucket must not wait for slots to fill: the age policy
    closes it once the oldest request exceeds the bucket target."""
    requests = synthetic_stream(3, shapes=((16, 32, 8),), seed=1)
    eng = StreamEngine(slots=8, store=False, min_age_s=0.001)
    tickets = [eng.submit(seq, A) for seq, A in requests]
    # no close(): the age policy alone must complete the requests
    for t in tickets:
        t.result(timeout=TIMEOUT)
    assert eng.stats["closes_age"] >= 1
    assert eng.stats["closes_size"] == 0
    assert eng.service.stats["padded_slots"] >= 5  # 3 real + 5 identity
    eng.close()


def test_age_target_scales_with_cost_model():
    """The per-bucket age target derives from the §6-modeled batch
    seconds once the bucket is planned, clamped to [min, max]."""
    requests = synthetic_stream(8, shapes=((16, 32, 8),), seed=2)
    eng = StreamEngine(slots=8, store=False, start=False,
                       min_age_s=0.004, max_age_s=0.2, age_factor=8.0)
    key = eng.service._bucket_key(*requests[0])
    assert eng._age_target(key) == eng.min_age_s  # unplanned: floor
    for seq, A in requests:
        eng.submit(seq, A)
    eng.close(drain=True)  # inline drain resolves the bucket plan
    est = eng.service.bucket_plan_estimate(key)
    assert est is not None and est > 0
    assert eng._age_target(key) == min(
        eng.max_age_s, max(eng.min_age_s, eng.age_factor * est))


def test_weighted_round_robin_serves_cold_bucket():
    """Deterministic WRR check on the scheduler policy itself: with a
    hot bucket (3 batches queued) and a cold full bucket, the cold
    bucket is served within ``max_burst`` consecutive hot closes."""
    eng = StreamEngine(slots=4, store=False, start=False, max_burst=2)
    hot = synthetic_stream(12, shapes=((16, 32, 8),), seed=3)
    cold = synthetic_stream(4, shapes=((16, 64, 12),), seed=4)
    for seq, A in hot + cold:
        eng.submit(seq, A)
    order = []
    for _ in range(4):
        with eng._lock:
            key, tickets, reason = eng._close_next_locked()
        order.append((key.n, reason))
    ns = [n for n, _ in order]
    assert ns[0] == 32                       # hot leads (admission order)
    assert 64 in ns[:3]                      # cold served within the burst
    assert all(r == "size" for _, r in order)


def test_fairness_hot_and_cold_end_to_end():
    """A single cold request completes (age close + WRR) while a hot
    bucket keeps the engine saturated — no starvation, no shedding."""
    eng = StreamEngine(slots=4, store=False, min_age_s=0.001)
    hot = synthetic_stream(32, shapes=((16, 32, 8),), seed=6)
    (cold_seq, cold_A), = synthetic_stream(1, shapes=((16, 64, 12),),
                                           seed=7)
    hot_tickets = [eng.submit(seq, A) for seq, A in hot[:16]]
    cold_ticket = eng.submit(cold_seq, cold_A)
    hot_tickets += [eng.submit(seq, A) for seq, A in hot[16:]]
    cold_out = cold_ticket.result(timeout=TIMEOUT)
    eng.close(drain=True)
    ref = cold_seq.plan(like=cold_A).apply(cold_A)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(cold_out))
    assert all(t.result(timeout=TIMEOUT) is not None for t in hot_tickets)
    assert eng.stats["shed"] == 0
    assert eng.stats["completed"] == 33


# ------------------------------------------------ backpressure policies ----

def test_backpressure_fail_policy_rejects():
    eng = StreamEngine(slots=4, store=False, start=False, max_pending=2,
                       backpressure="fail")
    requests = synthetic_stream(3, shapes=((8, 16, 4),), seed=8)
    eng.submit(*requests[0])
    eng.submit(*requests[1])
    with obs.override(True):
        with pytest.raises(Backpressure):
            eng.submit(*requests[2])
        assert obs.snapshot()["counters"]["serve.stream.rejected"] == 1
    assert eng.stats["rejected"] == 1
    eng.close(drain=True)  # the two admitted requests still drain


def test_backpressure_shed_policy_drops_expired():
    """Under pressure the shed policy fails queued past-deadline tickets
    (DeadlineExceeded) to admit new work; unexpired requests survive."""
    eng = StreamEngine(slots=4, store=False, start=False, max_pending=3,
                       backpressure="shed")
    requests = synthetic_stream(5, shapes=((8, 16, 4),), seed=9)
    doomed = [eng.submit(*requests[i], deadline_s=0.0) for i in range(2)]
    keeper = eng.submit(*requests[2])  # no deadline: never shed
    with obs.override(True):
        admitted = eng.submit(*requests[3])  # sheds both expired tickets
        assert obs.snapshot()["counters"]["serve.stream.shed"] == 2
    for t in doomed:
        with pytest.raises(DeadlineExceeded):
            t.result(timeout=1.0)
    assert eng.stats["shed"] == 2
    # budget full again with unsheddable requests -> Backpressure
    eng.submit(*requests[4])
    with pytest.raises(Backpressure):
        eng.submit(*requests[0])
    eng.close(drain=True)
    for t in (keeper, admitted):
        assert t.result(timeout=TIMEOUT) is not None


def test_backpressure_block_policy_waits_for_room():
    """submit() under the block policy stalls until the scheduler frees
    budget — every request is eventually admitted and served."""
    eng = StreamEngine(slots=2, store=False, max_pending=2,
                       backpressure="block", min_age_s=0.001)
    requests = synthetic_stream(7, shapes=((8, 16, 4),), seed=10)
    outs = _run_stream(eng, requests)
    assert len(outs) == 7
    assert eng.stats["submitted"] == 7
    assert eng.stats["completed"] == 7
    assert eng.stats["rejected"] == eng.stats["shed"] == 0


# ------------------------------------------------------------ lifecycle ----

def test_graceful_shutdown_drains_everything():
    """close(drain=True) flushes every queued request — including
    partial buckets — through the normal batch path."""
    requests = synthetic_stream(11, seed=11)  # 3 buckets, none full
    eng = StreamEngine(slots=8, store=False, min_age_s=5.0,
                       max_age_s=10.0)  # age close effectively off
    tickets = [eng.submit(seq, A) for seq, A in requests]
    eng.close(drain=True)
    assert all(t.done() for t in tickets)
    refs = RotationService(slots=8, store=False).apply_many(requests)
    for ref, t in zip(refs, tickets):
        np.testing.assert_array_equal(np.asarray(ref),
                                      np.asarray(t.result()))
    assert eng.stats["closes_drain"] >= 3


def test_close_without_drain_fails_pending_tickets():
    eng = StreamEngine(slots=8, store=False, start=False)
    tickets = [eng.submit(seq, A)
               for seq, A in synthetic_stream(3, shapes=((8, 16, 4),))]
    eng.close(drain=False)
    for t in tickets:
        with pytest.raises(EngineClosed):
            t.result(timeout=1.0)
    with pytest.raises(EngineClosed):
        eng.submit(*synthetic_stream(1, shapes=((8, 16, 4),))[0])


def test_context_manager_drains_on_exit():
    requests = synthetic_stream(5, shapes=((16, 32, 8),), seed=12)
    with StreamEngine(slots=4, store=False) as eng:
        tickets = [eng.submit(seq, A) for seq, A in requests]
    assert all(t.done() for t in tickets)


# ------------------------------------------- plan discipline + metrics ----

def test_plans_resolved_exactly_once_per_bucket():
    """Many batches per bucket, one registry resolution per bucket —
    asserted through the same obs counters the artifacts export."""
    requests = synthetic_stream(24, seed=13)  # 3 buckets x 8 requests
    misses0 = plan_cache_stats()["misses"]
    with obs.override(True):
        obs.reset()
        eng = StreamEngine(slots=4, store=False)
        outs = _run_stream(eng, requests)
        snap = obs.snapshot()
    c = snap["counters"]
    assert len(outs) == 24
    assert c["serve.stream.submitted"] == 24
    assert c["serve.stream.completed"] == 24
    assert c["serve.plans_resolved"] == 3
    assert c["serve.batches"] == 6          # 8 requests / 4 slots, x3
    assert plan_cache_stats()["misses"] - misses0 == 3
    lat = snap["histograms"]["serve.request_latency_seconds"]
    assert lat["count"] == 24
    assert lat["p99"] >= lat["p50"] > 0


def test_stream_warm_start_zero_resolutions(tmp_path):
    """A restarted engine warm-binds every bucket plan from the
    serialized store: zero registry resolutions, identical bits."""
    store = str(tmp_path / "serve_plans.json")
    requests = synthetic_stream(12, seed=14)
    cold = StreamEngine(slots=4, store=store)
    outs = _run_stream(cold, requests)
    assert cold.service.stats["plans_resolved"] == 3

    clear_plan_cache()
    with obs.override(True):
        obs.reset()
        warm = StreamEngine(slots=4, store=store)
        outs2 = _run_stream(warm, requests)
        counters = obs.snapshot()["counters"]
    assert counters.get("serve.plans_resolved", 0) == 0
    assert counters.get("serve.warm_plans", 0) == 3
    assert counters.get("registry.plan_cache.misses", 0) == 0
    for a, b in zip(outs, outs2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_engine_rejects_bad_arguments():
    with pytest.raises(ValueError, match="backpressure"):
        StreamEngine(store=False, backpressure="drop", start=False)
    with pytest.raises(ValueError, match="max_pending"):
        StreamEngine(store=False, max_pending=0, start=False)
    svc = RotationService(slots=2, store=False)
    with pytest.raises(ValueError, match="service_kw"):
        StreamEngine(svc, store=False, start=False)
    eng = StreamEngine(svc, start=False)
    with pytest.raises(ValueError, match="2D"):
        eng.submit(random_sequence(jax.random.key(0), 16, 4),
                   jnp.zeros((2, 8, 16)))
