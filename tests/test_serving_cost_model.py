"""Serving-aware cost model: per-request vs shared-sequence batches.

The acceptance bucket throughout is the serving benchmark's
(m=16, n=32) shape with requests recorded at k_req=5 and padded to
k_pad=8, so ``live_planes = 31*5 = 155`` of ``planes_total = 31*8 =
248``.  The expected backend flips are the ones docs/cost-model.md
derives; they are pure cost-model arithmetic (no autotune, no JAX
dispatch), so the assertions are exact, not statistical.
"""
import dataclasses as _dc

import pytest

from repro.core import registry
from repro.core.registry import (Plan, Problem, clear_plan_cache,
                                 cost_components, select_plan)

M, N, K_PAD, LIVE = 16, 32, 8, 155


def _bucket_plan(batch, shared, platform):
    return select_plan(M, N, K_PAD, dtype="float32", platform=platform,
                       batch=batch, shared_sequence=shared,
                       live_planes=LIVE)


# --------------------------------------------------------------- the flip
def test_batch_one_ignores_the_flag():
    clear_plan_cache()
    a = _bucket_plan(1, True, "tpu")
    clear_plan_cache()
    b = _bucket_plan(1, False, "tpu")
    assert a == b  # normalized to the shared (legacy) key and plan


@pytest.mark.parametrize("batch", [8, 64])
def test_per_request_bucket_flips_to_fused_on_tpu(batch):
    clear_plan_cache()
    shared = _bucket_plan(batch, True, "tpu")
    per_req = _bucket_plan(batch, False, "tpu")
    assert per_req.method == "rotseq_batched", per_req
    assert shared.method != "rotseq_batched", shared


def test_per_request_bucket_never_plans_accumulated_on_cpu():
    clear_plan_cache()
    shared = _bucket_plan(64, True, "cpu")
    per_req = _bucket_plan(64, False, "cpu")
    # shared amortizes the Q_t build and wins on the GEMM path; paying
    # it 64x prices accumulated out entirely for the per-request twin
    assert shared.method == "accumulated", shared
    assert per_req.method != "accumulated", per_req


# --------------------------------------------------- components arithmetic
def _prob(shared):
    return Problem(m=M, n=N, k=K_PAD, dtype="float32", platform="cpu",
                   batch=64, shared_sequence=shared, live_planes=LIVE)


@pytest.mark.parametrize("method,plan", [
    ("accumulated", Plan("accumulated", n_b=32, k_b=8)),
    ("blocked", Plan("blocked", n_b=32, k_b=8)),
    ("rotseq_batched", Plan("rotseq_batched", m_blk=16)),
    ("wavefront", Plan("wavefront")),
])
def test_split_sums_to_totals(method, plan):
    c = cost_components(method, _prob(False), plan)
    assert c["flops"] == c["setup"]["flops"] + c["stream"]["flops"]
    assert c["bytes"] == c["setup"]["bytes"] + c["stream"]["bytes"]


def test_per_request_setup_scales_with_batch():
    plan = Plan("accumulated", n_b=32, k_b=8)
    shared = cost_components("accumulated", _prob(True), plan)
    per_req = cost_components("accumulated", _prob(False), plan)
    # setup x64, stream identical
    assert per_req["setup"]["flops"] == 64 * shared["setup"]["flops"]
    assert per_req["setup"]["bytes"] == 64 * shared["setup"]["bytes"]
    assert per_req["stream"] == shared["stream"]


def test_fused_kernel_price_is_ownership_flat():
    plan = Plan("rotseq_batched", m_blk=16)
    shared = cost_components("rotseq_batched", _prob(True), plan)
    per_req = cost_components("rotseq_batched", _prob(False), plan)
    # the kernel re-reads the panel per batch element either way
    assert shared == per_req


def test_modeled_prediction_cliff_is_at_least_5x():
    # the serve/prediction_cliff bench row, as a unit test: penalty-free
    # setup+stream attribution, accumulated vs fused at batch 64
    acc = cost_components("accumulated", _prob(False),
                          Plan("accumulated", n_b=32, k_b=8))
    fused = cost_components("rotseq_batched", _prob(False),
                            Plan("rotseq_batched", m_blk=16))
    acc_s = acc["setup"]["seconds"] + acc["stream"]["seconds"]
    fused_s = fused["setup"]["seconds"] + fused["stream"]["seconds"]
    assert acc_s / fused_s >= 5.0


# ------------------------------------------------------------- cache keys
def test_per_request_key_is_distinct_and_round_trips(tmp_path):
    clear_plan_cache()
    shared = _bucket_plan(64, True, "tpu")
    per_req = _bucket_plan(64, False, "tpu")
    keys = [k for k in registry._PLAN_CACHE
            if k[:3] == (M, N, K_PAD) and "per_req" in k]
    assert len(keys) == 1
    (pkey,) = keys
    assert registry._PLAN_CACHE[pkey] == per_req
    assert per_req != shared

    # round-trip through the persisted store: the marker must survive
    # JSON (lists -> tuples) and come back as the same class
    registry._PLAN_CACHE[pkey] = _dc.replace(per_req, source="measured")
    path = str(tmp_path / "plans.json")
    assert registry.save_plan_cache(path) == path
    clear_plan_cache()
    assert registry.load_plan_cache(path) >= 1
    restored = registry._PLAN_CACHE[pkey]
    assert restored.method == per_req.method
    assert restored.source == "persisted"
    # and select_plan finds it as a hit, not a re-resolution
    assert _bucket_plan(64, False, "tpu") == restored


def test_batch_one_shares_the_legacy_key():
    clear_plan_cache()
    _bucket_plan(1, False, "tpu")
    assert all("per_req" not in k for k in registry._PLAN_CACHE)


# ---------------------------------------------------------- interpolation
def _seed_measured(batch, shared, method):
    """Plant a measured plan for the acceptance bucket in the cache."""
    prob = Problem(m=M, n=N, k=K_PAD, dtype="float32", platform="tpu",
                   batch=batch, shared_sequence=shared, live_planes=LIVE)
    key = registry._plan_key(prob)
    registry._PLAN_CACHE[key] = Plan(method=method, est_seconds=1e-6,
                                     source="measured")


def test_interpolation_never_crosses_the_ownership_class():
    # a measured per-request plan at distance 0 must NOT be borrowed by
    # the shared twin (and vice versa): the classes differ like dense
    # vs live-annotated
    clear_plan_cache()
    _seed_measured(64, False, "unoptimized")
    shared = _bucket_plan(64, True, "tpu")
    assert shared.source == "model"

    clear_plan_cache()
    _seed_measured(64, True, "accumulated")
    per_req = _bucket_plan(64, False, "tpu")
    assert per_req.source == "model"


def test_interpolation_transfers_within_the_per_request_class():
    clear_plan_cache()
    _seed_measured(64, False, "rotseq_batched")
    near = select_plan(M, N, K_PAD, dtype="float32", platform="tpu",
                       batch=32, shared_sequence=False, live_planes=LIVE)
    assert near.source == "interpolated"
    assert near.method == "rotseq_batched"
