"""The paper's own experimental workload (SS8): apply k = 180 waves of
rotations to square matrices, m = n swept.  Used by the benchmark
harness; not an LM architecture.
"""
from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class RotSeqConfig:
    k: int = 180
    sizes: Tuple[int, ...] = (240, 480, 960, 1920, 3840)
    n_b: int = 64
    k_b: int = 16
    # TPU kernel tiling (the adaptation of the paper's m_r=16, k_r=2)
    mxu_n_b: int = 128
    mxu_k_b: int = 128
    m_blk: int = 256


CONFIG = RotSeqConfig()
