"""SmolLM-135M [hf:HuggingFaceTB/SmolLM-135M] — llama-arch small.

30L, d_model 576, 9 heads (GQA kv=3), d_ff 1536 (SwiGLU), vocab 49152.
~135M params, tied embeddings.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m", family="dense",
    n_layers=30, d_model=576, n_heads=9, n_kv_heads=3, head_dim=64,
    d_ff=1536, vocab=49152, rope_base=10000.0, tie_embeddings=True,
)
