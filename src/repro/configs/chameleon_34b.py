"""Chameleon-34B [arXiv:2405.09818] — early-fusion VLM.

48L, d_model 8192, 64 heads (GQA kv=8), d_ff 22016 (SwiGLU), vocab 65536
(text + VQ-VAE image tokens early-fused into one vocabulary — the image
"frontend" is the discrete VQ tokenizer, so model inputs are plain token
ids; see DESIGN.md).  qk-norm (chameleon's stabilization), untied. ~34B.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=22016, vocab=65536, qk_norm=True, tie_embeddings=False,
    dryrun_grad_accum=4,
)
