"""Kimi-K2 (1T total / 32B active) [arXiv:2501.kimi2; paper-table].

61L, d_model 7168, 64 heads (GQA kv=8 per the assignment table; the
released K2 uses MLA — we follow the assignment), vocab 163840.
MoE: 384 routed experts top-8 + 1 shared, expert d_ff 2048; first layer
dense d_ff 18432.  ~1.03T params.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=18432, vocab=163840,
    n_experts=384, n_shared_experts=1, top_k=8, d_ff_expert=2048,
    first_dense_layers=1, tie_embeddings=False, rope_base=50000.0,
    param_dtype="bfloat16", dryrun_grad_accum=8, dryrun_seq_parallel=True,
    dryrun_q8=True,
)
