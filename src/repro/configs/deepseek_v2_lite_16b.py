"""DeepSeek-V2-Lite (16B total / 2.4B active) [arXiv:2405.04434; hf].

27L, d_model 2048, 16 heads MLA (kv_lora 512, rope_dim 64, nope 128,
v_head 128, no q compression), vocab 102400.  MoE: 64 routed experts
top-6 + 2 shared, expert d_ff 1408; first layer dense d_ff 10944.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=10944, vocab=102400,
    mla=True, kv_lora=512, q_lora=0, qk_nope_dim=128, qk_rope_dim=64,
    v_head_dim=128,
    n_experts=64, n_shared_experts=2, top_k=6, d_ff_expert=1408,
    first_dense_layers=1, tie_embeddings=False,
)
