"""Config system: architecture + shape + run configuration.

Every assigned architecture is a ``ModelConfig`` instance in its own
module (``repro/configs/<arch>.py``) with the exact published numbers.
``ModelConfig.reduced()`` returns a family-preserving scaled-down config
for CPU smoke tests; the full configs are only ever lowered via
ShapeDtypeStructs in the dry-run.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "shape_skips"]


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None    # default: d_model // n_heads

    # positions / attention
    pos_type: str = "rope"            # rope | sinusoidal | none
    rope_base: float = 10000.0
    rope_base_global: Optional[float] = None  # gemma3 global layers
    qk_norm: bool = False
    window: Optional[int] = None      # sliding-window size for local layers
    # layer pattern: (period, global/attn positions within the period)
    # dense default: every layer is the same block.
    pattern_period: int = 1
    pattern_global: Tuple[int, ...] = (0,)  # which slots use global attn
    # hybrid (recurrentgemma): slots NOT in pattern_global are RG-LRU /
    # local-attention per family.

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    first_dense_layers: int = 0
    capacity_factor: float = 1.25

    # MLA (deepseek-style latent attention)
    mla: bool = False
    kv_lora: int = 0
    q_lora: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # SSM (mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_chunk: int = 256
    conv_width: int = 4
    ssm_expand: int = 2

    # RG-LRU (recurrentgemma)
    lru_width: Optional[int] = None

    # encoder-decoder (whisper)
    enc_layers: int = 0
    dec_layers: int = 0
    dec_len: int = 448

    # MLP
    mlp_gated: bool = True            # SwiGLU (llama) vs plain GELU

    # embeddings
    tie_embeddings: bool = True
    emb_scale: bool = False           # gemma-style sqrt(d) embed scaling

    # numerics
    dtype: str = "bfloat16"           # activation/compute dtype
    param_dtype: str = "float32"
    logit_softcap: float = 0.0

    # dry-run / production policy (memory-fit levers per arch)
    dryrun_grad_accum: int = 1
    dryrun_seq_parallel: bool = False
    dryrun_q8: bool = False           # 8-bit Adam states

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(self.n_heads, 1))

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    def reduced(self) -> "ModelConfig":
        """Family-preserving tiny config for CPU smoke tests."""
        def shrink(v, lo, hi):
            return max(lo, min(v, hi))

        kw = dict(
            n_layers=shrink(self.n_layers, 2, 4),
            d_model=64,
            n_heads=4,
            n_kv_heads=shrink(self.n_kv_heads, 1, 2),
            head_dim=16,
            d_ff=128,
            vocab=512,
            window=min(self.window, 16) if self.window else None,
        )
        if self.n_experts:
            kw.update(n_experts=4, n_shared_experts=min(self.n_shared_experts, 1),
                      top_k=2, d_ff_expert=32,
                      first_dense_layers=min(self.first_dense_layers, 1))
        if self.mla:
            kw.update(kv_lora=16, q_lora=0, qk_nope_dim=16, qk_rope_dim=8,
                      v_head_dim=16)
        if self.ssm_state:
            kw.update(ssm_state=16, ssm_head_dim=8, ssm_chunk=8)
        if self.is_encdec:
            kw.update(enc_layers=2, dec_layers=2, dec_len=16)
            kw["n_layers"] = 2
        if self.lru_width is not None:
            kw.update(lru_width=64)
        if self.family == "hybrid":
            kw["n_layers"] = 3 * max(1, self.n_layers // (3 * 13))  # keep R,R,A
        if self.pattern_period > 1:
            kw["n_layers"] = max(self.pattern_period,
                                 kw["n_layers"] - kw["n_layers"] % self.pattern_period)
        kw["dtype"] = "float32"
        kw["param_dtype"] = "float32"
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# archs with pure full attention skip long_500k (O(seq) KV decode is fine
# but the assignment restricts the 500k cell to sub-quadratic families)
_FULL_ATTN = {
    "starcoder2-3b", "smollm-135m", "llama3-405b", "chameleon-34b",
    "deepseek-v2-lite-16b", "kimi-k2-1t-a32b", "whisper-large-v3",
}


def shape_skips(cfg: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    """Return a skip reason or None if the (arch, shape) cell runs."""
    if shape.name == "long_500k" and cfg.name in _FULL_ATTN:
        return "pure full-attention arch: long_500k skipped per assignment"
    return None
