"""Whisper-large-v3 [arXiv:2212.04356] — encoder-decoder, audio.

32 encoder + 32 decoder layers, d_model 1280, 20 heads, d_ff 5120
(plain GELU), vocab 51866.  Conv/mel frontend is a STUB per the
assignment: inputs are precomputed frame embeddings.  ~1.5B params.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="audio",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20, head_dim=64,
    d_ff=5120, vocab=51866, pos_type="none", mlp_gated=False,
    enc_layers=32, dec_layers=32, dec_len=448, tie_embeddings=True,
)
