"""StarCoder2-3B [arXiv:2402.19173; hf:bigcode/starcoder2-3b].

30L, d_model 3072, 24 heads (GQA kv=2), d_ff 12288 (plain GELU MLP),
vocab 49152, RoPE.  ~3.0B params.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2, head_dim=128,
    d_ff=12288, vocab=49152,
    mlp_gated=False, rope_base=999999.0, tie_embeddings=True,
)
