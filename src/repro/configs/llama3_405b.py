"""Llama-3.1-405B [arXiv:2407.21783].

126L, d_model 16384, 128 heads (GQA kv=8, head_dim 128), d_ff 53248
(SwiGLU), vocab 128256, RoPE base 500k, untied embeddings. ~405B params.

Memory policy: at the 256-chip single pod, fp32 Adam is physically
impossible (405B x 12 B/param = 4.9 TB > 256 x 16 GiB), so the dry-run
trains with bf16 params + 8-bit Adam states (+ grad accumulation and
sequence parallelism) — same policy as kimi-k2.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b", family="dense",
    n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8, head_dim=128,
    d_ff=53248, vocab=128256, rope_base=500000.0, tie_embeddings=False,
    param_dtype="bfloat16", dryrun_grad_accum=8, dryrun_seq_parallel=True,
    dryrun_q8=True,
)
