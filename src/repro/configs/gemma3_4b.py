"""Gemma-3-4B [hf:google/gemma-3-4b-pt].

34L, d_model 2560, 8 heads (GQA kv=4, head_dim 256), d_ff 10240 (GeGLU),
vocab 262144.  5:1 local:global pattern, sliding window 1024, RoPE base
10k local / 1M global, qk-norm, sqrt(d) embedding scaling, tied. ~4B.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b", family="dense",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4, head_dim=256,
    d_ff=10240, vocab=262144,
    window=1024, pattern_period=6, pattern_global=(5,),
    rope_base=10000.0, rope_base_global=1000000.0,
    qk_norm=True, emb_scale=True, tie_embeddings=True,
    dryrun_grad_accum=4,
)
