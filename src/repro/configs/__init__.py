"""Config registry: one module per assigned architecture."""
from importlib import import_module

from .base import SHAPES, ModelConfig, ShapeConfig, shape_skips

ARCHS = (
    "starcoder2-3b", "smollm-135m", "llama3-405b", "gemma3-4b",
    "recurrentgemma-9b", "chameleon-34b", "deepseek-v2-lite-16b",
    "kimi-k2-1t-a32b", "mamba2-370m", "whisper-large-v3",
)


def get_config(name: str) -> ModelConfig:
    mod = import_module(f"repro.configs.{name.replace('-', '_')}")
    return mod.CONFIG


__all__ = ["ARCHS", "SHAPES", "ModelConfig", "ShapeConfig", "get_config",
           "shape_skips"]
