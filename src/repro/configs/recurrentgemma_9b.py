"""RecurrentGemma-9B (Griffin) [arXiv:2402.19427].

38 residual blocks, d_model 4096, pattern (R, R, A): RG-LRU recurrent
blocks (lru_width 4096) with local MQA attention every third block
(16 heads, kv=1, head_dim 256, window 2048), d_ff 12288 (GeGLU, gated),
vocab 256000.  ~9B params.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, head_dim=256,
    d_ff=12288, vocab=256000,
    window=2048, lru_width=4096, emb_scale=True, tie_embeddings=True,
)
