"""Mamba2-370M [arXiv:2405.21060] — SSD state-space model.

48L, d_model 1024 (d_inner 2048, 32 heads of dim 64), ssm_state 128,
1 group, chunk 256, vocab 50280.  Attention-free. ~370M params.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=0, n_kv_heads=0, head_dim=1,
    d_ff=0, vocab=50280, pos_type="none",
    ssm_state=128, ssm_head_dim=64, ssm_groups=1, ssm_chunk=256,
    ssm_expand=2, conv_width=4, tie_embeddings=True,
)
