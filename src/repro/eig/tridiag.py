"""Symmetric tridiagonalization recorded as an adjacent-plane rotation
sequence (the front half of the `eigh_givens` QR pipeline).

Classic Givens tridiagonalization zeroes ``H[i, t]`` with a rotation in
the arbitrary plane ``(t+1, i)``; that plane pair cannot be stored in the
paper's ``(n-1, k)`` adjacent-plane layout.  Instead we eliminate each
column *bottom-up with adjacent planes only*: sweep ``t`` zeroes
``H[t+2:, t]`` by rotations in planes ``(j, j+1)`` for
``j = n-2, ..., t+1`` (each zeroing ``H[j+1, t]`` against ``H[j, t]``),
applied two-sidedly so symmetry is preserved.  Sweep ``t`` only touches
planes ``>= t+1``, so previously finished columns stay zero.

**Wave packing.**  The recorded sequence must replay in the paper's
wave-major order (wave ``p`` ascending, ``j`` ascending within a wave),
while the sweeps above run *descending* in ``j``.  Rotations in planes
``|j - j'| >= 2`` act on disjoint column pairs and commute *exactly*
(bitwise — each touches only its own two columns), so any schedule
respecting the dependence order of overlapping planes is equivalent.
Placing sweep ``t``'s plane-``j`` rotation at wave

    ``p(j, t) = (n - 2 - j) + 2 t``

does exactly that: within a sweep, descending ``j`` lands in ascending
waves; across sweeps ``t < t'``, conflicting planes (``|j - j'| <= 1``)
differ in wave by ``(j - j') + 2 (t' - t) >= 1``.  This is the same
pipelined-staircase ("communication-avoiding") packing the blocked
appliers tile into parallelograms: ``K = 2n - 5`` waves total instead of
one wave per rotation, so the registry backends stream the whole
similarity transform in ``ceil(K / k_b)`` passes over the accumulator.

Generation runs host-side in float64 (the coefficients are
data-dependent scalars); the *application* of the recorded sequence — the
flop-dominant part — is delegated to ``apply_rotation_sequence`` via
:class:`repro.eig.delayed.DelayedRotationBuffer`.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

from repro.core.rotations import RotationSequence, plane_update

__all__ = ["TridiagResult", "tridiagonalize", "tridiag_wave_count",
           "host_givens"]


def host_givens(a: float, b: float) -> tuple:
    """Host-side ``(c, s)`` zeroing ``b`` against ``a`` (identity at 0)."""
    r = float(np.hypot(a, b))
    if r == 0.0:
        return 1.0, 0.0
    return a / r, b / r


def tridiag_wave_count(n: int) -> int:
    """Waves of the pipelined-staircase packing: ``2n - 5`` (0 for n<3)."""
    return max(0, 2 * n - 5)


class TridiagResult(NamedTuple):
    """``T = Q^T H Q`` with ``Q`` recorded as adjacent-plane rotations."""

    diag: np.ndarray      # (n,)   float64 diagonal of T
    offdiag: np.ndarray   # (n-1,) float64 sub/super-diagonal of T
    cos: np.ndarray       # (n-1, K) float64 recorded sequence
    sin: np.ndarray       # (n-1, K)

    @property
    def n(self) -> int:
        return self.diag.shape[0]

    def sequence(self, dtype=None) -> RotationSequence:
        """The recorded transform as a jnp :class:`RotationSequence`."""
        import jax.numpy as jnp

        dt = jnp.asarray(self.cos).dtype if dtype is None else dtype
        return RotationSequence(jnp.asarray(self.cos, dt),
                                jnp.asarray(self.sin, dt))


def tridiagonalize(H) -> TridiagResult:
    """Reduce symmetric ``H`` to tridiagonal ``T`` via adjacent rotations.

    Applying the returned sequence to ``M`` computes ``M @ Q``; in
    particular ``Q = apply(I)`` satisfies ``Q^T H Q = T`` (sub-1e-12
    relative off-tridiagonal mass — generation is float64 throughout).
    """
    H = np.array(H, dtype=np.float64)
    n = H.shape[0]
    if H.shape != (n, n):
        raise ValueError(f"tridiagonalize expects a square matrix, "
                         f"got {H.shape}")
    K = tridiag_wave_count(n)
    C = np.ones((max(n - 1, 0), K), np.float64)
    S = np.zeros((max(n - 1, 0), K), np.float64)
    for t in range(n - 2):
        for j in range(n - 2, t, -1):
            c, s = host_givens(H[j, t], H[j + 1, t])
            if s != 0.0:
                # columns < t of rows/cols >= t+1 are already zero, so
                # the update only needs the trailing t: slice.  g=-1.0
                # gives the rotation form -s*x + c*y bit-identically
                # (negation is exact), keeping the canonical stencil.
                H[j, t:], H[j + 1, t:] = plane_update(
                    H[j, t:], H[j + 1, t:], c, s, -1.0)
                H[t:, j], H[t:, j + 1] = plane_update(
                    H[t:, j], H[t:, j + 1], c, s, -1.0)
            p = (n - 2 - j) + 2 * t
            C[j, p] = c
            S[j, p] = s
    d = np.diagonal(H).copy()
    e = np.diagonal(H, offset=1).copy() if n > 1 else np.zeros(0)
    return TridiagResult(d, e, C, S)
