"""Golub-Kahan SVD machinery recorded as adjacent-plane rotation sequences.

Two stages, both emitting the paper's ``(planes, waves)`` C/S layout:

* :func:`bidiagonalize` — reduce ``A`` (m >= n) to upper bidiagonal
  ``B = U^T A V`` with adjacent-plane Givens only: sweep ``t`` zeroes
  column ``t`` below the subdiagonal bottom-up with *row* rotations
  (planes ``(i, i+1)`` of the row space, recorded in an ``(m-1, K_L)``
  left sequence), then row ``t`` right of the superdiagonal with
  *column* rotations (an ``(n-1, K_R)`` right sequence).  Each side uses
  the same pipelined-staircase wave packing as
  :mod:`repro.eig.tridiag` — descending-``j`` sweeps interleave into
  ``O(m + n)`` waves that replay correctly in wave-major order (see that
  module for the ordering proof).  Row ops and column ops commute as
  linear maps, so the two recordings are independent.

* :func:`bidiag_qr` — implicit-shift QR on the bidiagonal band
  (Golub-Kahan; shift from the trailing 2x2 of ``B^T B``, zero-shift
  fallback near-singularity a la Demmel-Kahan).  Each sweep chases the
  bulge with one *right* rotation wave and one *left* rotation wave —
  again adjacent planes in ascending order, i.e. one wave each per sweep.

Applying the left sequence to ``M`` computes ``M @ U``; the right one,
``M @ V``; with ``A = U B V^T`` and ``B`` diagonalized by the QR waves.
Singular-vector accumulation is therefore entirely "delayed": the caller
streams both recordings through ``apply_rotation_sequence`` via the
delayed buffer (paper SS5.1), which is where the solver's flops live.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import numpy as np

from repro.core.rotations import plane_update

from .qr_shift import wilkinson_shift
from .tridiag import host_givens

__all__ = ["BidiagResult", "BidiagQRResult", "bidiagonalize", "bidiag_qr"]

_EPS = float(np.finfo(np.float64).eps)


class BidiagResult(NamedTuple):
    """``B = U^T A V`` (upper bidiagonal), factors as recorded sequences."""

    diag: np.ndarray       # (n,)   float64 main diagonal of B
    superdiag: np.ndarray  # (n-1,) float64 superdiagonal of B
    cos_left: np.ndarray   # (m-1, K_L) row-space rotations (U factor)
    sin_left: np.ndarray
    cos_right: np.ndarray  # (n-1, K_R) column-space rotations (V factor)
    sin_right: np.ndarray


def bidiagonalize(A) -> BidiagResult:
    """Adjacent-plane Givens bidiagonalization of ``A`` with ``m >= n``."""
    A = np.array(A, dtype=np.float64)
    m, n = A.shape
    if m < n:
        raise ValueError(f"bidiagonalize expects m >= n, got {A.shape}; "
                         f"transpose first (svd_givens does)")
    # wave counts of the staircase packing (max index + 1, see tridiag)
    KL = max(0, (m - 2) + (n - 1) + 1) if m >= 2 else 0
    KR = max(0, 2 * n - 5)
    CL = np.ones((max(m - 1, 0), KL), np.float64)
    SL = np.zeros((max(m - 1, 0), KL), np.float64)
    CR = np.ones((max(n - 1, 0), KR), np.float64)
    SR = np.zeros((max(n - 1, 0), KR), np.float64)
    for t in range(n):
        # rows: zero A[t+1:, t] bottom-up, planes (i, i+1), i = m-2 .. t
        for i in range(m - 2, t - 1, -1):
            c, s = host_givens(A[i, t], A[i + 1, t])
            if s != 0.0:
                # g=-1.0 yields -s*x + c*y bit-identically (negation is
                # exact); the canonical stencil stays single-sourced.
                A[i, t:], A[i + 1, t:] = plane_update(
                    A[i, t:], A[i + 1, t:], c, s, -1.0)
            CL[i, (m - 2 - i) + 2 * t] = c
            SL[i, (m - 2 - i) + 2 * t] = s
        # columns: zero A[t, t+2:] right-to-left, planes (j, j+1),
        # j = n-2 .. t+1
        for j in range(n - 2, t, -1):
            c, s = host_givens(A[t, j], A[t, j + 1])
            if s != 0.0:
                A[t:, j], A[t:, j + 1] = plane_update(
                    A[t:, j], A[t:, j + 1], c, s, -1.0)
            CR[j, (n - 2 - j) + 2 * t] = c
            SR[j, (n - 2 - j) + 2 * t] = s
    d = np.diagonal(A).copy()
    f = np.diagonal(A, offset=1).copy() if n > 1 else np.zeros(0)
    return BidiagResult(d, f, CL, SL, CR, SR)


class BidiagQRResult(NamedTuple):
    values: np.ndarray     # (n,) float64 diagonal after QR (signed!)
    cos_left: np.ndarray   # (n-1, sweeps) one wave per sweep (U side)
    sin_left: np.ndarray
    cos_right: np.ndarray  # (n-1, sweeps) one wave per sweep (V side)
    sin_right: np.ndarray
    sweeps: int
    converged: bool


def bidiag_qr(d, f, *, tol: Optional[float] = None,
              max_sweeps: Optional[int] = None) -> BidiagQRResult:
    """Implicit-shift QR on upper-bidiagonal ``(d, f)``; waves recorded.

    Returns the (possibly signed) diagonal and per-sweep left/right
    rotation waves: ``diag(values) = L^T B R`` where ``L``/``R`` are the
    recorded left/right sequences applied wave-major.  Sign fixing and
    sorting are the caller's job (they are column flips/permutations of
    the accumulated vectors, not rotations).
    """
    d = np.array(d, dtype=np.float64)
    f = np.array(f, dtype=np.float64)
    n = d.shape[0]
    if f.shape[0] != max(0, n - 1):
        raise ValueError(f"superdiagonal shape {f.shape} vs n={n}")
    tol = _EPS if tol is None else float(tol)
    if max_sweeps is None:
        max_sweeps = 40 * max(1, n)
    J = max(0, n - 1)
    wcl: list = []
    wsl: list = []
    wcr: list = []
    wsr: list = []

    def pack(converged: bool) -> BidiagQRResult:
        CL = np.stack(wcl, 1) if wcl else np.ones((J, 0))
        SL = np.stack(wsl, 1) if wsl else np.zeros((J, 0))
        CR = np.stack(wcr, 1) if wcr else np.ones((J, 0))
        SR = np.stack(wsr, 1) if wsr else np.zeros((J, 0))
        return BidiagQRResult(d, CL, SL, CR, SR, len(wcl), converged)

    if n <= 1:
        return pack(True)

    def negligible(i: int) -> bool:
        return abs(f[i]) <= tol * (abs(d[i]) + abs(d[i + 1]))

    scale = float(np.max(np.abs(d)) + np.max(np.abs(f))) if n > 1 else 0.0
    hi = n - 1
    while hi > 0:
        while hi > 0 and negligible(hi - 1):
            f[hi - 1] = 0.0
            hi -= 1
        if hi == 0:
            break
        if len(wcl) >= max_sweeps:
            return pack(False)
        lo = hi - 1
        while lo > 0 and not negligible(lo - 1):
            lo -= 1
        if lo > 0:
            f[lo - 1] = 0.0

        cl = np.ones(J, np.float64)
        sl = np.zeros(J, np.float64)
        cr = np.ones(J, np.float64)
        sr = np.zeros(J, np.float64)
        # an *exactly* zero leading diagonal stalls the implicit sweep
        # (y = z = 0 makes every rotation the identity); the classical
        # row-annihilation fix needs non-adjacent planes, so instead
        # nudge d[lo] by one deflation-tolerance unit — an O(tol * ||B||)
        # perturbation, the same order as the deflation error itself
        if d[lo] == 0.0:
            blockscale = max(float(np.max(np.abs(d[lo:hi + 1]))),
                             float(np.max(np.abs(f[lo:hi]))))
            d[lo] = tol * max(blockscale, np.finfo(np.float64).tiny)
        # shift from the trailing 2x2 of B^T B; zero shift near a tiny
        # diagonal (Demmel-Kahan-style: keeps sweeps adjacent-plane)
        dm, dh, fm = d[hi - 1], d[hi], f[hi - 1]
        fm2 = f[hi - 2] if hi - 2 >= lo else 0.0
        if min(abs(dm), abs(dh)) <= tol * scale:
            mu = 0.0
        else:
            mu = wilkinson_shift(dm * dm + fm2 * fm2, dm * fm,
                                 dh * dh + fm * fm)
        y = d[lo] * d[lo] - mu
        z = d[lo] * f[lo]
        for j in range(lo, hi):
            # right rotation: columns (j, j+1)
            c, s = host_givens(y, z)
            cr[j] = c
            sr[j] = s
            if j > lo:
                f[j - 1] = c * f[j - 1] + s * z  # z = right bulge
            d[j], f[j] = plane_update(d[j], f[j], c, s, -1.0)
            bulge = s * d[j + 1]
            d[j + 1] = c * d[j + 1]
            # left rotation: rows (j, j+1), zero the (j+1, j) bulge
            c, s = host_givens(d[j], bulge)
            cl[j] = c
            sl[j] = s
            d[j] = c * d[j] + s * bulge
            f[j], d[j + 1] = plane_update(f[j], d[j + 1], c, s, -1.0)
            if j < hi - 1:
                bulge2 = s * f[j + 1]
                f[j + 1] = c * f[j + 1]
                y = f[j]
                z = bulge2
        wcl.append(cl)
        wsl.append(sl)
        wcr.append(cr)
        wsr.append(sr)

    return pack(True)
