"""Public eigensolver / SVD entry points built on recorded rotations.

``eigh_givens(A, method="qr"|"jacobi")`` and ``svd_givens(A)`` are
drop-in analogues of ``jnp.linalg.eigh`` / ``jnp.linalg.svd`` whose
eigen/singular-vector accumulation runs through the rotation-sequence
registry:

* ``method="qr"`` — tridiagonalize (:mod:`repro.eig.tridiag`), then
  implicit Wilkinson-shift QR (:mod:`repro.eig.qr_shift`).  Both stages
  *record* their rotations; the basis ``V = Q_tri . U_qr`` is obtained
  by streaming the two recordings — they share the ``(n-1, .)`` plane
  layout — through a single :class:`DelayedRotationBuffer` seeded with
  the identity.  Eigen*values* come from float64 scalar recurrences, so
  value accuracy is oracle-grade in every dtype; vector accuracy is that
  of the (blocked) application in the requested dtype.
* ``method="jacobi"`` — wraps the existing round-robin solver
  (``repro.core.jacobi``), with its recorded reflector sequence applied
  through the same ``method="auto"`` dispatch.

``svd_givens`` runs Golub-Kahan bidiagonalization + bidiagonal QR
(:mod:`repro.eig.svd`) with one delayed buffer per singular-vector side.

The ``k_delay`` knob is the paper-SS5.1 delay depth: how many recorded
waves are batched per registry-dispatched application.
"""
from __future__ import annotations

import warnings
from typing import NamedTuple, Optional

import numpy as np

from repro.core.sequence import RotationSequence

from .delayed import DelayedRotationBuffer
from .qr_shift import tridiag_qr
from .svd import bidiag_qr, bidiagonalize
from .tridiag import tridiagonalize

__all__ = ["EighResult", "SvdResult", "eigh_givens", "svd_givens"]


class EighResult(NamedTuple):
    eigenvalues: "object"   # (n,) ascending, like jnp.linalg.eigh
    eigenvectors: "object"  # (n, n); column i pairs with eigenvalue i


class SvdResult(NamedTuple):
    U: "object"   # (m, k) left singular vectors, k = min(m, n)
    s: "object"   # (k,) descending, non-negative
    Vt: "object"  # (k, n) right singular vectors, transposed


def _canonical_dtype(A):
    import jax.numpy as jnp

    return jnp.zeros((), getattr(A, "dtype", jnp.float32)).dtype


def eigh_givens(A, *, method: str = "qr", k_delay: int = 32,
                apply_method: str = "auto", autotune: bool = False,
                cycles: int = 8, tol: Optional[float] = None,
                max_sweeps: Optional[int] = None) -> EighResult:
    """Symmetric eigendecomposition via recorded rotation sequences.

    Args:
      A: symmetric ``(n, n)``.
      method: ``"qr"`` (tridiagonal QR, default) or ``"jacobi"``
        (round-robin ``core.jacobi``).
      k_delay: delayed-application batch depth (waves per flush).
      apply_method: dispatch method for basis accumulation (``"auto"``
        routes through the registry cost model + plan cache).
      autotune: measure candidate plans on the first flush.
      cycles: Jacobi cycles (``method="jacobi"`` only).
      tol / max_sweeps: QR deflation threshold and sweep budget.

    Returns:
      ``EighResult(eigenvalues, eigenvectors)`` with ascending
      eigenvalues, ``A @ V == V @ diag(w)`` up to dtype accuracy.
    """
    import jax.numpy as jnp

    n = A.shape[0]
    if A.shape != (n, n):
        raise ValueError(f"eigh_givens expects square input, got {A.shape}")
    dtype = _canonical_dtype(A)
    if n == 0:
        return EighResult(jnp.zeros((0,), dtype), jnp.zeros((0, 0), dtype))

    if method == "jacobi":
        from repro.core.jacobi import jacobi_apply_basis, jacobi_eigh

        res = jacobi_eigh(jnp.asarray(A, dtype), cycles=cycles)
        V = jacobi_apply_basis(res, method=apply_method, autotune=autotune)
        w = res.eigenvalues
        order = jnp.argsort(w)
        return EighResult(w[order].astype(dtype), V[:, order].astype(dtype))
    if method != "qr":
        raise ValueError(f"unknown eigh method {method!r}; "
                         f"one of ('qr', 'jacobi')")

    tri = tridiagonalize(np.asarray(A, np.float64))
    qr = tridiag_qr(tri.diag, tri.offdiag, tol=tol, max_sweeps=max_sweeps)
    _warn_unconverged("eigh_givens", qr.converged, qr.sweeps)
    buf = DelayedRotationBuffer(jnp.eye(n, dtype=dtype), k_delay=k_delay,
                                method=apply_method, autotune=autotune)
    # V = Q_tri @ U_qr: both recordings share the (n-1, .) plane layout,
    # so they stream through the buffer as one composed sequence
    buf.push_sequence(RotationSequence(tri.cos, tri.sin))
    buf.push_sequence(RotationSequence(qr.cos, qr.sin))
    V = buf.value
    order = np.argsort(qr.eigenvalues, kind="stable")
    w = jnp.asarray(qr.eigenvalues[order], dtype)
    return EighResult(w, V[:, jnp.asarray(order)])


def svd_givens(A, *, k_delay: int = 32, apply_method: str = "auto",
               autotune: bool = False, tol: Optional[float] = None,
               max_sweeps: Optional[int] = None,
               full_matrices: bool = False) -> SvdResult:
    """Golub-Kahan SVD via recorded rotation sequences.

    Returns ``SvdResult(U, s, Vt)`` matching
    ``jnp.linalg.svd(A, full_matrices=False)`` conventions: descending
    non-negative ``s``, ``A ~= U @ diag(s) @ Vt``.  With
    ``full_matrices=True`` the trailing null-space columns of the wide
    factor are kept.
    """
    import jax.numpy as jnp

    m, n = A.shape
    dtype = _canonical_dtype(A)
    if m < n:
        r = svd_givens(jnp.asarray(A).T, k_delay=k_delay,
                       apply_method=apply_method, autotune=autotune,
                       tol=tol, max_sweeps=max_sweeps,
                       full_matrices=full_matrices)
        return SvdResult(r.Vt.T, r.s, r.U.T)
    if n == 0:
        return SvdResult(jnp.zeros((m, 0), dtype), jnp.zeros((0,), dtype),
                         jnp.zeros((0, 0), dtype))

    bd = bidiagonalize(np.asarray(A, np.float64))
    qr = bidiag_qr(bd.diag, bd.superdiag, tol=tol, max_sweeps=max_sweeps)
    _warn_unconverged("svd_givens", qr.converged, qr.sweeps)

    # left factor: bidiag waves live on (m-1) planes, QR waves on (n-1);
    # embed the latter with identity padding below plane n-2
    buf_u = DelayedRotationBuffer(jnp.eye(m, dtype=dtype), k_delay=k_delay,
                                  method=apply_method, autotune=autotune)
    buf_u.push_sequence(RotationSequence(bd.cos_left, bd.sin_left))
    buf_u.push_sequence(RotationSequence(
        _embed_planes(qr.cos_left, m - 1, 1.0),
        _embed_planes(qr.sin_left, m - 1, 0.0)))
    U = buf_u.value
    buf_v = DelayedRotationBuffer(jnp.eye(n, dtype=dtype), k_delay=k_delay,
                                  method=apply_method, autotune=autotune)
    buf_v.push_sequence(RotationSequence(bd.cos_right, bd.sin_right))
    buf_v.push_sequence(RotationSequence(qr.cos_right, qr.sin_right))
    V = buf_v.value

    # sign fix + descending sort are column ops on the accumulated
    # factors, not rotations
    vals = qr.values
    sgn = np.where(vals < 0.0, -1.0, 1.0)
    order = np.argsort(-np.abs(vals), kind="stable")
    s = jnp.asarray(np.abs(vals)[order], dtype)
    Uk = (U[:, :n] * jnp.asarray(sgn, dtype)[None, :])[:, jnp.asarray(order)]
    Vk = V[:, jnp.asarray(order)]
    if full_matrices and m > n:
        Uk = jnp.concatenate([Uk, U[:, n:]], axis=1)
    return SvdResult(Uk, s, Vk.T)


def _warn_unconverged(who: str, converged: bool, sweeps: int) -> None:
    # values from a truncated run look plausible; make the truncation loud
    if not converged:
        warnings.warn(
            f"{who}: implicit-shift QR exhausted its sweep budget "
            f"({sweeps} sweeps) before full deflation; results are "
            f"approximate (raise max_sweeps, or check the input for "
            f"pathological structure)", RuntimeWarning, stacklevel=3)


def _embed_planes(C, planes: int, fill: float) -> np.ndarray:
    """Grow a ``(j, k)`` wave block to ``planes`` rows of no-op padding."""
    C = np.asarray(C, np.float64)
    if C.shape[0] == planes:
        return C
    out = np.full((planes, C.shape[1]), fill, np.float64)
    out[:C.shape[0], :] = C
    return out
