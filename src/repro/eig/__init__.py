"""Eigensolvers and SVD built on recorded rotation sequences.

The paper's killer application (SS5.1): eigenvalue algorithms *generate*
sequences of planar rotations; accumulating the eigen/singular-vector
bases means *applying* those sequences to a matrix — exactly the
operation this library optimizes.  The solvers here (tridiagonal
Wilkinson-shift QR, Golub-Kahan SVD, plus a wrapper over the round-robin
Jacobi solver in ``repro.core.jacobi``) record every rotation into the
paper's ``(n-1, K)`` C/S wave layout and flush them in delayed batches
through the registry-dispatched appliers.

Public API: :func:`eigh_givens`, :func:`svd_givens`; building blocks:
:func:`tridiagonalize`, :func:`bidiagonalize`,
:class:`DelayedRotationBuffer`.
"""
from .api import EighResult, SvdResult, eigh_givens, svd_givens
from .delayed import DelayedRotationBuffer
from .qr_shift import TridiagQRResult, tridiag_qr
from .svd import BidiagQRResult, BidiagResult, bidiag_qr, bidiagonalize
from .tridiag import TridiagResult, tridiag_wave_count, tridiagonalize

__all__ = [
    "EighResult", "SvdResult", "eigh_givens", "svd_givens",
    "DelayedRotationBuffer",
    "TridiagResult", "tridiagonalize", "tridiag_wave_count",
    "TridiagQRResult", "tridiag_qr",
    "BidiagResult", "BidiagQRResult", "bidiagonalize", "bidiag_qr",
]
