"""Implicit Wilkinson-shift QR on a symmetric tridiagonal matrix, with
each bulge-chasing sweep *recorded* as one wave of the paper's
``(n-1, K)`` rotation layout instead of applied eagerly.

A sweep on the active block ``[lo, hi]`` generates rotations in planes
``j = lo, lo+1, ..., hi-1`` in ascending order — exactly one wave of the
paper's wave-major schedule, with identity rotations padding the planes
outside the block.  Eigen*values* converge from the scalar recurrences
below at O(1) flops per rotation; the eigen*vector* work — accumulating
``U = G_1 G_2 ...`` — is deferred entirely to the recorded sequence,
which the caller flushes through ``apply_rotation_sequence`` in blocks
(paper SS5.1 "delayed sequences of rotations").  That is what makes the
solver's flop profile land on the optimized appliers rather than on
per-rotation scalar code.

Scalar update per rotation ``(c, s)`` at plane ``(j, j+1)`` — derived
from ``T' = G^T T G`` with the repo convention
``G = [[c, -s], [s, c]]``::

    d[j]'   =  c^2 d[j] + 2 c s e[j] + s^2 d[j+1]
    d[j+1]' =  s^2 d[j] - 2 c s e[j] + c^2 d[j+1]
    e[j]'   =  c s (d[j+1] - d[j]) + (c^2 - s^2) e[j]

with the bulge entering at ``(j+2, j)`` as ``s * e[j+1]`` and the next
rotation chosen to zero it against ``e[j]``.  Deflated ``e`` entries are
set to exactly zero, so blocks are independent and the recorded sequence
applied to the *full* matrix reproduces the tracked band to the
deflation tolerance.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import numpy as np

from .tridiag import host_givens

__all__ = ["TridiagQRResult", "tridiag_qr", "wilkinson_shift"]

_EPS = float(np.finfo(np.float64).eps)


class TridiagQRResult(NamedTuple):
    eigenvalues: np.ndarray  # (n,) float64, unsorted (deflation order)
    cos: np.ndarray          # (n-1, sweeps) one recorded wave per sweep
    sin: np.ndarray          # (n-1, sweeps)
    sweeps: int              # waves recorded
    converged: bool          # all off-diagonals deflated within budget


def wilkinson_shift(a: float, b: float, c: float) -> float:
    """Eigenvalue of ``[[a, b], [b, c]]`` closest to ``c`` (stable form)."""
    if b == 0.0:
        return c
    delta = (a - c) / 2.0
    sgn = 1.0 if delta >= 0.0 else -1.0
    return c - b * b / (delta + sgn * float(np.hypot(delta, b)))


def tridiag_qr(d, e, *, tol: Optional[float] = None,
               max_sweeps: Optional[int] = None) -> TridiagQRResult:
    """Diagonalize ``tridiag(d, e)``; record every sweep as a wave.

    Args:
      d: ``(n,)`` diagonal.  e: ``(n-1,)`` off-diagonal.
      tol: relative deflation threshold (default machine eps).
      max_sweeps: sweep budget (default ``40 n``; also the recorded
        ``K``).  A truncated run still returns a *valid* sequence — the
        eigenvalues are just not fully converged (``converged=False``).

    Applying the recorded waves to ``M`` computes ``M @ U`` where
    ``U^T T U = diag(eigenvalues)``.
    """
    d = np.array(d, dtype=np.float64)
    e = np.array(e, dtype=np.float64)
    n = d.shape[0]
    if e.shape[0] != max(0, n - 1):
        raise ValueError(f"off-diagonal shape {e.shape} does not match "
                         f"n={n}")
    tol = _EPS if tol is None else float(tol)
    if max_sweeps is None:
        max_sweeps = 40 * max(1, n)
    waves_c: list = []
    waves_s: list = []
    if n <= 1:
        return TridiagQRResult(d, np.ones((max(0, n - 1), 0)),
                               np.zeros((max(0, n - 1), 0)), 0, True)

    def negligible(i: int) -> bool:
        return abs(e[i]) <= tol * (abs(d[i]) + abs(d[i + 1]))

    hi = n - 1
    while hi > 0:
        while hi > 0 and negligible(hi - 1):
            e[hi - 1] = 0.0
            hi -= 1
        if hi == 0:
            break
        if len(waves_c) >= max_sweeps:
            return TridiagQRResult(
                d, np.stack(waves_c, 1) if waves_c else np.ones((n - 1, 0)),
                np.stack(waves_s, 1) if waves_s else np.zeros((n - 1, 0)),
                len(waves_c), False)
        lo = hi - 1
        while lo > 0 and not negligible(lo - 1):
            lo -= 1
        if lo > 0:
            e[lo - 1] = 0.0  # deflate exactly: blocks become independent

        cvec = np.ones(n - 1, np.float64)
        svec = np.zeros(n - 1, np.float64)
        mu = wilkinson_shift(d[hi - 1], e[hi - 1], d[hi])
        x = d[lo] - mu
        z = e[lo]
        for j in range(lo, hi):
            c, s = host_givens(x, z)
            cvec[j] = c
            svec[j] = s
            if j > lo:
                e[j - 1] = c * e[j - 1] + s * z  # z is the bulge here
            dj, dj1, ej = d[j], d[j + 1], e[j]
            d[j] = c * c * dj + 2.0 * c * s * ej + s * s * dj1
            d[j + 1] = s * s * dj - 2.0 * c * s * ej + c * c * dj1
            e[j] = c * s * (dj1 - dj) + (c * c - s * s) * ej
            if j < hi - 1:
                bulge = s * e[j + 1]
                e[j + 1] = c * e[j + 1]
                x = e[j]
                z = bulge
        waves_c.append(cvec)
        waves_s.append(svec)

    C = np.stack(waves_c, 1) if waves_c else np.ones((n - 1, 0))
    S = np.stack(waves_s, 1) if waves_s else np.zeros((n - 1, 0))
    return TridiagQRResult(d, C, S, len(waves_c), True)
