"""Delayed application of recorded rotation waves (paper SS5.1).

The eigensolvers in this package *generate* rotations one scalar at a
time (bulge chasing is inherently sequential) but *apply* them to the
eigen/singular-vector accumulators in bulk: a
:class:`DelayedRotationBuffer` holds the accumulator matrix and queues
recorded waves until ``k_delay`` of them are pending, then flushes the
whole batch as one :class:`~repro.core.sequence.RotationSequence`
through a **cached** :class:`~repro.core.sequence.SequencePlan` — the
registry (capability filter + cost model + plan cache, or measured
autotune) is consulted on the *first* flush only; every later flush
rebinds the frozen plan to the fresh waves and calls the chosen backend
directly.  This converts the accumulation flops from ``K`` rank-2
column updates into ``K / k_delay`` blocked/accumulated (or Pallas)
applications — the paper's "delayed sequences of rotations" use case —
and makes plan-once/apply-many the structural invariant rather than a
cache accident.

Partial final batches are identity-padded
(:meth:`~repro.core.sequence.RotationSequence.pad_to`; ``c=1, s=0`` is
an *exact* no-op, the same trick the blocked appliers use for wavefront
triangles) so every flush presents the same ``(n-1, k_delay)`` problem
shape and reuses the same frozen plan.
"""
from __future__ import annotations

import numpy as np

from repro import obs

__all__ = ["DelayedRotationBuffer"]


class DelayedRotationBuffer:
    """Accumulate ``M <- M @ G_wave`` lazily, flushing every ``k_delay``.

    Args:
      M: initial accumulator ``(m, n)`` (e.g. an identity basis), or a
        *batched* accumulator ``(b, m, n)`` — ``b`` independent bases
        sharing every pushed wave, flushed in one batched application
        (:meth:`~repro.core.sequence.SequencePlan.apply_batched`; exact
        per slice, since rotations act row-wise).
      k_delay: waves buffered per flush (the SS5.1 delay depth).
      method: dispatch method for the flush; ``"auto"`` consults the
        registry cost model + plan cache (once — see ``plan``).
      autotune: measure candidate plans when first resolving the flush
        plan (``auto`` only).
      mesh: optional ``jax.sharding.Mesh`` — flushes resolve a
        row-sharded :class:`~repro.dist.ShardedSequencePlan` via
        :func:`repro.dist.plan_sharded` instead of a replicated
        ``SequencePlan`` (distributed eigenvector accumulation; the
        comm-extended cost model arbitrates sharded vs replicated under
        ``method="auto"``).
      row_axes: mesh axes the accumulator's rows shard over (with
        ``mesh``; default ``("data",)``).
      apply_kw: extra plan kwargs (e.g. explicit ``n_b``/``k_b``
        overrides) forwarded to ``RotationSequence.plan``.
    """

    def __init__(self, M, *, k_delay: int = 32, method: str = "auto",
                 autotune: bool = False, pad_flush: bool = True,
                 mesh=None, row_axes=("data",), **apply_kw):
        import jax.numpy as jnp

        if k_delay < 1:
            raise ValueError(f"k_delay must be >= 1, got {k_delay}")
        self._M = jnp.asarray(M)
        if self._M.ndim not in (2, 3):
            raise ValueError(
                f"accumulator must be 2D (m, n) or batched 3D (b, m, n), "
                f"got {self._M.shape}")
        self.k_delay = int(k_delay)
        self.method = method
        self.autotune = autotune
        self.mesh = mesh
        self.row_axes = tuple(row_axes)
        self.pad_flush = bool(pad_flush)
        self.apply_kw = dict(apply_kw)
        self.planes = self._M.shape[-1] - 1
        self.flushes = 0
        self.waves_pushed = 0
        self._c: list = []
        self._s: list = []
        self._g: list = []  # per-wave sign columns; None = all-rotation
        # frozen SequencePlan per flush signature (k_padded, signs) —
        # resolved once, rebound to fresh waves on every later flush
        self._plans: dict = {}

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (f"DelayedRotationBuffer(shape={tuple(self._M.shape)}, "
                f"pending={len(self._c)}/{self.k_delay}, "
                f"flushes={self.flushes}, method={self.method!r})")

    @property
    def pending(self) -> int:
        return len(self._c)

    def push(self, c, s, g=None) -> "DelayedRotationBuffer":
        """Queue one wave (``(n-1,)`` cos/sin, optional sign column)."""
        c = np.asarray(c, np.float64).reshape(-1)
        s = np.asarray(s, np.float64).reshape(-1)
        if c.shape[0] != self.planes or s.shape[0] != self.planes:
            raise ValueError(
                f"wave has {c.shape[0]} planes; accumulator with "
                f"{self._M.shape[-1]} columns needs {self.planes}")
        self._c.append(c)
        self._s.append(s)
        self._g.append(None if g is None
                       else np.asarray(g, np.float64).reshape(-1))
        self.waves_pushed += 1
        if len(self._c) >= self.k_delay:
            self.flush()
        return self

    def push_sequence(self, seq, S=None, G=None) -> "DelayedRotationBuffer":
        """Queue every wave of a :class:`RotationSequence` in order.

        The legacy raw-array form ``push_sequence(C, S[, G])`` is still
        accepted but deprecated — wrap the waves in a
        ``RotationSequence`` instead.
        """
        from repro.core.sequence import RotationSequence

        if not isinstance(seq, RotationSequence):
            import warnings

            warnings.warn(
                "push_sequence(C, S) with raw wave arrays is deprecated; "
                "push a RotationSequence instead",
                DeprecationWarning, stacklevel=2)
            seq = RotationSequence(np.asarray(seq), np.asarray(S),
                                   None if G is None else np.asarray(G))
        C = np.asarray(seq.cos)
        S_ = np.asarray(seq.sin)
        G_ = None if seq.sign is None else np.asarray(seq.sign)
        if G_ is None and seq.reflect:
            G_ = np.ones(C.shape, np.float64)
        for p in range(C.shape[1]):
            self.push(C[:, p], S_[:, p], None if G_ is None else G_[:, p])
        return self

    def _pending_sequence(self):
        """Pending waves as one RotationSequence, identity-padded to the
        flush shape (``(n-1, k_delay)``) when ``pad_flush`` is on."""
        from repro.core.sequence import RotationSequence

        k = len(self._c)
        C = np.stack(self._c, 1)
        S = np.stack(self._s, 1)
        G = None
        if any(g is not None for g in self._g):
            G = np.full((self.planes, k), -1.0, np.float64)
            for p, g in enumerate(self._g):
                if g is not None:
                    G[:, p] = g
        dt = self._M.dtype
        seq = RotationSequence(C.astype(dt), S.astype(dt),
                               None if G is None else G.astype(dt))
        if self.pad_flush and k < self.k_delay:
            seq = seq.pad_to(self.k_delay)
        return seq

    def flush(self):
        """Apply all pending waves through the cached frozen plan."""
        if self._c:
            waves = len(self._c)
            with obs.span("flush", waves=waves, planes=self.planes):
                seq = self._pending_sequence()
                plan_key = (seq.k, seq.sign is not None)
                plan = self._plans.get(plan_key)
                if plan is None:
                    # a batched accumulator applies ONE pending sequence
                    # to every basis in the (b, m, n) stack — a
                    # shared-sequence batch (explicit, so the registry
                    # amortizes per-sequence setup instead of pricing it
                    # per basis like a serving bucket)
                    if self.mesh is not None:
                        from repro import dist

                        plan = dist.plan_sharded(
                            seq, like=self._M, mesh=self.mesh,
                            row_axes=self.row_axes, method=self.method,
                            autotune=self.autotune, shared_sequence=True,
                            **self.apply_kw)
                    else:
                        plan = seq.plan(like=self._M, method=self.method,
                                        autotune=self.autotune,
                                        shared_sequence=True,
                                        **self.apply_kw)
                    self._plans[plan_key] = plan
                else:
                    with obs.span("rebind"):
                        plan = plan.rebind(seq)
                # host-driven accumulation is never differentiated
                # through; the direct paths skip the custom_vjp wrapper
                # (and keep the backend's native autodiff semantics if
                # anyone ever does).  A batched accumulator flushes all
                # b bases through one batched application of the same
                # frozen plan.
                if self._M.ndim == 3:
                    self._M = plan.apply_batched(self._M, direct=True)
                elif self.mesh is not None:
                    # ShardedSequencePlan spells direct as a kwarg
                    self._M = plan.apply(self._M, direct=True)
                else:
                    self._M = plan.apply_direct(self._M)
                self._c.clear()
                self._s.clear()
                self._g.clear()
                self.flushes += 1
            obs.inc("eig.flushes")
            obs.observe("eig.waves_per_flush", waves, unit="waves")
        return self._M

    @property
    def value(self):
        """Flush any pending waves and return the accumulator."""
        return self.flush()
