"""Delayed application of recorded rotation waves (paper SS5.1).

The eigensolvers in this package *generate* rotations one scalar at a
time (bulge chasing is inherently sequential) but *apply* them to the
eigen/singular-vector accumulators in bulk: a
:class:`DelayedRotationBuffer` holds the accumulator matrix and queues
recorded waves until ``k_delay`` of them are pending, then flushes the
whole batch through one registry-dispatched
``apply_rotation_sequence(method="auto")`` call.  This converts the
accumulation flops from ``K`` rank-2 column updates into
``K / k_delay`` blocked/accumulated (or Pallas) applications — the
paper's "delayed sequences of rotations" use case, and the reason the
solvers' hot path runs on the optimized kernels.

Partial final batches are padded with identity waves (``c=1, s=0`` is an
*exact* no-op, the same trick the blocked appliers use for wavefront
triangles) so every flush presents the same ``(n-1, k_delay)`` problem
shape — one plan-cache entry per accumulator, planned once (or autotuned
once, persisting to the on-disk plan cache) and reused for every flush.
"""
from __future__ import annotations

import numpy as np

__all__ = ["DelayedRotationBuffer"]


class DelayedRotationBuffer:
    """Accumulate ``M <- M @ G_wave`` lazily, flushing every ``k_delay``.

    Args:
      M: initial accumulator ``(m, n)`` (e.g. an identity basis).
      k_delay: waves buffered per flush (the SS5.1 delay depth).
      method: dispatch method for the flush; ``"auto"`` consults the
        registry cost model + plan cache.
      autotune: measure candidate plans on first flush (``auto`` only).
      apply_kw: extra kwargs forwarded to ``apply_rotation_sequence``
        (e.g. explicit ``n_b``/``k_b`` overrides).
    """

    def __init__(self, M, *, k_delay: int = 32, method: str = "auto",
                 autotune: bool = False, pad_flush: bool = True,
                 **apply_kw):
        import jax.numpy as jnp

        if k_delay < 1:
            raise ValueError(f"k_delay must be >= 1, got {k_delay}")
        self._M = jnp.asarray(M)
        if self._M.ndim != 2:
            raise ValueError(f"accumulator must be 2D, got {self._M.shape}")
        self.k_delay = int(k_delay)
        self.method = method
        self.autotune = autotune
        self.pad_flush = bool(pad_flush)
        self.apply_kw = dict(apply_kw)
        self.planes = self._M.shape[1] - 1
        self.flushes = 0
        self.waves_pushed = 0
        self._c: list = []
        self._s: list = []
        self._g: list = []  # per-wave sign columns; None = all-rotation

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (f"DelayedRotationBuffer(shape={tuple(self._M.shape)}, "
                f"pending={len(self._c)}/{self.k_delay}, "
                f"flushes={self.flushes}, method={self.method!r})")

    @property
    def pending(self) -> int:
        return len(self._c)

    def push(self, c, s, g=None) -> "DelayedRotationBuffer":
        """Queue one wave (``(n-1,)`` cos/sin, optional sign column)."""
        c = np.asarray(c, np.float64).reshape(-1)
        s = np.asarray(s, np.float64).reshape(-1)
        if c.shape[0] != self.planes or s.shape[0] != self.planes:
            raise ValueError(
                f"wave has {c.shape[0]} planes; accumulator with "
                f"{self._M.shape[1]} columns needs {self.planes}")
        self._c.append(c)
        self._s.append(s)
        self._g.append(None if g is None
                       else np.asarray(g, np.float64).reshape(-1))
        self.waves_pushed += 1
        if len(self._c) >= self.k_delay:
            self.flush()
        return self

    def push_sequence(self, C, S, G=None) -> "DelayedRotationBuffer":
        """Queue every wave (column) of ``C``/``S`` in order."""
        C = np.asarray(C)
        S = np.asarray(S)
        for p in range(C.shape[1]):
            self.push(C[:, p], S[:, p],
                      None if G is None else np.asarray(G)[:, p])
        return self

    def _stacked(self):
        k = len(self._c)
        pad = self.k_delay - k if self.pad_flush else 0
        C = np.ones((self.planes, k + pad), np.float64)
        S = np.zeros((self.planes, k + pad), np.float64)
        C[:, :k] = np.stack(self._c, 1)
        S[:, :k] = np.stack(self._s, 1)
        G = None
        if any(g is not None for g in self._g):
            G = np.full((self.planes, k + pad), -1.0, np.float64)
            for p, g in enumerate(self._g):
                if g is not None:
                    G[:, p] = g
        return C, S, G

    def flush(self):
        """Apply all pending waves in one registry-dispatched call."""
        if self._c:
            import jax.numpy as jnp

            from repro.core.api import apply_rotation_sequence

            C, S, G = self._stacked()
            dt = self._M.dtype
            self._M = apply_rotation_sequence(
                self._M, jnp.asarray(C, dt), jnp.asarray(S, dt),
                method=self.method,
                G=None if G is None else jnp.asarray(G, dt),
                autotune=self.autotune, **self.apply_kw)
            self._c.clear()
            self._s.clear()
            self._g.clear()
            self.flushes += 1
        return self._M

    @property
    def value(self):
        """Flush any pending waves and return the accumulator."""
        return self.flush()
