"""Public API for rotation-sequence application.

``apply_rotation_sequence(A, C, S, method=...)`` dispatches to all
implementations; ``method`` one of:

  ``unoptimized``   Algorithm 1.2 (paper baseline, jnp)
  ``wavefront``     Algorithm 1.3 (jnp)
  ``blocked``       blocked wavefront, host jnp (paper SS2/SS5)
  ``accumulated``   rs_gemm analogue: tile factors + GEMM sweeps
  ``pallas_wave``   Pallas VPU wavefront kernel (packed layout)
  ``pallas_mxu``    Pallas MXU accumulated kernel
"""
from __future__ import annotations

from .accumulate import rot_sequence_accumulated
from .blocked import rot_sequence_blocked
from .ref import rot_sequence_unoptimized, rot_sequence_wavefront

__all__ = ["apply_rotation_sequence", "METHODS"]

METHODS = (
    "unoptimized", "wavefront", "blocked", "accumulated",
    "pallas_wave", "pallas_mxu",
)


def apply_rotation_sequence(A, C, S, *, method: str = "accumulated",
                            n_b: int = 64, k_b: int = 16,
                            reflect: bool = False, G=None, **kw):
    if method == "unoptimized":
        assert G is None, "per-entry signs need a blocked method"
        return rot_sequence_unoptimized(A, C, S, reflect=reflect)
    if method == "wavefront":
        assert G is None, "per-entry signs need a blocked method"
        return rot_sequence_wavefront(A, C, S, reflect=reflect)
    if method == "blocked":
        return rot_sequence_blocked(A, C, S, n_b=n_b, k_b=k_b,
                                    reflect=reflect, G=G)
    if method == "accumulated":
        return rot_sequence_accumulated(A, C, S, n_b=n_b, k_b=k_b,
                                        reflect=reflect, G=G)
    if method == "pallas_wave":
        from repro.kernels.rotseq.ops import rot_sequence_wave
        return rot_sequence_wave(A, C, S, n_b=n_b, k_b=k_b,
                                 reflect=reflect, G=G, **kw)
    if method == "pallas_mxu":
        from repro.kernels.rotseq_mxu.ops import rot_sequence_mxu
        return rot_sequence_mxu(A, C, S, n_b=n_b, k_b=k_b,
                                reflect=reflect, G=G, **kw)
    raise ValueError(f"unknown method {method!r}; one of {METHODS}")
