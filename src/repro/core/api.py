"""Public API for rotation-sequence application.

``apply_rotation_sequence(A, C, S, method=...)`` dispatches through the
backend **registry** (:mod:`repro.core.registry`); ``method`` one of:

  ``unoptimized``   Algorithm 1.2 (paper baseline, jnp)
  ``wavefront``     Algorithm 1.3 (jnp)
  ``blocked``       blocked wavefront, host jnp (paper SS2/SS5)
  ``accumulated``   rs_gemm analogue: tile factors + GEMM sweeps
  ``pallas_wave``   Pallas VPU wavefront kernel (packed layout)
  ``pallas_mxu``    Pallas MXU accumulated kernel
  ``auto``          registry cost model picks backend + (n_b, k_b, m_blk)
                    from problem shape/dtype/platform; pass
                    ``autotune=True`` to measure the top candidates and
                    cache the fastest plan per (shape, dtype, platform).

Each backend is registered below with a capability record (dtypes,
platforms, per-entry-sign support, shard_map compatibility, Pallas
requirements) and a cost model from the paper's SS6 memory-operation
analysis.  Explicit ``n_b``/``k_b``/``m_blk`` arguments always override
the planned tiles.
"""
from __future__ import annotations

from repro.core import registry
from repro.core.registry import BackendSpec, Capability, select_plan

from .accumulate import rot_sequence_accumulated
from .blocked import rot_sequence_blocked
from .ref import rot_sequence_unoptimized, rot_sequence_wavefront

__all__ = ["apply_rotation_sequence", "METHODS", "select_plan"]


# --------------------------------------------------------------------------
# backend registration
# --------------------------------------------------------------------------

def _run_unoptimized(A, C, S, *, reflect=False, G=None, **kw):
    assert G is None, "per-entry signs need a blocked method"
    return rot_sequence_unoptimized(A, C, S, reflect=reflect)


def _run_wavefront(A, C, S, *, reflect=False, G=None, **kw):
    assert G is None, "per-entry signs need a blocked method"
    return rot_sequence_wavefront(A, C, S, reflect=reflect)


def _run_blocked(A, C, S, *, n_b=64, k_b=16, reflect=False, G=None, **kw):
    return rot_sequence_blocked(A, C, S, n_b=n_b, k_b=k_b, reflect=reflect,
                                G=G)


def _run_accumulated(A, C, S, *, n_b=64, k_b=16, reflect=False, G=None,
                     **kw):
    return rot_sequence_accumulated(A, C, S, n_b=n_b, k_b=k_b,
                                    reflect=reflect, G=G)


def _run_pallas_wave(A, C, S, *, n_b=64, k_b=16, reflect=False, G=None,
                     **kw):
    from repro.kernels.rotseq.ops import rot_sequence_wave
    return rot_sequence_wave(A, C, S, n_b=n_b, k_b=k_b, reflect=reflect,
                             G=G, **kw)


def _run_pallas_mxu(A, C, S, *, n_b=64, k_b=16, reflect=False, G=None,
                    **kw):
    from repro.kernels.rotseq_mxu.ops import rot_sequence_mxu
    return rot_sequence_mxu(A, C, S, n_b=n_b, k_b=k_b, reflect=reflect,
                            G=G, **kw)


registry.register(BackendSpec(
    name="unoptimized",
    fn=_run_unoptimized,
    capability=Capability(supports_signs=False, supports_sharding=True),
    cost=registry.cost_unoptimized,
    candidates=registry.no_tiles,
    doc="Algorithm 1.2 reference: one rotation at a time, no blocking.",
))

registry.register(BackendSpec(
    name="wavefront",
    fn=_run_wavefront,
    capability=Capability(supports_signs=False, supports_sharding=True),
    cost=registry.cost_wavefront,
    candidates=registry.no_tiles,
    doc="Algorithm 1.3 wavefront order, unblocked.",
))

registry.register(BackendSpec(
    name="blocked",
    fn=_run_blocked,
    capability=Capability(supports_sharding=True, tile_min=(2, 1)),
    cost=registry.cost_blocked,
    candidates=registry.blocked_tiles,
    doc="Blocked wavefront (paper SS2/SS5), jnp scan over tiles.",
))

registry.register(BackendSpec(
    name="accumulated",
    fn=_run_accumulated,
    capability=Capability(supports_sharding=True, tile_min=(2, 1)),
    cost=registry.cost_accumulated,
    candidates=registry.accumulated_tiles,
    doc="rs_gemm analogue: accumulate tile factors, sweep as GEMMs.",
))

registry.register(BackendSpec(
    name="pallas_wave",
    fn=_run_pallas_wave,
    capability=Capability(platforms=("tpu",), tile_min=(2, 1),
                          needs_pallas=True),
    cost=registry.cost_pallas_wave,
    candidates=registry.pallas_wave_tiles,
    doc="Pallas TPU VPU wavefront kernel (packed layout, VMEM carry).",
))

registry.register(BackendSpec(
    name="pallas_mxu",
    fn=_run_pallas_mxu,
    capability=Capability(platforms=("tpu",), tile_min=(2, 1),
                          needs_pallas=True),
    cost=registry.cost_pallas_mxu,
    candidates=registry.pallas_mxu_tiles,
    doc="Pallas TPU MXU accumulated kernel.",
))

METHODS = registry.registered_methods()

# persisted (autotuned) plans can only be validated against the registry
# once every backend above is registered — hence load-here, not on
# registry import
registry.load_plan_cache()


# --------------------------------------------------------------------------
# dispatch
# --------------------------------------------------------------------------

def apply_rotation_sequence(A, C, S, *, method: str = "accumulated",
                            n_b: int | None = None, k_b: int | None = None,
                            reflect: bool = False, G=None,
                            autotune: bool = False, **kw):
    """Apply the rotation sequence ``(C, S)`` to ``A`` from the right.

    ``method="auto"`` consults the registry: capability filtering, the
    SS6 cost model (or measured autotune), and the per-(shape, dtype,
    platform) plan cache decide the backend and tile sizes.  A named
    ``method`` keeps the seed behaviour: every tiled backend defaults to
    ``n_b=64, k_b=16`` unless overridden.
    """
    if method == "auto":
        m, n = A.shape
        _, k = C.shape
        if n < 2 or k < 1 or m < 1:
            return A  # no rotation sites: application is the identity
        plan = select_plan(m, n, k, dtype=A.dtype,
                           platform=kw.pop("platform", None),
                           signs=G is not None,
                           sharded=kw.pop("sharded", False),
                           autotune=autotune)
        planned = plan.kwargs()
        if n_b is not None:
            planned["n_b"] = n_b
        if k_b is not None:
            planned["k_b"] = k_b
        planned.update(kw)
        spec = registry.get_backend(plan.method)
        return spec.fn(A, C, S, reflect=reflect, G=G, **planned)

    spec = registry.get_backend(method)  # raises ValueError if unknown
    if G is not None and not spec.capability.supports_signs:
        raise ValueError(
            f"method {method!r} does not support per-entry signs (G); "
            f"use a blocked-family backend"
        )
    planned = dict(kw)
    for planner_kw in ("sharded", "platform"):  # planner-only kwargs
        planned.pop(planner_kw, None)
    if spec.candidates is not registry.no_tiles:  # registry: tiled backend
        planned["n_b"] = 64 if n_b is None else n_b  # seed defaults
        planned["k_b"] = 16 if k_b is None else k_b
    return spec.fn(A, C, S, reflect=reflect, G=G, **planned)
