"""Backend registration + the raw-array compatibility entry point.

The idiomatic API lives in :mod:`repro.core.sequence`: build a
:class:`~repro.core.sequence.RotationSequence`, resolve it once with
``seq.plan(like=A)``, and apply the frozen plan many times.
``apply_rotation_sequence(A, C, S, method=...)`` below is the thin
back-compat wrapper over that machinery for callers still holding loose
``C``/``S`` arrays; ``method`` one of:

  ``unoptimized``   Algorithm 1.2 (paper baseline, jnp)
  ``wavefront``     Algorithm 1.3 (jnp)
  ``blocked``       blocked wavefront, host jnp (paper SS2/SS5)
  ``accumulated``   rs_gemm analogue: tile factors + GEMM sweeps
  ``pallas_wave``   Pallas VPU wavefront kernel (packed layout)
  ``pallas_mxu``    Pallas MXU accumulated kernel
  ``auto``          registry cost model picks backend + (n_b, k_b, m_blk)
                    from problem shape/dtype/platform; pass
                    ``autotune=True`` to measure the top candidates and
                    cache the fastest plan per (shape, dtype, platform).

Each backend is registered below with a capability record (dtypes,
platforms, per-entry-sign support, shard_map compatibility, Pallas
requirements) and a cost model from the paper's SS6 memory-operation
analysis.  Explicit ``n_b``/``k_b``/``m_blk`` arguments always override
the planned tiles.

Deprecation policy: the raw-array kwargs that duplicate
``RotationSequence`` state (``G=`` per-entry signs) warn with
``DeprecationWarning`` and will be removed once external callers have
migrated; plain ``(A, C, S)`` positional calls remain supported as the
compatibility surface.  Internal ``src/repro`` code must construct
``RotationSequence`` objects instead — analyzer rule RA201 and the
``pytest.ini`` DeprecationWarning-to-error filter (scoped to warnings
originating from ``repro.*`` frames) enforce it.
"""
from __future__ import annotations

import warnings

from repro.core import registry
from repro.core.registry import BackendSpec, Capability, select_plan
from repro.core.sequence import RotationSequence

from .accumulate import rot_sequence_accumulated
from .blocked import rot_sequence_blocked
from .ref import rot_sequence_unoptimized, rot_sequence_wavefront

__all__ = ["apply_rotation_sequence", "METHODS", "select_plan"]


# --------------------------------------------------------------------------
# backend registration
# --------------------------------------------------------------------------

def _run_unoptimized(A, C, S, *, reflect=False, G=None, **kw):
    return rot_sequence_unoptimized(A, C, S, reflect=reflect, G=G)


def _run_wavefront(A, C, S, *, reflect=False, G=None, **kw):
    return rot_sequence_wavefront(A, C, S, reflect=reflect, G=G)


def _run_blocked(A, C, S, *, n_b=64, k_b=16, reflect=False, G=None, **kw):
    return rot_sequence_blocked(A, C, S, n_b=n_b, k_b=k_b, reflect=reflect,
                                G=G)


def _run_accumulated(A, C, S, *, n_b=64, k_b=16, reflect=False, G=None,
                     **kw):
    return rot_sequence_accumulated(A, C, S, n_b=n_b, k_b=k_b,
                                    reflect=reflect, G=G)


def _run_pallas_wave(A, C, S, *, n_b=64, k_b=16, reflect=False, G=None,
                     **kw):
    from repro.kernels.rotseq.ops import rot_sequence_wave
    return rot_sequence_wave(A, C, S, n_b=n_b, k_b=k_b, reflect=reflect,
                             G=G, **kw)


def _run_pallas_mxu(A, C, S, *, n_b=64, k_b=16, reflect=False, G=None,
                    **kw):
    from repro.kernels.rotseq_mxu.ops import rot_sequence_mxu
    return rot_sequence_mxu(A, C, S, n_b=n_b, k_b=k_b, reflect=reflect,
                            G=G, **kw)


def _run_rotseq_batched(A, C, S, *, m_blk=256, reflect=False, G=None,
                        n_b=None, k_b=None, **kw):
    # n_b/k_b are accepted (and ignored) so seed tile defaults from
    # named-method planning don't trip the fused kernel, which tiles
    # only over lanes (whole n stays VMEM-resident).
    from repro.kernels.rotseq_batched.ops import rot_sequence_batched
    return rot_sequence_batched(A, C, S, m_blk=m_blk, reflect=reflect,
                                G=G, **kw)


registry.register(BackendSpec(
    name="unoptimized",
    fn=_run_unoptimized,
    capability=Capability(supports_signs=False, supports_sharding=True),
    cost=registry.cost_unoptimized,
    candidates=registry.no_tiles,
    doc="Algorithm 1.2 reference: one rotation at a time, no blocking.",
))

registry.register(BackendSpec(
    name="wavefront",
    fn=_run_wavefront,
    capability=Capability(supports_signs=False, supports_sharding=True),
    cost=registry.cost_wavefront,
    candidates=registry.no_tiles,
    doc="Algorithm 1.3 wavefront order, unblocked.",
))

registry.register(BackendSpec(
    name="blocked",
    fn=_run_blocked,
    capability=Capability(supports_sharding=True, tile_min=(2, 1)),
    cost=registry.cost_blocked,
    candidates=registry.blocked_tiles,
    doc="Blocked wavefront (paper SS2/SS5), jnp scan over tiles.",
))

registry.register(BackendSpec(
    name="accumulated",
    fn=_run_accumulated,
    capability=Capability(supports_sharding=True, tile_min=(2, 1)),
    cost=registry.cost_accumulated,
    candidates=registry.accumulated_tiles,
    doc="rs_gemm analogue: accumulate tile factors, sweep as GEMMs.",
))

# Pallas kernels pad m to m_blk internally, so a shared-sequence batch
# still flattens to (b*m, n); per-request wave batches fall back to a
# per-element loop (supports_vmap=False) rather than vmapping pallas_call.
registry.register(BackendSpec(
    name="pallas_wave",
    fn=_run_pallas_wave,
    capability=Capability(platforms=("tpu",), tile_min=(2, 1),
                          needs_pallas=True, supports_vmap=False),
    cost=registry.cost_pallas_wave,
    candidates=registry.pallas_wave_tiles,
    doc="Pallas TPU VPU wavefront kernel (packed layout, VMEM carry).",
))

registry.register(BackendSpec(
    name="pallas_mxu",
    fn=_run_pallas_mxu,
    capability=Capability(platforms=("tpu",), tile_min=(2, 1),
                          needs_pallas=True, supports_vmap=False),
    cost=registry.cost_pallas_mxu,
    candidates=registry.pallas_mxu_tiles,
    doc="Pallas TPU MXU accumulated kernel.",
))

# The fused multi-request kernel: one launch per serve bucket, grid over
# (batch, m-blocks), per-wave valid_planes windows skipping pad_to /
# seq.T identity padding.  batch_via="fused" makes apply_batched hand it
# the whole (b, m, n) stack (shared or per-request waves) in one call;
# per-request vmap/loop stays available as the fallback capability on
# every other backend.  supports_sharding: the launch is pure per-shard
# work (rows are independent under column-pair rotations), so
# repro.dist runs exactly one of these launches per shard_map shard.
registry.register(BackendSpec(
    name="rotseq_batched",
    fn=_run_rotseq_batched,
    capability=Capability(platforms=("tpu",), tile_min=(2, 1),
                          needs_pallas=True, supports_vmap=False,
                          supports_sharding=True, batch_via="fused"),
    cost=registry.cost_rotseq_batched,
    candidates=registry.rotseq_batched_tiles,
    doc="Fused multi-request Pallas kernel (one launch per bucket, "
        "identity planes skipped).",
))

METHODS = registry.registered_methods()

# persisted (autotuned) plans can only be validated against the registry
# once every backend above is registered — hence load-here, not on
# registry import
registry.load_plan_cache()


# --------------------------------------------------------------------------
# dispatch
# --------------------------------------------------------------------------

def apply_rotation_sequence(A, C, S, *, method: str = "accumulated",
                            n_b: int | None = None, k_b: int | None = None,
                            reflect: bool = False, G=None,
                            autotune: bool = False, **kw):
    """Apply the rotation sequence ``(C, S)`` to ``A`` from the right.

    Back-compat wrapper: wraps the loose arrays in a
    :class:`~repro.core.sequence.RotationSequence` and executes one
    freshly resolved :class:`~repro.core.sequence.SequencePlan`.
    ``method="auto"`` consults the registry (capability filter, SS6 cost
    model / autotune, per-(shape, dtype, platform) plan cache); a named
    ``method`` keeps the seed behaviour (tiled backends default to
    ``n_b=64, k_b=16``).  Empty sequences (``n < 2`` or ``k < 1``) are
    the identity under *every* method.

    Prefer the typed API for new code — especially for repeated
    applications, where ``seq.plan(like=A)`` amortizes dispatch:

    ======================================  ==================================
    raw-array call                          RotationSequence API
    ======================================  ==================================
    ``apply_rotation_sequence(A, C, S)``    ``seq.apply(A)``
    ``..., G=G)``                           ``RotationSequence(C, S, sign=G)``
    ``..., reflect=True)``                  ``RotationSequence(C, S, reflect=True)``
    ``..., method=..., n_b=..., k_b=...)``  ``seq.plan(like=A, method=..., ...)``
    per-call dispatch                       ``plan.apply(A)`` (plan once)
    ======================================  ==================================

    Autodiff note: this wrapper calls the planned backend *directly*, so
    it keeps the seed's native JAX differentiation semantics — including
    gradients w.r.t. ``C``/``S`` through the pure-jnp backends.  The
    typed ``plan.apply`` instead uses the transposed-sequence
    ``custom_vjp`` (exact and cheap w.r.t. ``A``; the sequence is a
    constant there — see :mod:`repro.core.sequence`).
    """
    if G is not None:
        warnings.warn(
            "apply_rotation_sequence(G=...) with a raw per-entry sign "
            "array is deprecated; construct "
            "RotationSequence(C, S, sign=G) and use seq.apply / "
            "seq.plan(...).apply instead",
            DeprecationWarning, stacklevel=2)
    seq = RotationSequence(C, S, G, reflect)
    platform = kw.pop("platform", None)
    sharded = kw.pop("sharded", False)
    plan = seq.plan(like=A, method=method, autotune=autotune,
                    platform=platform, sharded=sharded,
                    n_b=n_b, k_b=k_b, **kw)
    return plan.apply_direct(A)
