"""First-class rotation sequences: plan-once / apply-many.

The paper's central object is not a matrix but a *sequence of planar
rotations* — recorded once in the packed ``(n-1, K)`` C/S wave layout,
then applied many times with blocked or accumulated kernels.  This
module makes that object a real type:

* :class:`RotationSequence` — a frozen dataclass holding ``cos``/``sin``
  waves, an optional per-entry ``sign`` array (mixed rotation/reflector
  sequences, paper SS8.4), and a ``reflect`` flag.  It is registered as
  a JAX **pytree**, so sequences pass through ``jit``/``vmap``/``grad``
  and ``shard_map`` like any array.  Constructors
  (:meth:`~RotationSequence.from_waves`,
  :meth:`~RotationSequence.from_pairs`,
  :meth:`~RotationSequence.identity`) validate the wave layout and can
  repair ``c^2 + s^2 = 1`` drift.

* **Composition semantics** — ``seq.T`` is the exact inverse (reversed
  waves, transposed planes), ``seq1 @ seq2`` concatenates along ``K``
  ("apply seq1, then seq2"), ``seq[i:j]`` slices waves, and
  :meth:`~RotationSequence.pad_to` identity-pads to a target ``K`` so
  repeated applications present plan-cache-stable shapes.

* **Two-phase execution** — ``plan = seq.plan(like=A)`` resolves the
  backend registry *once* (capability filter + SS6 cost model + plan
  cache, or measured autotune) into a frozen :class:`SequencePlan`;
  ``plan.apply(A)`` then calls the chosen backend directly, with no
  registry lookup on the hot path.  ``seq.apply(A)`` is the one-shot
  convenience composing both.

* **Autodiff** — application is linear in ``A``, so its VJP is exactly
  one application of the *transposed* sequence: ``custom_vjp`` on the
  planned apply makes ``jax.grad`` work through any backend (including
  Pallas kernels) at the cost of one extra sequence application — no
  unrolled rotation tape.  The sequence itself is treated as a constant
  (its cotangents are symbolically zero); differentiate the *recording*
  step instead if you need angle gradients.

Transpose math: one plane transform is ``M(c, s, g) = [[c, g s], [s,
-g c]]`` acting on columns ``(j, j+1)`` (``g = -1`` rotation, ``g = +1``
reflector).  ``M^T = M(c, g s, g)`` on the *same* column pair, so the
inverse applies the per-plane transposes in reversed total order; that
order re-packs into the wave-major layout as an anti-diagonal staircase
(see :attr:`RotationSequence.T`), the same pipelining trick the eig
recorders use for their descending elimination sweeps.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat, obs
from repro.core import registry

__all__ = ["RotationSequence", "SequencePlan", "PLAN_DICT_FORMAT",
           "planned_apply", "planned_apply_batched", "planned_run",
           "stack_request_waves"]


# sign value of the unified update ``y' = g * (s x - c y)``
_ROT = -1.0      # plain rotation (identity padding is a no-op)
_REFL = 1.0      # 2x2 reflector (paper SS8.4)

# relative drift of c^2 + s^2 (in ulps of the wave dtype) above which
# from_waves(normalize="auto") renormalizes an entry (exact pairs pass
# through bit-for-bit)
_DRIFT_ULPS = 64


def _ensure_backends() -> None:
    """Planning needs the backend registry populated (api.py does it)."""
    import repro.core.api  # noqa: F401  (import side effect: registration)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True, eq=False)
class RotationSequence:
    """A sequence of ``(n-1) * k`` planar rotations in the paper's layout.

    ``cos``/``sin`` have shape ``(n-1, k)``: entry ``(j, p)`` acts on
    columns ``(j, j+1)`` of the target during wave ``p`` (wave-major
    order, ascending ``j`` within a wave).  ``sign`` is an optional
    per-entry array mixing rotations (``-1``) and 2x2 reflectors
    (``+1``); ``reflect=True`` marks an all-reflector sequence without
    materializing the array.

    ``k_live`` is an optional *static* upper bound on the number of
    non-identity planes in the grid (``None`` = unknown, assume dense).
    Identity-padding constructors maintain it — ``pad_to`` preserves the
    pre-padding bound, ``seq.T`` carries the original plane count
    through the anti-diagonal staircase, ``identity`` is 0 — so the
    planner can route padded/staircase sequences to plane-skipping
    backends (``rotseq_batched``) whose cost scales with live planes
    rather than the padded grid.

    Registered as a JAX pytree: ``cos``/``sin``/``sign`` are children,
    ``reflect`` and ``k_live`` are static aux data.
    """

    cos: Any
    sin: Any
    sign: Any = None
    reflect: bool = False
    k_live: Optional[int] = None

    # -- pytree protocol ---------------------------------------------------
    def tree_flatten(self):
        return (self.cos, self.sin, self.sign), (self.reflect, self.k_live)

    @classmethod
    def tree_unflatten(cls, aux, children):
        cos, sin, sign = children
        reflect = aux[0]
        k_live = aux[1] if len(aux) > 1 else None
        return cls(cos, sin, sign, reflect, k_live)

    # -- shape / dtype -----------------------------------------------------
    @property
    def n(self) -> int:
        """Width of a compatible target matrix (``planes + 1``)."""
        return self.cos.shape[0] + 1

    @property
    def k(self) -> int:
        """Number of waves."""
        return self.cos.shape[1]

    @property
    def shape(self) -> Tuple[int, int]:
        return tuple(self.cos.shape)

    @property
    def dtype(self):
        return self.cos.dtype

    def __repr__(self) -> str:
        return (f"RotationSequence(n={self.n}, k={self.k}, "
                f"dtype={getattr(self.cos, 'dtype', '?')}, "
                f"sign={'per-entry' if self.sign is not None else None}, "
                f"reflect={self.reflect})")

    # -- constructors ------------------------------------------------------
    @classmethod
    def from_waves(cls, cos, sin, sign=None, *, reflect: bool = False,
                   normalize: str | bool = "auto") -> "RotationSequence":
        """Build from ``(n-1, k)`` wave arrays, validating the layout.

        ``normalize``: ``"auto"`` (default) renormalizes only entries
        whose ``c^2 + s^2`` drifts from 1 by more than ~64 ulp — exact
        pairs pass through bit-for-bit; ``True`` always divides by
        ``hypot(c, s)``; ``False`` stores the arrays untouched.
        """
        cos = jnp.asarray(cos)
        sin = jnp.asarray(sin)
        if cos.ndim != 2:
            raise ValueError(f"waves must be 2D (n-1, k), got {cos.shape}")
        if cos.shape != sin.shape:
            raise ValueError(
                f"cos/sin shape mismatch: {cos.shape} vs {sin.shape}")
        if sign is not None:
            sign = jnp.asarray(sign)
            if sign.shape != cos.shape:
                raise ValueError(
                    f"sign shape {sign.shape} != wave shape {cos.shape}")
        if normalize == "auto":
            r2 = cos * cos + sin * sin
            tol = _DRIFT_ULPS * jnp.finfo(
                r2.dtype if jnp.issubdtype(r2.dtype, jnp.floating)
                else jnp.float32).eps
            drift = jnp.abs(r2 - 1.0) > jnp.asarray(tol, r2.dtype)
            r = jnp.sqrt(jnp.where(r2 > 0, r2, 1.0))
            # a (0, 0) pair has no direction to rescale: repair it to the
            # identity rotation, like normalize=True does
            cos = jnp.where(drift, jnp.where(r2 > 0, cos / r, 1.0), cos)
            sin = jnp.where(drift, jnp.where(r2 > 0, sin / r, 0.0), sin)
        elif normalize:
            r = jnp.hypot(cos, sin)
            safe = r > 0
            rs = jnp.where(safe, r, 1.0)
            cos = jnp.where(safe, cos / rs, 1.0)
            sin = jnp.where(safe, sin / rs, 0.0)
        return cls(cos, sin, sign, reflect)

    @classmethod
    def from_pairs(cls, waves, *, reflect: bool = False) -> "RotationSequence":
        """Build from an iterable of per-wave columns.

        Each element is ``(c, s)`` or ``(c, s, g)`` with 1D arrays of a
        common length ``n-1``; waves are stacked along ``K`` in order.
        ``g`` columns may be ``None`` (all-rotation wave); if any wave
        carries signs the missing ones are filled with rotations.
        """
        waves = list(waves)
        if not waves:
            raise ValueError("from_pairs needs at least one wave; use "
                             "RotationSequence.identity for an empty one")
        cs, ss, gs = [], [], []
        for w in waves:
            c, s, g = (*w, None) if len(w) == 2 else w
            c = jnp.asarray(c).reshape(-1)
            s = jnp.asarray(s).reshape(-1)
            cs.append(c)
            ss.append(s)
            gs.append(None if g is None else jnp.asarray(g).reshape(-1))
        planes = cs[0].shape[0]
        for c, s in zip(cs, ss):
            if c.shape[0] != planes or s.shape[0] != planes:
                raise ValueError(
                    f"inconsistent wave lengths: {c.shape[0]} vs {planes}")
        sign = None
        if any(g is not None for g in gs):
            fill = jnp.full((planes,), _REFL if reflect else _ROT,
                            cs[0].dtype)
            sign = jnp.stack([fill if g is None else g for g in gs], axis=1)
        return cls.from_waves(jnp.stack(cs, axis=1), jnp.stack(ss, axis=1),
                              sign, reflect=reflect, normalize=False)

    @classmethod
    def identity(cls, n: int, k: int, dtype=jnp.float32) -> "RotationSequence":
        """``k`` identity waves on ``n`` columns (exact no-op)."""
        return cls(jnp.ones((n - 1, k), dtype), jnp.zeros((n - 1, k), dtype),
                   k_live=0)

    # -- composition -------------------------------------------------------
    @property
    def T(self) -> "RotationSequence":
        """The inverse sequence: ``seq.T.apply(seq.apply(A)) == A`` in
        exact arithmetic.

        ``Q^T`` is the product of per-plane transposes (``M(c, s, g)^T =
        M(c, g s, g)`` — each staying on its own column pair ``(j,
        j+1)``) in *reversed* total order.  Reversed wave-major order
        means descending ``j`` within descending ``p``, which re-packs
        into the wave-major layout as an anti-diagonal staircase: the
        rotation from ``(j, p)`` lands in wave ``q = (n-2-j) +
        (k-1-p)``, giving an ``(n-1, n+k-2)`` grid with identity
        padding off the staircase (``seq.T.T`` therefore applies the
        same transform as ``seq``, identity-padded wider).

        The result carries ``k_live``: the staircase holds exactly the
        original ``(n-1) * k`` planes (or the original bound if one was
        already known), so plane-skipping backends apply it at the cost
        of the *original* sequence, not the padded grid.
        """
        c_t, s_t, g_t, refl_t = _transpose_waves(
            self.cos, self.sin, self.sign, self.reflect)
        J, k = self.cos.shape
        live = self.k_live if self.k_live is not None else J * k
        return RotationSequence(c_t, s_t, g_t, refl_t, k_live=live)

    def __matmul__(self, other: "RotationSequence") -> "RotationSequence":
        """Concatenate along ``K``: applying ``seq1 @ seq2`` equals
        applying ``seq1`` then ``seq2`` (``A @ Q1 @ Q2``)."""
        if not isinstance(other, RotationSequence):
            return NotImplemented
        if self.cos.shape[0] != other.cos.shape[0]:
            raise ValueError(
                f"cannot compose sequences on {self.n} and {other.n} columns")
        cos = jnp.concatenate([self.cos, other.cos], axis=1)
        sin = jnp.concatenate([self.sin, other.sin], axis=1)
        live = None
        if self.k_live is not None and other.k_live is not None:
            live = self.k_live + other.k_live
        if (self.sign is None and other.sign is None
                and self.reflect == other.reflect):
            return RotationSequence(cos, sin, None, self.reflect,
                                    k_live=live)
        return RotationSequence(
            cos, sin,
            jnp.concatenate([self._sign_array(), other._sign_array()],
                            axis=1),
            False, k_live=live)

    def __getitem__(self, idx) -> "RotationSequence":
        """Wave slicing: ``seq[i:j]`` keeps waves ``i..j-1``."""
        if not isinstance(idx, slice):
            raise TypeError(
                "RotationSequence supports wave *slices* only (seq[i:j]); "
                "a single wave is seq[p:p+1]")
        return RotationSequence(
            self.cos[:, idx], self.sin[:, idx],
            None if self.sign is None else self.sign[:, idx], self.reflect)

    def pad_to(self, k_target: int) -> "RotationSequence":
        """Identity-pad to ``k_target`` waves (plan-cache-stable shapes).

        Padding waves are exact no-op *rotations*.  A plain (unsigned)
        sequence stays plain — padding into a signed serve bucket must
        not materialize a dense sign grid; the batch-stacking step
        broadcasts an implicit-identity sign lazily when (and only
        when) a genuinely sign-carrying batch needs one.  An
        all-reflector sequence is the exception and materializes its
        ``sign`` array (a padded reflector would not be a no-op — det
        is -1).  The pre-padding live-plane bound is preserved so the
        planner can skip the padding it just added.
        """
        pad = k_target - self.k
        if pad < 0:
            raise ValueError(f"cannot pad {self.k} waves down to {k_target}")
        if pad == 0:
            return self
        planes = self.cos.shape[0]
        live = self.k_live if self.k_live is not None else planes * self.k
        cos = jnp.concatenate(
            [self.cos, jnp.ones((planes, pad), self.cos.dtype)], axis=1)
        sin = jnp.concatenate(
            [self.sin, jnp.zeros((planes, pad), self.sin.dtype)], axis=1)
        if self.sign is None and not self.reflect:
            return RotationSequence(cos, sin, None, False, k_live=live)
        sign = jnp.concatenate(
            [self._sign_array(),
             jnp.full((planes, pad), _ROT, self.cos.dtype)], axis=1)
        return RotationSequence(cos, sin, sign, False, k_live=live)

    def _sign_array(self):
        """Per-entry sign array (``reflect`` folded in), built on demand.

        Implicit signs materialize *here*, not at admission: queued
        sequences keep ``sign=None`` and only the consumer that
        genuinely needs a grid (batch stacking of a sign-carrying
        bucket, transposition of a reflector) pays for one — and only
        at that moment.  (Under eager execution the broadcast still
        commits a device buffer; the saving is that implicit sequences
        sitting in queues or pad slots never do.)
        """
        if self.sign is not None:
            return self.sign
        return jnp.broadcast_to(
            jnp.asarray(_REFL if self.reflect else _ROT, self.cos.dtype),
            self.cos.shape)

    def with_signs(self) -> "RotationSequence":
        """Per-entry-sign normal form: ``sign`` materialized, ``reflect``
        folded in — for callers that need every sequence in a batch to
        present the same pytree structure.  (The serving path no longer
        calls this at admission: plain sequences stay implicit in the
        bucket queue and are sign-broadcast at stack time.)"""
        if self.sign is not None:
            return self
        return RotationSequence(self.cos, self.sin, self._sign_array(),
                                False, k_live=self.k_live)

    # -- serialization -----------------------------------------------------
    def to_dict(self) -> dict:
        """JSON-serializable dict (wave arrays as nested lists).

        Intended for small recorded sequences (warm-start state,
        request replay); large waves belong in array checkpoints.
        """
        import numpy as np

        return {
            "cos": np.asarray(self.cos).tolist(),
            "sin": np.asarray(self.sin).tolist(),
            "sign": None if self.sign is None
            else np.asarray(self.sign).tolist(),
            "reflect": bool(self.reflect),
            "dtype": str(self.dtype),
            "k_live": self.k_live,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "RotationSequence":
        """Inverse of :meth:`to_dict` (waves pass through bit-for-bit)."""
        import numpy as np

        dtype = jnp.dtype(d.get("dtype", "float32"))
        cos = jnp.asarray(np.asarray(d["cos"], dtype))
        sin = jnp.asarray(np.asarray(d["sin"], dtype))
        sign = d.get("sign")
        if sign is not None:
            sign = jnp.asarray(np.asarray(sign, dtype))
        seq = cls.from_waves(cos, sin, sign,
                             reflect=bool(d.get("reflect", False)),
                             normalize=False)
        k_live = d.get("k_live")
        if k_live is not None:
            seq = dataclasses.replace(seq, k_live=int(k_live))
        return seq

    # -- execution ---------------------------------------------------------
    def plan(self, like=None, *, m: Optional[int] = None,
             method: str = "auto", autotune: bool = False,
             platform: Optional[str] = None, sharded: bool = False,
             batch: Optional[int] = None, shared_sequence: bool = True,
             n_b: Optional[int] = None, k_b: Optional[int] = None,
             **kw) -> "SequencePlan":
        """Resolve the registry once into a frozen :class:`SequencePlan`.

        ``like`` (an array or ShapeDtypeStruct) supplies the target row
        count and dtype; ``m`` overrides the row count.  A 3D ``like``
        (``(b, m, n)``, a batched target for :meth:`SequencePlan.
        apply_batched`) supplies the batch count too; ``batch``
        overrides it.  ``shared_sequence=False`` declares the batch
        *per-request* — each target will carry its own sequence via
        ``apply_batched(A, sequences=...)`` — which prices per-sequence
        setup × b and can plan onto a different backend than the same
        batch sharing one sequence (docs/cost-model.md).
        ``method="auto"`` runs capability filtering + the
        SS6 cost model (or measured ``autotune``) through the per-shape
        plan cache — batch-aware, so a batch-64 bucket can plan onto a
        different backend than a single request; a named method keeps
        the seed defaults (``n_b=64, k_b=16`` for tiled backends).
        Explicit ``n_b``/``k_b`` always override the planned tiles.
        """
        _ensure_backends()
        like_shape = getattr(like, "shape", None)
        if like_shape is not None and len(like_shape) == 3:
            if batch is None:
                batch = like_shape[0]
            if m is None:
                m = like_shape[1]
        if m is None:
            m = like_shape[0] if like_shape is not None else max(self.n, 1)
        batch = 1 if batch is None else max(1, int(batch))
        dtype = getattr(like, "dtype", None) or self.dtype
        n, k = self.n, self.k
        if method != "auto":
            # validate the method name + sign capability even when the
            # sequence is empty, so typos never silently "succeed"
            spec = registry.get_backend(method)  # raises on unknown
            if self.sign is not None and not spec.capability.supports_signs:
                raise ValueError(
                    f"method {method!r} does not support per-entry signs; "
                    f"use a blocked-family backend")
        if n < 2 or k < 1 or m < 1:
            return SequencePlan(self, _IDENTITY, (), None)

        if method == "auto":
            with obs.span("plan", m=m, n=n, k=k, batch=batch) as sp:
                plan = registry.select_plan(
                    m, n, k, dtype=dtype, platform=platform,
                    signs=self.sign is not None, sharded=sharded,
                    batch=batch, shared_sequence=shared_sequence,
                    live_planes=self.k_live, autotune=autotune)
                sp.set(method=plan.method, source=plan.source)
            planned = plan.kwargs()
            if n_b is not None:
                planned["n_b"] = n_b
            if k_b is not None:
                planned["k_b"] = k_b
            planned.update(kw)
            return SequencePlan(self, plan.method,
                                tuple(sorted(planned.items())), plan)

        planned = dict(kw)
        if spec.candidates is not registry.no_tiles:  # tiled backend
            planned["n_b"] = 64 if n_b is None else n_b  # seed defaults
            planned["k_b"] = 16 if k_b is None else k_b
        return SequencePlan(self, method, tuple(sorted(planned.items())),
                            None)

    def apply(self, A, *, method: str = "auto", **kw):
        """One-shot convenience: ``seq.plan(like=A, ...).apply(A)``.

        For repeated applications at a fixed shape, hold the plan — that
        is the whole point of the two-phase API.
        """
        return self.plan(like=A, method=method, **kw).apply(A)


# sentinel backend name for degenerate (zero-rotation) plans
_IDENTITY = "identity"


@dataclasses.dataclass(frozen=True, eq=False)
class SequencePlan:
    """A frozen dispatch decision bound to one :class:`RotationSequence`.

    ``apply(A)`` calls the resolved backend directly — no registry
    lookup, no plan-cache probe — and is differentiable w.r.t. ``A``
    (``custom_vjp``: the cotangent is one application of the transposed
    sequence).  Rebind the same decision to fresh waves of the same
    shape with :meth:`rebind` (the delayed-buffer hot path).
    """

    sequence: RotationSequence
    method: str
    kwargs: Tuple[Tuple[str, Any], ...]
    plan: Optional[registry.Plan] = None

    def __repr__(self) -> str:
        return (f"SequencePlan(method={self.method!r}, "
                f"kwargs={dict(self.kwargs)}, seq={self.sequence!r})")

    def apply(self, A):
        """Apply the planned sequence: ``A <- A @ Q`` on the hot path.

        Differentiable w.r.t. ``A`` through every backend via the
        transposed-sequence ``custom_vjp``; the sequence arrays are
        treated as constants (zero cotangents).  Use
        :meth:`apply_direct` for the backend's native JAX autodiff.

        Backward-pass cost: ``seq.T`` re-packs ``k`` waves into an
        ``n + k - 2``-wave staircase, so one VJP costs roughly
        ``(n + k) / k`` forward applications — cheap for wide recordings
        (``k >~ n``), noticeable for small ``k``; prefer
        :meth:`apply_direct` for grad-heavy small-``k`` jnp workloads
        (a padding-free transpose kernel is a ROADMAP item).
        """
        self._check_target(A)
        if self.method == _IDENTITY:
            return A
        seq = self.sequence
        if not obs.enabled() or compat.is_tracer(A):
            return _apply_planned(self.method, self.kwargs, seq.reflect,
                                  A, seq.cos, seq.sin, seq.sign)
        with obs.span("apply", method=self.method, m=int(A.shape[0]),
                      n=int(A.shape[1])):
            t0 = obs.timing.now()
            out = _apply_planned(self.method, self.kwargs, seq.reflect,
                                 A, seq.cos, seq.sin, seq.sign)
            out = jax.block_until_ready(out)
            dt = obs.timing.now() - t0
        self._record_dispatch(A, dt)
        return out

    __call__ = apply

    def apply_direct(self, A):
        """Apply via the backend with no ``custom_vjp`` wrapping.

        Differentiation (where the backend supports it — the pure-jnp
        family) goes through the actual computation, so gradients
        w.r.t. the wave arrays are exact rather than symbolically zero.
        The compat wrapper ``apply_rotation_sequence`` uses this path to
        preserve the seed's autodiff semantics.
        """
        self._check_target(A)
        if self.method == _IDENTITY:
            return A
        seq = self.sequence
        if not obs.enabled() or compat.is_tracer(A):
            return _run_backend(self.method, self.kwargs, seq.reflect,
                                A, seq.cos, seq.sin, seq.sign)
        with obs.span("apply", method=self.method, direct=True):
            t0 = obs.timing.now()
            out = _run_backend(self.method, self.kwargs, seq.reflect,
                               A, seq.cos, seq.sin, seq.sign)
            out = jax.block_until_ready(out)
            dt = obs.timing.now() - t0
        self._record_dispatch(A, dt)
        return out

    def apply_batched(self, A, sequences=None, *, direct: bool = False):
        """Apply to a batch of targets ``A`` of shape ``(b, m, n)``.

        With ``sequences=None`` the plan's own sequence is applied to
        every batch element.  Rotations act row-wise, so most backends
        execute the *flattened* ``(b*m, n)`` problem — bit-identical to
        ``b`` separate :meth:`apply` calls; backends whose capability
        says ``batch_via="vmap"`` are mapped over the leading axis
        instead.

        With ``sequences`` (an iterable of ``b`` :class:`RotationSequence`
        objects of the plan's wave shape) each batch element gets its
        own waves — the serving path's shape-bucketed execution.
        Backends whose capability says ``batch_via="fused"`` (the
        ``rotseq_batched`` kernel) take the whole stack in **one
        launch**; otherwise the backend is ``jax.vmap``-ed over
        ``(A, cos, sin[, sign])`` where its capability allows
        (bit-identical to per-request application for the pure-jnp
        backends) and looped per element as the last resort.

        Sign structure: when the plan's sequence carries per-entry
        signs, batch members may be plain rotation sequences — their
        implicit-identity sign is broadcast at stack time, never
        materialized per request (bucket admission keeps queues
        implicit).  A signed member under an unsigned plan still
        raises, since the planned backend was not capability-checked
        for signs.

        Autodiff mirrors the single-target pair :meth:`apply` /
        :meth:`apply_direct` uniformly across every strategy:
        ``direct=False`` (default) differentiates w.r.t. ``A`` through
        the transposed-sequence ``custom_vjp`` (wave cotangents are
        symbolic zeros); ``direct=True`` calls the backend with its
        native JAX autodiff semantics.
        """
        A = jnp.asarray(A)
        if A.ndim != 3:
            raise ValueError(
                f"apply_batched expects A of shape (b, m, n); "
                f"got {A.shape} — use apply() for a single target")
        if self.method == _IDENTITY:
            return A
        if not obs.enabled() or compat.is_tracer(A):
            return self._apply_batched_impl(A, sequences, direct)
        with obs.span("apply_batched", method=self.method,
                      batch=int(A.shape[0]), m=int(A.shape[1]),
                      n=int(A.shape[2])):
            t0 = obs.timing.now()
            out = self._apply_batched_impl(A, sequences, direct)
            out = jax.block_until_ready(out)
            dt = obs.timing.now() - t0
        self._record_dispatch(A, dt, shared=sequences is None)
        return out

    def _apply_batched_impl(self, A, sequences, direct: bool):
        seq = self.sequence
        b, m, n = A.shape
        if n != seq.n:
            raise ValueError(
                f"plan built for n={seq.n} targets; got A.shape={A.shape}")
        run = _run_backend if direct else _apply_planned
        run_fused = _run_backend if direct else _apply_planned_batched
        cap = registry.get_backend(self.method).capability
        if sequences is None:
            if cap.batch_via == "fused":
                return run_fused(self.method, self.kwargs, seq.reflect,
                                 A, seq.cos, seq.sin, seq.sign)
            if cap.batch_via == "flatten":
                out = run(self.method, self.kwargs, seq.reflect,
                          A.reshape(b * m, n), seq.cos, seq.sin, seq.sign)
                return out.reshape(b, m, n)
            return jax.vmap(
                lambda Ai: run(self.method, self.kwargs, seq.reflect,
                               Ai, seq.cos, seq.sin, seq.sign))(A)

        seqs = list(sequences)
        if len(seqs) != b:
            raise ValueError(
                f"{len(seqs)} sequences for a batch of {b} targets")
        plan_signed = seq.sign is not None
        for s in seqs:
            if not isinstance(s, RotationSequence):
                raise TypeError(f"expected RotationSequence, got {type(s)}")
            if tuple(s.shape) != tuple(seq.shape):
                raise ValueError(
                    f"sequence shape {s.shape} != plan shape {seq.shape}; "
                    f"pad_to a bucket-stable wave count first")
            if plan_signed:
                continue  # any structure coerces to the sign grid below
            if s.sign is not None or s.reflect != seq.reflect:
                raise ValueError(
                    "mixed sign/reflect structure in one batch; plan the "
                    "bucket on a sign-carrying representative (or "
                    "normalize with RotationSequence.with_signs()) first")
        C, S, G = _stack_waves(seqs, plan_signed)
        if cap.batch_via == "fused":
            return run_fused(self.method, self.kwargs, seq.reflect,
                             A, C, S, G)
        if cap.supports_vmap:
            in_axes = (0, 0, 0, None if G is None else 0)
            return jax.vmap(
                lambda Ai, Ci, Si, Gi: run(
                    self.method, self.kwargs, seq.reflect, Ai, Ci, Si, Gi),
                in_axes=in_axes)(A, C, S, G)
        return jnp.stack([
            run(self.method, self.kwargs, seq.reflect,
                A[i], C[i], S[i], None if G is None else G[i])
            for i in range(b)])

    def _check_target(self, A):
        if self.method == _IDENTITY:
            return
        if A.ndim != 2 or A.shape[1] != self.sequence.n:
            raise ValueError(
                f"plan built for n={self.sequence.n} targets; "
                f"got A.shape={A.shape}")

    def _record_dispatch(self, A, measured_s: float,
                         shared: bool = True) -> None:
        """Roofline-attribute one completed host-side dispatch.

        Called only on the obs-enabled, non-traced path, *after* the
        result is device-complete: pairs the §6 cost model's predicted
        flops/bytes/seconds — including the per-sequence setup vs
        per-row stream split, priced per-request when the batch carried
        distinct sequences (``shared=False``) — for this exact
        (problem, backend, tile) with the measured wall time (see
        :mod:`repro.obs.roofline`).
        """
        seq = self.sequence
        if A.ndim == 3:
            b, m = int(A.shape[0]), int(A.shape[1])
        else:
            b, m = 1, int(A.shape[0])
        kw = dict(self.kwargs)
        problem = registry.Problem(
            m=m, n=seq.n, k=seq.k, dtype=str(A.dtype),
            platform=compat.default_platform(),
            signs=seq.sign is not None, batch=b, shared_sequence=shared,
            live_planes=seq.k_live)
        rplan = self.plan if self.plan is not None else registry.Plan(
            method=self.method, n_b=kw.get("n_b"), k_b=kw.get("k_b"),
            m_blk=kw.get("m_blk"))
        try:
            comp = registry.cost_components(self.method, problem, rplan)
        except ValueError:  # unregistered/identity method: no model
            comp = {"flops": 0.0, "bytes": 0.0, "seconds": 0.0,
                    "setup": {"seconds": 0.0}, "stream": {"seconds": 0.0}}
        obs.roofline.record_dispatch(
            backend=self.method, m_total=problem.m_total, n=seq.n,
            k=seq.k, batch=b, dtype=str(A.dtype),
            tile={key: val for key, val in kw.items()
                  if key in ("n_b", "k_b", "m_blk")},
            planes_live=problem.planes_live,
            planes_total=problem.planes_total,
            predicted_flops=comp["flops"], predicted_bytes=comp["bytes"],
            predicted_s=comp["seconds"], measured_s=measured_s,
            predicted_setup_s=comp["setup"]["seconds"],
            predicted_stream_s=comp["stream"]["seconds"],
            shared_sequence=shared)
        obs.inc("sequence.applies")
        obs.observe("sequence.apply_seconds", measured_s)

    def rebind(self, sequence: RotationSequence) -> "SequencePlan":
        """Bind this (method, tiles) decision to a new same-shape sequence."""
        old = self.sequence
        if sequence.shape != old.shape:
            raise ValueError(
                f"rebind needs matching wave shape {old.shape}; "
                f"got {sequence.shape}")
        if (sequence.sign is None) != (old.sign is None) and \
                self.method != _IDENTITY:
            spec = registry.get_backend(self.method)
            if sequence.sign is not None and \
                    not spec.capability.supports_signs:
                raise ValueError(
                    f"plan method {self.method!r} cannot carry per-entry "
                    f"signs; re-plan the sign-carrying sequence")
        return dataclasses.replace(self, sequence=sequence)

    # -- serialization -----------------------------------------------------
    def to_dict(self) -> dict:
        """Serialize the *dispatch decision* (not the waves) to JSON.

        The dict captures everything a warm process needs to skip
        planning: backend method, resolved kwargs, the registry
        :class:`~repro.core.registry.Plan` record, and the wave
        shape/dtype/sign signature the decision was made for.  It is
        keyed to the running JAX version (mirroring the persisted plan
        cache — measured decisions do not transfer across compilers);
        :meth:`from_dict` rejects stale or mismatched entries.
        """
        seq = self.sequence
        d = {
            "format": PLAN_DICT_FORMAT,
            "jax": registry._jax_version_str(),
            "method": self.method,
            "kwargs": dict(self.kwargs),
            "shape": list(seq.shape),
            "dtype": str(seq.dtype),
            "signed": seq.sign is not None,
            "reflect": bool(seq.reflect),
        }
        if self.plan is not None:
            d["plan"] = {"method": self.plan.method, "n_b": self.plan.n_b,
                         "k_b": self.plan.k_b, "m_blk": self.plan.m_blk,
                         "est_seconds": self.plan.est_seconds,
                         "source": self.plan.source}
        return d

    @classmethod
    def from_dict(cls, d: dict, sequence: RotationSequence) -> "SequencePlan":
        """Rebuild a frozen plan from :meth:`to_dict`, bound to ``sequence``.

        Raises ``ValueError`` when the entry is unusable: unknown
        format, different JAX version, wave-shape/dtype/sign mismatch
        with ``sequence``, or a backend that is no longer registered.
        Callers holding persisted plans should treat the error as a
        cache miss and re-plan.
        """
        _ensure_backends()
        if d.get("format") != PLAN_DICT_FORMAT:
            raise ValueError(
                f"unsupported SequencePlan dict format {d.get('format')!r}")
        jax_now = registry._jax_version_str()
        if d.get("jax") != jax_now:
            raise ValueError(
                f"plan serialized under JAX {d.get('jax')!r}; running "
                f"{jax_now} — re-plan (measured decisions do not transfer)")
        if tuple(d.get("shape", ())) != tuple(sequence.shape):
            raise ValueError(
                f"plan serialized for wave shape {d.get('shape')}; "
                f"sequence has {sequence.shape}")
        if d.get("signed", False) != (sequence.sign is not None) \
                or d.get("reflect", False) != bool(sequence.reflect):
            raise ValueError(
                "plan serialized for a different sign/reflect structure")
        if d.get("dtype") != str(sequence.dtype):
            raise ValueError(
                f"plan serialized for dtype {d.get('dtype')!r}; "
                f"sequence is {sequence.dtype}")
        method = d["method"]
        if method != _IDENTITY:
            spec = registry.get_backend(method)  # raises on unknown
            if sequence.sign is not None \
                    and not spec.capability.supports_signs:
                raise ValueError(
                    f"serialized method {method!r} cannot carry signs")
        kwargs = tuple(sorted(d.get("kwargs", {}).items()))
        plan = None
        pd = d.get("plan")
        if pd is not None:
            plan = registry.Plan(
                method=str(pd.get("method", method)), n_b=pd.get("n_b"),
                k_b=pd.get("k_b"), m_blk=pd.get("m_blk"),
                est_seconds=float(pd.get("est_seconds", 0.0)),
                source="persisted")
        return cls(sequence, method, kwargs, plan)


# JSON format version of SequencePlan.to_dict (bump on layout change)
PLAN_DICT_FORMAT = 1


# --------------------------------------------------------------------------
# planned application with a transposed-sequence VJP
# --------------------------------------------------------------------------

def _transpose_waves(cos, sin, sign, reflect: bool):
    """Anti-diagonal staircase repack of one ``(n-1, k)`` wave grid.

    The pure-function core of :attr:`RotationSequence.T` (vmapped by
    the batched VJP over per-request stacks).  Returns
    ``(c_t, s_t, g_t, reflect_t)`` where ``g_t`` is ``None`` for plain
    rotation inputs and a materialized sign grid otherwise (identity
    padding off the staircase must stay a rotation no-op).
    """
    J, k = cos.shape
    if sign is None:
        s_signed = sin if reflect else -sin
    else:
        s_signed = jnp.where(sign > 0, sin, -sin)
    j = jnp.arange(J)[:, None]
    q = jnp.arange(J + k - 1)[None, :]
    p_idx = (J - 1 - j) + (k - 1) - q
    valid = (p_idx >= 0) & (p_idx < k)
    pc = jnp.clip(p_idx, 0, k - 1)
    jb = jnp.broadcast_to(j, pc.shape)
    c_t = jnp.where(valid, cos[jb, pc], jnp.ones((), cos.dtype))
    s_t = jnp.where(valid, s_signed[jb, pc], jnp.zeros((), sin.dtype))
    g_t = None
    if sign is not None:
        g_t = jnp.where(valid, sign[jb, pc], jnp.asarray(_ROT, sign.dtype))
    elif reflect:
        # identity padding must stay a rotation no-op (a padded
        # reflector has det -1), so materialize the sign grid
        g_t = jnp.where(valid, jnp.asarray(_REFL, cos.dtype),
                        jnp.asarray(_ROT, cos.dtype))
    return c_t, s_t, g_t, (False if g_t is not None else reflect)


def _stack_waves(seqs, plan_signed: bool):
    """Stack per-request waves into ``(b, planes, k)`` batch arrays.

    On the concrete (serving) path the stack happens in **numpy** — one
    memcpy per array instead of one traced ``jnp.stack`` op over ``b``
    operands, which dominates the per-batch host time at serving batch
    sizes.  The bytes are identical either way (stacking reorders
    storage, never values), so the streamed-vs-synchronous bitwise
    contract is untouched; the batch arrays convert to device buffers
    once at the backend call boundary.  Traced leaves (a transformed
    caller) keep the ``jnp.stack`` path.
    """
    leaves = [x for s in seqs for x in (s.cos, s.sin, s.sign)
              if x is not None]
    if any(compat.is_tracer(x) for x in leaves):
        C = jnp.stack([s.cos for s in seqs])
        S = jnp.stack([s.sin for s in seqs])
        G = None if not plan_signed \
            else jnp.stack([s._sign_array() for s in seqs])
        return C, S, G
    C = np.stack([np.asarray(s.cos) for s in seqs])
    S = np.stack([np.asarray(s.sin) for s in seqs])
    G = None if not plan_signed \
        else np.stack([np.asarray(s._sign_array()) for s in seqs])
    return C, S, G


def _run_backend(method: str, kwargs: Tuple[Tuple[str, Any], ...],
                 reflect: bool, A, C, S, G):
    spec = registry.get_backend(method)
    return spec.fn(A, C, S, reflect=reflect, G=G, **dict(kwargs))


@partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _apply_planned(method, kwargs, reflect, A, C, S, G):
    return _run_backend(method, kwargs, reflect, A, C, S, G)


def _apply_planned_fwd(method, kwargs, reflect, A, C, S, G):
    out = _run_backend(method, kwargs, reflect, A, C, S, G)
    return out, (C, S, G)


def _apply_planned_bwd(method, kwargs, reflect, residuals, dY):
    C, S, G = residuals
    seq_t = RotationSequence(C, S, G, reflect).T
    bwd_method, bwd_kwargs = method, kwargs
    if seq_t.sign is not None and \
            not registry.get_backend(method).capability.supports_signs:
        # transposing an all-reflector sequence materializes a mixed
        # sign grid; route the cotangent through the blocked family
        bwd_method, bwd_kwargs = "blocked", tuple(
            (key, val) for key, val in kwargs if key in ("n_b", "k_b"))
    dA = _run_backend(bwd_method, bwd_kwargs, seq_t.reflect,
                      dY, seq_t.cos, seq_t.sin, seq_t.sign)
    # The sequence is a constant of the application (symbolic-zero
    # cotangents): exact angle gradients would need the rotation tape.
    return (dA, jnp.zeros_like(C), jnp.zeros_like(S),
            None if G is None else jnp.zeros_like(G))


_apply_planned.defvjp(_apply_planned_fwd, _apply_planned_bwd)


# --------------------------------------------------------------------------
# fused batched application (batch_via="fused" backends) with the same
# transposed-sequence VJP semantics as the per-target path
# --------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _apply_planned_batched(method, kwargs, reflect, A, C, S, G):
    return _run_backend(method, kwargs, reflect, A, C, S, G)


def _apply_planned_batched_fwd(method, kwargs, reflect, A, C, S, G):
    out = _run_backend(method, kwargs, reflect, A, C, S, G)
    return out, (C, S, G)


def _apply_planned_batched_bwd(method, kwargs, reflect, residuals, dY):
    C, S, G = residuals
    if C.ndim == 2:
        c_t, s_t, g_t, refl_t = _transpose_waves(C, S, G, reflect)
    elif G is None and not reflect:
        # plain rotation stacks transpose to plain rotation staircases
        tw = lambda c, s: _transpose_waves(c, s, None, False)[:2]
        c_t, s_t = jax.vmap(tw)(C, S)
        g_t, refl_t = None, False
    else:
        # sign-carrying (or all-reflector) stacks materialize the
        # transposed sign grid per request; g_t presence is static in
        # (G, reflect), so the vmap output structure is uniform
        if G is None:
            tw = lambda c, s: _transpose_waves(c, s, None, True)[:3]
            c_t, s_t, g_t = jax.vmap(tw)(C, S)
        else:
            tw = lambda c, s, g: _transpose_waves(c, s, g, reflect)[:3]
            c_t, s_t, g_t = jax.vmap(tw)(C, S, G)
        refl_t = False
    # fused backends declare supports_signs (capability-checked at
    # registration); no blocked reroute is needed here, unlike the
    # reflect-through-unblocked case in _apply_planned_bwd
    dA = _run_backend(method, kwargs, refl_t, dY, c_t, s_t, g_t)
    return (dA, jnp.zeros_like(C), jnp.zeros_like(S),
            None if G is None else jnp.zeros_like(G))


_apply_planned_batched.defvjp(_apply_planned_batched_fwd,
                              _apply_planned_batched_bwd)


# --------------------------------------------------------------------------
# shard-local execution hooks (repro.dist)
# --------------------------------------------------------------------------
#
# ``repro.dist`` executes shard-local work through the exact same
# planned ``custom_vjp`` pair as the single-device paths — called from
# *inside* ``shard_map``, so gradients flow shard-locally into the
# transposed-sequence VJP with zero extra collectives (rotations act on
# column pairs; row shards differentiate independently).  These are the
# sanctioned planned-execution entry points for the dist layer, which
# never imports kernel modules directly (analyzer rule RA206).

def planned_apply(method, kwargs, reflect, A, C, S, G):
    """Planned single-target application (``custom_vjp`` w.r.t. ``A``).

    ``method``/``kwargs``/``reflect`` are the static fields of a
    resolved :class:`SequencePlan`; ``A`` is a ``(m, n)`` target and
    ``C``/``S``/``G`` the ``(n-1, k)`` wave arrays (``G`` may be
    ``None``).
    """
    return _apply_planned(method, kwargs, reflect, A, C, S, G)


def planned_apply_batched(method, kwargs, reflect, A, C, S, G):
    """Planned fused batched application (``custom_vjp`` w.r.t. ``A``).

    ``A`` is ``(b, m, n)``; waves are shared ``(n-1, k)`` or stacked
    ``(b, n-1, k)`` per-request grids (see :func:`stack_request_waves`).
    """
    return _apply_planned_batched(method, kwargs, reflect, A, C, S, G)


def planned_run(method, kwargs, reflect, A, C, S, G):
    """Planned application with the backend's *native* autodiff.

    The shard-local analogue of :meth:`SequencePlan.apply_direct` — no
    ``custom_vjp`` wrapping, so gradients w.r.t. the wave arrays go
    through the actual computation where the backend supports it.
    """
    return _run_backend(method, kwargs, reflect, A, C, S, G)


def stack_request_waves(seqs, plan_signed: bool):
    """Stack ``b`` per-request sequences into ``(b, n-1, k)`` wave arrays.

    The public face of the serving path's stacker for out-of-package
    batched executors (``repro.dist``): numpy memcpy on the concrete
    path, ``jnp.stack`` under tracing, implicit-identity signs
    broadcast only when ``plan_signed``.
    """
    return _stack_waves(seqs, plan_signed)
