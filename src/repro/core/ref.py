"""Reference implementations of rotation-sequence application.

``rot_sequence_numpy``        — Algorithm 1.2, pure numpy, float64: the oracle.
``rot_sequence_unoptimized``  — Algorithm 1.2 in JAX (fori_loop), jit-able.
``rot_sequence_wavefront``    — Algorithm 1.3 (wavefront order) in JAX.

All three are mathematically identical; the wavefront version re-orders the
rotations along anti-diagonals of the ``(j, p)`` grid, which is legal because
rotations only need to respect the partial order
``(j, p) < (j+1, p)`` and ``(j+1, p) < (j, p+1)``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "rot_sequence_numpy",
    "rot_sequence_unoptimized",
    "rot_sequence_wavefront",
    "reflector_sequence_numpy",
]


def rot_sequence_numpy(A, C, S, reflect: bool = False) -> np.ndarray:
    """Algorithm 1.2 in numpy (float64 accumulate). The test oracle."""
    A = np.array(A, dtype=np.float64, copy=True)
    C = np.asarray(C, dtype=np.float64)
    S = np.asarray(S, dtype=np.float64)
    n = A.shape[1]
    assert C.shape[0] == n - 1, (C.shape, A.shape)
    for p in range(C.shape[1]):
        for j in range(n - 1):
            c, s = C[j, p], S[j, p]
            x = A[:, j].copy()
            y = A[:, j + 1].copy()
            if reflect:
                A[:, j] = c * x + s * y
                A[:, j + 1] = s * x - c * y
            else:
                A[:, j] = c * x + s * y
                A[:, j + 1] = -s * x + c * y
    return A


def reflector_sequence_numpy(A, C, S) -> np.ndarray:
    """2x2 reflector variant (paper SS8.4): ``[[c, s], [s, -c]]`` per plane."""
    return rot_sequence_numpy(A, C, S, reflect=True)


def _rot_cols(A, j, c, s, g):
    """Apply one plane transform to columns ``(j, j+1)`` of ``A``.

    Unified update ``y' = g * (s*x - c*y)``: ``g = -1`` is a rotation,
    ``g = +1`` a 2x2 reflector.
    """
    xy = jax.lax.dynamic_slice_in_dim(A, j, 2, axis=1)  # (m, 2)
    x = xy[:, 0]
    y = xy[:, 1]
    xn = c * x + s * y
    yn = g * (s * x - c * y)
    return jax.lax.dynamic_update_slice_in_dim(
        A, jnp.stack([xn, yn], axis=1), j, axis=1
    )


@partial(jax.jit, static_argnames=("reflect",))
def rot_sequence_unoptimized(A, C, S, reflect: bool = False):
    """Algorithm 1.2 with ``fori_loop`` over ``p`` (outer) and ``j`` (inner)."""
    n = A.shape[1]
    k = C.shape[1]
    g = jnp.asarray(1.0 if reflect else -1.0, A.dtype)

    def wave(p, A):
        def body(j, A):
            return _rot_cols(A, j, C[j, p].astype(A.dtype),
                             S[j, p].astype(A.dtype), g)

        return jax.lax.fori_loop(0, n - 1, body, A)

    return jax.lax.fori_loop(0, k, wave, A)


@partial(jax.jit, static_argnames=("reflect",))
def rot_sequence_wavefront(A, C, S, reflect: bool = False):
    """Algorithm 1.3: anti-diagonal (wavefront) order.

    Diagonal ``d`` applies rotations ``(j, p)`` with ``j + p = d`` in order of
    ascending ``p``.  Out-of-range entries are skipped via identity rotations
    (c=1, s=0), the same trick the blocked algorithms use for the startup and
    shutdown triangles.
    """
    n = A.shape[1]
    k = C.shape[1]

    def diag(d, A):
        def body(p, A):
            j = d - p
            valid = (j >= 0) & (j <= n - 2)
            jc = jnp.clip(j, 0, n - 2)
            c = jnp.where(valid, C[jc, p], 1.0).astype(A.dtype)
            s = jnp.where(valid, S[jc, p], 0.0).astype(A.dtype)
            # padding must stay a no-op => rotation sign (-1) when invalid
            g = jnp.where(valid & reflect, 1.0, -1.0).astype(A.dtype)
            return _rot_cols(A, jc, c, s, g)

        return jax.lax.fori_loop(0, k, body, A)

    return jax.lax.fori_loop(0, (n - 2) + (k - 1) + 1, diag, A)
