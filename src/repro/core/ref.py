"""Reference implementations of rotation-sequence application.

``rot_sequence_numpy``        — Algorithm 1.2, pure numpy, float64: the oracle.
``rot_sequence_unoptimized``  — Algorithm 1.2 in JAX (fori_loop), jit-able.
``rot_sequence_wavefront``    — Algorithm 1.3 (wavefront order) in JAX.

All three are mathematically identical; the wavefront version re-orders the
rotations along anti-diagonals of the ``(j, p)`` grid, which is legal because
rotations only need to respect the partial order
``(j, p) < (j+1, p)`` and ``(j+1, p) < (j, p+1)``.

Bit-stability: every path evaluates the 2x2 plane transform through
:func:`repro.core.rotations.plane_update` with the rotation/reflector
sign held as a *runtime array* — the scalar ``reflect=True`` flag is
normalized to a ``+1`` sign grid rather than a foldable scalar constant,
so the scalar-reflect and sign-grid paths compile to the same evaluation
order and agree to the last bit (the ROADMAP "bitwise-stable reflector
normalization" contract).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.rotations import plane_update

__all__ = [
    "rot_sequence_numpy",
    "rot_sequence_unoptimized",
    "rot_sequence_wavefront",
    "reflector_sequence_numpy",
]


def rot_sequence_numpy(A, C, S, reflect: bool = False,
                       G=None) -> np.ndarray:
    """Algorithm 1.2 in numpy (float64 accumulate). The test oracle.

    Evaluates the canonical :func:`~repro.core.rotations.plane_update`
    order with the sign materialized per entry, exactly like the jnp
    and Pallas paths (numpy has no constant folding, so the unified
    order is bit-identical to the seed's branched formulas).
    """
    A = np.array(A, dtype=np.float64, copy=True)
    C = np.asarray(C, dtype=np.float64)
    S = np.asarray(S, dtype=np.float64)
    n = A.shape[1]
    assert C.shape[0] == n - 1, (C.shape, A.shape)
    if G is None:
        G = np.full(C.shape, 1.0 if reflect else -1.0)
    else:
        G = np.asarray(G, dtype=np.float64)
    for p in range(C.shape[1]):
        for j in range(n - 1):
            c, s, g = C[j, p], S[j, p], G[j, p]
            x = A[:, j].copy()
            y = A[:, j + 1].copy()
            A[:, j], A[:, j + 1] = plane_update(x, y, c, s, g)
    return A


def reflector_sequence_numpy(A, C, S) -> np.ndarray:
    """2x2 reflector variant (paper SS8.4): ``[[c, s], [s, -c]]`` per plane."""
    return rot_sequence_numpy(A, C, S, reflect=True)


def _rot_cols(A, j, c, s, g):
    """Apply one plane transform to columns ``(j, j+1)`` of ``A``.

    Unified update ``y' = g * (s*x - c*y)``: ``g = -1`` is a rotation,
    ``g = +1`` a 2x2 reflector.  ``g`` must carry a runtime array value
    (see :func:`repro.core.rotations.plane_update`).
    """
    xy = jax.lax.dynamic_slice_in_dim(A, j, 2, axis=1)  # (m, 2)
    xn, yn = plane_update(xy[:, 0], xy[:, 1], c, s, g)
    return jax.lax.dynamic_update_slice_in_dim(
        A, jnp.stack([xn, yn], axis=1), j, axis=1
    )


def _sign_grid(C, reflect: bool, G):
    """Per-entry sign array for the signed families, or ``None``.

    Plain rotations (``G is None`` and not ``reflect``) keep the seed's
    scalar ``g = -1`` fast path — no per-plane gather, and a constant
    ``-1`` multiplicand in the ``g*(s*x - c*y)`` form is bit-identical
    to the runtime ``-1`` array (negation commutes with rounding).
    Reflector/sign paths must carry a runtime *array*: a foldable
    scalar ``+1`` is exactly the low-order-bit divergence
    :func:`~repro.core.rotations.plane_update` documents.
    """
    if G is not None:
        return G
    if reflect:
        return jnp.full(C.shape, 1.0, C.dtype)
    return None


@partial(jax.jit, static_argnames=("reflect",))
def rot_sequence_unoptimized(A, C, S, reflect: bool = False, G=None):
    """Algorithm 1.2 with ``fori_loop`` over ``p`` (outer) and ``j`` (inner)."""
    n = A.shape[1]
    k = C.shape[1]
    G = _sign_grid(C, reflect, G)
    g_rot = jnp.asarray(-1.0, A.dtype)

    def wave(p, A):
        def body(j, A):
            g = g_rot if G is None else G[j, p].astype(A.dtype)
            return _rot_cols(A, j, C[j, p].astype(A.dtype),
                             S[j, p].astype(A.dtype), g)

        return jax.lax.fori_loop(0, n - 1, body, A)

    return jax.lax.fori_loop(0, k, wave, A)


@partial(jax.jit, static_argnames=("reflect",))
def rot_sequence_wavefront(A, C, S, reflect: bool = False, G=None):
    """Algorithm 1.3: anti-diagonal (wavefront) order.

    Diagonal ``d`` applies rotations ``(j, p)`` with ``j + p = d`` in order of
    ascending ``p``.  Out-of-range entries are skipped via identity rotations
    (c=1, s=0), the same trick the blocked algorithms use for the startup and
    shutdown triangles.
    """
    n = A.shape[1]
    k = C.shape[1]
    G = _sign_grid(C, reflect, G)

    def diag(d, A):
        def body(p, A):
            j = d - p
            valid = (j >= 0) & (j <= n - 2)
            jc = jnp.clip(j, 0, n - 2)
            c = jnp.where(valid, C[jc, p], 1.0).astype(A.dtype)
            s = jnp.where(valid, S[jc, p], 0.0).astype(A.dtype)
            if G is None:
                g = jnp.asarray(-1.0, A.dtype)
            else:
                # padding must stay a no-op => rotation sign when invalid
                g = jnp.where(valid, G[jc, p],
                              jnp.asarray(-1.0, G.dtype)).astype(A.dtype)
            return _rot_cols(A, jc, c, s, g)

        return jax.lax.fori_loop(0, k, body, A)

    return jax.lax.fori_loop(0, (n - 2) + (k - 1) + 1, diag, A)
