"""Jacobi eigensolver built on rotation/reflector sequences.

Adjacent-pivot Jacobi with the Brent-Luk odd-even (round-robin) ordering:
each wave zeroes all disjoint adjacent pairs ``(j, j+1)`` (even ``j`` on
even waves, odd ``j`` on odd waves) and *swaps* the pair so that every
index pair becomes adjacent over a full cycle of ``n`` waves — plain
adjacent-pivot Jacobi without swapping does not converge (e.g. a matrix
whose only off-diagonal mass sits at ``(0, 2)``).

The rotation-then-swap ``G(c, s) @ PI`` is exactly a 2x2 *reflector*
``[[c', s'], [s', -c']]`` with ``(c', s') = (-s, c)`` — the paper's SS8.4
variant.  The solver therefore records its pivots as a reflector sequence
in the paper's ``(n-1, K)`` ``C``/``S`` layout, and the accumulated
eigenvector basis is recovered by *applying the recorded sequence to the
identity* with any of the optimized appliers — the "delayed sequences of
rotations" use-case (paper SS5.1) that motivates the whole library.

Used by ``repro.optim.soap_givens`` to maintain preconditioner eigenbases.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.rotations import plane_update

__all__ = ["JacobiResult", "jacobi_eigh", "jacobi_apply_basis"]


class JacobiResult(NamedTuple):
    eigenvalues: jax.Array  # (n,) unsorted (round-robin permuted)
    cos: jax.Array          # (n-1, K) recorded mixed sequence
    sin: jax.Array          # (n-1, K)
    sign: jax.Array         # (n-1, K) +1 reflector pivot / -1 no-op rotation
    off_norm: jax.Array     # final off-diagonal Frobenius norm

    def rotation_sequence(self):
        """The recorded pivots as a first-class ``RotationSequence``."""
        from .sequence import RotationSequence

        return RotationSequence(self.cos, self.sin, self.sign)


def _wave_pairs(n: int, parity):
    """Mask of valid pivot positions ``j`` for a wave of given parity."""
    j = jnp.arange(n - 1)
    return (j % 2) == (parity % 2)


def _pivot_coeffs(H, parity):
    """Reflector coefficients zeroing ``H[j, j+1]`` for all disjoint pairs.

    Returns ``(c, s)`` of shape ``(n-1,)`` in the reflector convention;
    invalid (off-parity) positions get the no-op rotation.
    """
    n = H.shape[0]
    j = jnp.arange(n - 1)
    hjj = jnp.diagonal(H)[:-1]
    hkk = jnp.diagonal(H)[1:]
    hjk = jnp.diagonal(H, offset=1)
    # stable inner rotation (|theta| <= pi/4, Golub & Van Loan sym.schur2
    # adapted to our G = [[c, -s], [s, c]] convention): zeroes
    # (G^T B G)_{01} for B = [[a, b], [b, d]] via tau = (a - d) / (2 b)
    b_safe = jnp.where(jnp.abs(hjk) > 0, hjk, 1.0)
    tau = (hjj - hkk) / (2.0 * b_safe)
    t = jnp.sign(tau) / (jnp.abs(tau) + jnp.hypot(1.0, tau))
    t = jnp.where(tau == 0, 1.0, t)
    c = 1.0 / jnp.hypot(1.0, t)
    s = t * c
    # b == 0: pair already diagonal -> plain swap is still applied via the
    # reflector (keeps the round-robin schedule intact)
    c = jnp.where(jnp.abs(hjk) > 0, c, 1.0)
    s = jnp.where(jnp.abs(hjk) > 0, s, 0.0)
    # rotation-then-swap G([[c,-s],[s,c]]) @ PI == reflector [[-s,c],[c,s]],
    # i.e. (c', s') = (-s, c) in the x' = c'x + s'y ; y' = s'x - c'y form
    cr = -s
    sr = c
    valid = _wave_pairs(n, parity)
    cr = jnp.where(valid, cr, 1.0)
    sr = jnp.where(valid, sr, 0.0)
    gr = jnp.where(valid, 1.0, -1.0)  # reflector sign / no-op padding
    return cr, sr, gr


@partial(jax.jit, static_argnames=("cycles",))
def jacobi_eigh(H0, *, cycles: int = 8) -> JacobiResult:
    """Symmetric eigendecomposition by round-robin adjacent Jacobi.

    Args:
      H0: symmetric ``(n, n)`` (float32/float64).
      cycles: full odd-even cycles; each cycle is ``n`` waves.  ~8 cycles
        reaches f32 machine precision for well-conditioned inputs.

    Returns ``JacobiResult`` with the recorded reflector sequence of
    ``K = cycles * n`` waves.  ``V = apply(I, cos, sin, reflect=True)``
    satisfies ``V^T H0 V = diag(eigenvalues)``.
    """
    n = H0.shape[0]
    K = cycles * n
    dtype = H0.dtype

    jidx = jnp.arange(0, n - 1, 2)

    def wave(p, state):
        H, C, S, G = state
        c, s, g = _pivot_coeffs(H, p)

        # apply column pass (H @ R) on disjoint pairs, vectorized:
        even = (p % 2) == 0
        start = jnp.where(even, 0, 1)
        npairs = (n - 1 + 1) // 2  # upper bound on pairs per wave
        pj = jnp.minimum(start + 2 * jnp.arange(npairs), n - 2)
        cc = c[pj][None, :]
        ss = s[pj][None, :]
        gg = g[pj][None, :]

        def col_pass(M):
            x = M[:, pj]
            y = M[:, pj + 1]
            xn, yn = plane_update(x, y, cc, ss, gg)
            M = M.at[:, pj].set(xn)
            return M.at[:, pj + 1].set(yn)

        H = col_pass(H)          # H @ R
        H = col_pass(H.T).T      # R^T (H R)
        C = C.at[:, p].set(c.astype(dtype))
        S = S.at[:, p].set(s.astype(dtype))
        G = G.at[:, p].set(g.astype(dtype))
        return (H, C, S, G)

    C0 = jnp.ones((n - 1, K), dtype)
    S0 = jnp.zeros((n - 1, K), dtype)
    G0 = jnp.full((n - 1, K), -1.0, dtype)
    H, C, S, G = jax.lax.fori_loop(0, K, wave, (H0, C0, S0, G0))
    off = jnp.linalg.norm(H - jnp.diag(jnp.diagonal(H)))
    return JacobiResult(jnp.diagonal(H), C, S, G, off)


def jacobi_apply_basis(res: JacobiResult, M=None, *, method="auto",
                       n_b: int | None = None, k_b: int | None = None,
                       **kw):
    """Apply the recorded pivot sequence to ``M`` (default: identity).

    ``jacobi_apply_basis(res)`` returns the eigenvector matrix ``V``;
    ``jacobi_apply_basis(res, G)`` computes ``G @ V`` without forming ``V``
    — the paper's "delayed sequence" application.  The recorded pivots
    travel as a ``RotationSequence``; dispatch goes through
    ``seq.plan``: the default ``method="auto"`` lets the cost model +
    plan cache pick the backend and tiles for this shape (the
    sign-carrying sequence restricts it to the blocked family); a named
    method keeps the seed defaults ``n_b=64, k_b=16``.
    """
    seq = res.rotation_sequence()
    if M is None:
        M = jnp.eye(seq.n, dtype=res.cos.dtype)
    # apply_direct keeps the backend's native autodiff (gradients w.r.t.
    # the recorded waves stay exact, as before the typed migration); the
    # constant-sequence custom_vjp is opt-in via seq.plan(...).apply
    return seq.plan(like=M, method=method, n_b=n_b, k_b=k_b,
                    **kw).apply_direct(M)
