"""The paper's primary contribution: rotation-sequence application.

Submodules: ``ref`` (Alg 1.2/1.3 oracles), ``blocked`` (SS2/SS5 blocking),
``accumulate`` (rs_gemm/MXU), ``distributed`` (shard_map row/column
sharding), ``jacobi`` (eigensolver consumer), ``api`` (dispatch).
"""
from .api import METHODS, apply_rotation_sequence
from .jacobi import JacobiResult, jacobi_apply_basis, jacobi_eigh
from .rotations import (RotationSequence, givens, identity_sequence,
                        random_sequence, sequence_to_dense)

__all__ = [
    "METHODS", "apply_rotation_sequence",
    "JacobiResult", "jacobi_apply_basis", "jacobi_eigh",
    "RotationSequence", "givens", "identity_sequence", "random_sequence",
    "sequence_to_dense",
]
