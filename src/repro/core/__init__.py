"""The paper's primary contribution: rotation-sequence application.

The first-class object is :class:`~repro.core.sequence.RotationSequence`
(``sequence``): plan once with ``seq.plan(like=A)``, apply many with the
frozen :class:`~repro.core.sequence.SequencePlan`.  Submodules: ``ref``
(Alg 1.2/1.3 oracles), ``blocked`` (SS2/SS5 blocking), ``accumulate``
(rs_gemm/MXU), ``distributed`` (shard_map row/column sharding),
``jacobi`` (eigensolver consumer), ``api`` (backend registration + the
raw-array compat wrapper).
"""
from .api import METHODS, apply_rotation_sequence
from .jacobi import JacobiResult, jacobi_apply_basis, jacobi_eigh
from .rotations import (RotationSequence, givens, identity_sequence,
                        random_sequence, sequence_to_dense)
from .sequence import SequencePlan

__all__ = [
    "METHODS", "apply_rotation_sequence",
    "JacobiResult", "jacobi_apply_basis", "jacobi_eigh",
    "RotationSequence", "SequencePlan", "givens", "identity_sequence",
    "random_sequence", "sequence_to_dense",
]
