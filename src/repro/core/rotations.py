"""Plane (Givens) rotation sequences: representation and generation.

A rotation sequence is stored the way the paper stores it (Alg 1.2): two
matrices ``C`` and ``S`` of shape ``(n-1, k)``.  Rotation ``(j, p)`` acts on
columns ``j`` and ``j+1`` of the target matrix ``A`` (applied from the
right)::

    t        = c * A[:, j] + s * A[:, j+1]
    A[:,j+1] = -s * A[:, j] + c * A[:, j+1]
    A[:, j]  = t

i.e. ``A <- A @ G(j, p)`` with ``G = [[c, -s], [s, c]]`` embedded at
``(j, j)``.  The application order is wave-major: all rotations of wave
``p`` (ascending ``j``) before wave ``p+1``.

Identity padding: a rotation with ``c = 1, s = 0`` is a no-op.  All blocked
algorithms in this package pad the ``(j, p)`` grid with identity rotations
instead of special-casing the startup/shutdown triangles of the wavefront
(the TPU-idiomatic equivalent of the paper's ``k_r = 1`` edge kernels).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sequence import RotationSequence

__all__ = [
    "RotationSequence",
    "plane_update",
    "random_sequence",
    "givens",
    "identity_sequence",
    "sequence_to_dense",
]


def plane_update(x, y, c, s, g):
    """The canonical bit-stable plane transform on one column pair.

    Every rotation/reflector application path in this package — scalar
    ``reflect`` flags, per-entry sign grids, blocked tiles, and the
    Pallas kernels — must evaluate the 2x2 update with exactly this
    multiply/negate order (the evaluation-order discipline of Pereira,
    Lotfi & Langou's rounding analysis of Givens rotations)::

        x' = c*x + s*y
        y' = g * (s*x - c*y)

    with ``g`` a *runtime array value* (``-1`` rotation, ``+1``
    reflector).  The sign must never be a compile-time scalar constant:
    XLA folds ``1.0 * t`` / ``-1.0 * t`` away and then contracts the
    remaining expression differently from the un-folded form, which is
    exactly the low-order-bit divergence between the scalar ``reflect``
    path and the sign-grid path this helper exists to close.  Array
    constants (including ``jnp.full`` under an outer ``jit``) keep the
    multiply in the graph and are bit-identical to runtime signs.
    """
    xn = c * x + s * y
    yn = g * (s * x - c * y)
    return xn, yn


def givens(a, b):
    """Compute ``(c, s)`` zeroing ``b`` against ``a``: ``[c s; -s c]ᵀ [a; b] = [r; 0]``.

    Safe at ``a = b = 0`` (returns identity rotation).
    """
    r = jnp.hypot(a, b)
    safe = r > 0
    c = jnp.where(safe, a / jnp.where(safe, r, 1.0), 1.0)
    s = jnp.where(safe, b / jnp.where(safe, r, 1.0), 0.0)
    return c, s


def random_sequence(key, n: int, k: int, dtype=jnp.float32) -> RotationSequence:
    """Random rotation sequence: uniform angles in ``[0, 2pi)``."""
    theta = jax.random.uniform(key, (n - 1, k), minval=0.0, maxval=2.0 * np.pi)
    return RotationSequence(
        jnp.cos(theta).astype(dtype), jnp.sin(theta).astype(dtype)
    )


def identity_sequence(n: int, k: int, dtype=jnp.float32) -> RotationSequence:
    return RotationSequence.identity(n, k, dtype)


def sequence_to_dense(seq: RotationSequence,
                      reflect: bool | None = None) -> np.ndarray:
    """Accumulate the whole sequence into a dense ``n x n`` orthogonal matrix.

    ``A @ Q`` equals applying the sequence to ``A``.  Pure numpy; used by
    tests and by small-scale accumulation oracles.  ``reflect=None``
    honours the sequence's own ``reflect`` flag and per-entry ``sign``
    array; an explicit boolean overrides both (legacy behaviour).
    """
    cos = np.asarray(seq.cos, dtype=np.float64)
    sin = np.asarray(seq.sin, dtype=np.float64)
    sign = getattr(seq, "sign", None)
    if reflect is None:
        reflect = bool(getattr(seq, "reflect", False))
    else:
        sign = None
    if sign is not None:
        g_all = np.asarray(sign, dtype=np.float64)
    else:
        g_all = np.full(cos.shape, 1.0 if reflect else -1.0)
    n = cos.shape[0] + 1
    q = np.eye(n)
    for p in range(cos.shape[1]):
        for j in range(n - 1):
            c, s, g = cos[j, p], sin[j, p], g_all[j, p]
            x = q[:, j].copy()
            y = q[:, j + 1].copy()
            q[:, j], q[:, j + 1] = plane_update(x, y, c, s, g)
    return q
