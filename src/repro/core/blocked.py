"""Blocked wavefront application of rotation sequences (paper SS2, SS5).

The ``(j, p)`` rotation grid is tiled into *parallelograms*: bands of
``k_b`` waves x tiles of ``n_b`` anti-diagonals.  Within a band, the matrix
is swept left-to-right in column tiles; ``k_b`` partially-rotated "carry"
columns flow from each tile to the next — the TPU/VMEM analogue of the
paper's cache blocking.  The startup and shutdown triangles are handled
uniformly by identity-padding the rotation grid (instead of the paper's
special ``k_r = 1`` edge kernels).

Coordinate bookkeeping (derived once, reused by the Pallas kernels):

* diagonal index ``u = j + p``; tile ``t`` covers ``u in [t*n_b, (t+1)*n_b)``.
* tile ``t`` touches matrix columns ``[t*n_b - k_b + 1, (t+1)*n_b]``:
  ``k_b`` carry columns + ``n_b`` fresh columns.
* after tile ``t``, columns up to ``(t+1)*n_b - k_b`` are final; the last
  ``k_b`` touched columns become the next carry.
* inside a tile, wave ``p`` applies rotations at local column pairs
  ``(j_l, j_l + 1)`` for ``j_l = k_b - 1 - p + jj``, ``jj in [0, n_b)`` —
  exactly Algorithm 2.1 of the paper.
* the rotation value for ``(t, jj, p)`` is ``C[t*n_b + jj - p, p0 + p]`` —
  a *sheared* ("packed", paper SS4) view of ``C``/``S`` built host-side so
  kernels read aligned tiles.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.rotations import plane_update

__all__ = [
    "pack_sheared",
    "apply_tile",
    "apply_band",
    "rot_sequence_blocked",
    "num_tiles",
]


def num_tiles(n: int, n_b: int, k_b: int) -> int:
    """Number of diagonal tiles needed so every output column is emitted."""
    return math.ceil((n + k_b - 1) / n_b)


def pack_sheared(C, S, p0: int, k_b: int, n_b: int, T: int,
                 reflect: bool = False, G=None):
    """Shear-pack waves ``[p0, p0 + k_b)`` into aligned ``(T, n_b, k_b)`` tiles.

    ``Ct[t, jj, p] = C[t*n_b + jj - p, p0 + p]`` with no-op padding outside
    the valid ``(j, wave)`` range.  Returns ``(Ct, St, Gt)``; ``Gt`` holds
    the per-entry sign of the unified update ``y' = g * (s*x - c*y)``:
    ``g = -1`` is a rotation (and the no-op padding ``c=1, s=0``), ``g = +1``
    a 2x2 reflector (paper SS8.4).  A padded *reflector* would not be a
    no-op (det = -1), hence the sign tile rather than a global flag.

    ``G``: optional per-entry sign array ``(n-1, k)`` for *mixed*
    rotation/reflector sequences (e.g. the Jacobi solver's pivots-with-
    swaps interleaved with no-op rotations); overrides ``reflect``.
    """
    J, k = C.shape
    u = jnp.arange(T * n_b)
    p = jnp.arange(k_b)
    jg = u[:, None] - p[None, :]  # global j for each (u, p)
    pg = p0 + p  # global wave index
    valid = (jg >= 0) & (jg < J) & (pg < k)[None, :]
    jc = jnp.clip(jg, 0, J - 1)
    pc = jnp.minimum(pg, k - 1)
    Ct = jnp.where(valid, C[jc, pc], jnp.ones((), C.dtype))
    St = jnp.where(valid, S[jc, pc], jnp.zeros((), S.dtype))
    if G is not None:
        Gt = jnp.where(valid, G[jc, pc], -jnp.ones((), C.dtype))
    elif reflect:
        Gt = jnp.where(valid, jnp.ones((), C.dtype), -jnp.ones((), C.dtype))
    else:
        Gt = jnp.full_like(Ct, -1.0)
    return (
        Ct.reshape(T, n_b, k_b),
        St.reshape(T, n_b, k_b),
        Gt.reshape(T, n_b, k_b),
    )


def apply_tile(X, Ct, St, Gt):
    """Apply one parallelogram tile of rotations to ``X`` (m, k_b + n_b).

    ``Ct``/``St``/``Gt`` are one sheared tile of shape ``(n_b, k_b)``.
    Sequential wavefront order: wave ``p`` ascending, within a wave ``jj``
    ascending.  This is the jnp oracle for the Pallas kernel body.
    """
    n_b, k_b = Ct.shape

    def wave(p, X):
        def rot(jj, X):
            jl = k_b - 1 - p + jj
            c = Ct[jj, p].astype(X.dtype)
            s = St[jj, p].astype(X.dtype)
            g = Gt[jj, p].astype(X.dtype)
            xy = jax.lax.dynamic_slice_in_dim(X, jl, 2, axis=1)
            xn, yn = plane_update(xy[:, 0], xy[:, 1], c, s, g)
            return jax.lax.dynamic_update_slice_in_dim(
                X, jnp.stack([xn, yn], axis=1), jl, axis=1
            )

        return jax.lax.fori_loop(0, n_b, rot, X)

    return jax.lax.fori_loop(0, k_b, wave, X)


def _band_inputs(A, k_b: int, n_b: int, T: int):
    """Initial carry + fresh-column tiles for one band sweep over ``A``."""
    m, n = A.shape
    carry0 = jnp.concatenate(
        [jnp.zeros((m, k_b - 1), A.dtype), A[:, :1]], axis=1
    )
    # Fresh columns stream: tile t consumes columns [t*n_b + 1, (t+1)*n_b].
    fresh = jnp.pad(A[:, 1:], ((0, 0), (0, T * n_b - (n - 1))))
    return carry0, fresh


def apply_band(A, Ct, St, Gt):
    """Sweep one band of ``k_b`` waves over ``A`` via a scan with carry.

    ``Ct``/``St``/``Gt``: sheared tiles ``(T, n_b, k_b)`` from
    :func:`pack_sheared`.  Returns ``A`` with the band applied (true column
    coordinates).
    """
    T, n_b, k_b = Ct.shape
    m, n = A.shape
    carry0, fresh = _band_inputs(A, k_b, n_b, T)
    fresh_tiles = fresh.reshape(m, T, n_b).transpose(1, 0, 2)  # (T, m, n_b)

    def step(carry, xs):
        ct, st, gt, ft = xs
        X = jnp.concatenate([carry, ft], axis=1)  # (m, k_b + n_b)
        X = apply_tile(X, ct, st, gt)
        return X[:, n_b:], X[:, :n_b]

    _, out = jax.lax.scan(step, carry0, (Ct, St, Gt, fresh_tiles))
    O = out.transpose(1, 0, 2).reshape(m, T * n_b)
    # O[:, i] holds final column  i - (k_b - 1)  of A.
    return jax.lax.slice_in_dim(O, k_b - 1, k_b - 1 + n, axis=1)


@partial(jax.jit, static_argnames=("n_b", "k_b", "reflect"))
def rot_sequence_blocked(A, C, S, *, n_b: int = 64, k_b: int = 16,
                         reflect: bool = False, G=None):
    """Blocked wavefront algorithm (paper SS2 + SS5) on the host in jnp."""
    m, n = A.shape
    J, k = C.shape
    assert J == n - 1, (C.shape, A.shape)
    n_b = min(n_b, max(8, n))  # don't tile wider than the matrix
    T = num_tiles(n, n_b, k_b)
    for p0 in range(0, k, k_b):  # bands, sequential (python loop: k/k_b small)
        Ct, St, Gt = pack_sheared(C, S, p0, k_b, n_b, T, reflect=reflect,
                                  G=G)
        A = apply_band(A, Ct, St, Gt)
    return A
