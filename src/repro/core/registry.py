"""Backend registry + cost-model dispatch for rotation-sequence application.

Every backend registers a :class:`BackendSpec` (capability record, §6
memory-operation cost model split into per-sequence *setup* and per-row
*stream* terms, tile-candidate generator); ``select_plan`` ranks the
eligible (backend, tile) candidates by modeled cost — optionally
re-ranked by measured wall time with ``autotune=True`` — and caches the
winning :class:`Plan` per problem key, write-through to an on-disk store
for measured plans.  :class:`Problem.shared_sequence` distinguishes one
sequence amortized over a batch from the serving path's
one-sequence-per-request batches, which pay setup × b.

The full pricing derivation (every backend's flop/memop/setup formula,
the per-request correction, and a worked batch-64 example) lives in
``docs/cost-model.md``; ``docs/architecture.md`` places this module in
the registry → sequence → serve → stream layer diagram.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import tempfile
from typing import Callable, Dict, List, Optional, Tuple

from repro import compat, obs
from repro.obs import timing as _timing

__all__ = [
    "Hardware", "PLATFORMS", "Problem", "Plan", "Capability", "BackendSpec",
    "register", "get_backend", "registered_methods", "eligible_backends",
    "no_tiles", "blocked_tiles", "accumulated_tiles",
    "pallas_wave_tiles", "pallas_mxu_tiles", "rotseq_batched_tiles",
    "select_plan", "plan_cache_stats", "clear_plan_cache",
    "plan_cache_path", "save_plan_cache", "load_plan_cache",
    "cost_components",
]


# hardware table lives in the jax-free repro.hw (shared with the
# roofline report); re-exported here for registry users
from repro.hw import Hardware, PLATFORMS  # noqa: E402
from repro.kernels.limits import (SMEM_PANEL_BUDGET, VMEM_SLAB_BUDGET,
                                  clamp_m_blk)

# Pallas interpret mode executes the kernel body op-by-op on the host —
# orders of magnitude off compiled speed.  Off-TPU the pallas backends
# remain *eligible* (interpret_ok) but carry this penalty, so "auto"
# never picks them while explicit method="pallas_*" still works.
_INTERPRET_PENALTY = 1e3


# --------------------------------------------------------------------------
# problem / plan records
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Problem:
    """Shape/dtype/platform key of one application ``A (m,n) <- k waves``.

    ``batch`` counts independent ``(m, n)`` targets served by one call
    (the serving path's shape buckets, or a batched accumulator).
    Rotations act row-wise, so a shared-sequence batch flattens to a
    ``(batch*m, n)`` problem: streaming traffic and sweep flops scale
    with the batch while per-sequence setup work (accumulating tile
    factors ``Q_t``, packing sheared tiles) is paid once — which is why
    ``method="auto"`` can pick a different backend at ``batch=64`` than
    at ``batch=1``.

    ``shared_sequence`` says whether those ``batch`` targets share one
    rotation sequence (the default — a batched accumulator flush) or
    each carry their own (the serving path's per-request buckets, via
    ``apply_batched(A, sequences=...)``).  Per-request batches rebuild
    the per-sequence setup ``batch`` times, so the same shape can price
    — and plan — onto a different backend (see ``docs/cost-model.md``,
    "the per-request correction").
    """
    m: int
    n: int
    k: int
    dtype: str = "float32"
    platform: str = "cpu"
    signs: bool = False    # needs per-entry G support
    sharded: bool = False  # must be traceable inside shard_map
    batch: int = 1         # independent (m, n) targets per application
    # one sequence amortized over the batch (True) vs one sequence per
    # batch element (False, the serving path).  Irrelevant at batch=1.
    shared_sequence: bool = True
    # live (non-identity) planes in the (n-1, k) grid, when statically
    # known (RotationSequence.k_live): pad_to tails and seq.T staircase
    # padding make the live fraction tiny, which only plane-skipping
    # backends (rotseq_batched) can exploit — their cost scales with
    # live_planes while every other backend pays the full grid.
    live_planes: Optional[int] = None
    # mesh size of a sharded execution (repro.dist): shape fields above
    # stay *global* — per-shard row counts and the inter-device
    # communication term are derived from ``devices`` in the cost
    # models, never baked into ``m``.  Meaningful only with
    # ``sharded=True``; ``devices=1`` keeps every existing cost exact.
    devices: int = 1

    @property
    def itemsize(self) -> int:
        return {"float64": 8, "float32": 4, "bfloat16": 2,
                "float16": 2}.get(self.dtype, 4)

    @property
    def m_total(self) -> int:
        """Total rows streamed per application (``batch * m``)."""
        return self.m * max(1, self.batch)

    @property
    def sequences(self) -> int:
        """Distinct rotation sequences the application pays setup for."""
        if self.batch <= 1 or self.shared_sequence:
            return 1
        return self.batch

    @property
    def planes_total(self) -> int:
        """Planes in the full (n-1, k) grid (identity padding included)."""
        return max(0, self.n - 1) * self.k

    @property
    def planes_live(self) -> int:
        """Statically-known live planes (falls back to the full grid)."""
        if self.live_planes is None:
            return self.planes_total
        return min(self.live_planes, self.planes_total)

    @property
    def hardware(self) -> Hardware:
        return PLATFORMS.get(self.platform, PLATFORMS["cpu"])


@dataclasses.dataclass(frozen=True)
class Plan:
    """A dispatch decision: backend + tile parameters (+ model cost)."""
    method: str
    n_b: Optional[int] = None
    k_b: Optional[int] = None
    m_blk: Optional[int] = None
    est_seconds: float = float("inf")
    # "model" (cost-model ranked) | "measured" (autotuned this process) |
    # "persisted" (measured, loaded from disk) | "interpolated" (borrowed
    # from the nearest measured shape)
    source: str = "model"

    def kwargs(self) -> dict:
        kw = {}
        if self.n_b is not None:
            kw["n_b"] = self.n_b
        if self.k_b is not None:
            kw["k_b"] = self.k_b
        if self.m_blk is not None:
            kw["m_blk"] = self.m_blk
        return kw


@dataclasses.dataclass(frozen=True)
class Capability:
    """What a backend can run; consulted before costing it."""
    dtypes: Tuple[str, ...] = ("float32", "bfloat16", "float64", "float16")
    platforms: Tuple[str, ...] = ("cpu", "gpu", "tpu")
    supports_signs: bool = True       # per-entry G (mixed rot/reflector)
    supports_sharding: bool = False   # callable inside shard_map
    tile_min: Tuple[int, int] = (1, 1)
    tile_max: Tuple[int, int] = (4096, 4096)
    needs_pallas: bool = False
    interpret_ok: bool = True
    # batched execution (SequencePlan.apply_batched): rotations act
    # row-wise, so a shared-sequence batch (b, m, n) flattens exactly to
    # (b*m, n); "vmap" instead maps the backend over the leading axis
    # (for kernels whose tiling assumptions are per-instance); "fused"
    # means the backend fn natively accepts a (b, m, n) target with
    # shared (n-1, K) or stacked (b, n-1, K) waves — one launch per
    # bucket (the rotseq_batched kernel).
    batch_via: str = "flatten"        # "flatten" | "vmap" | "fused"
    supports_vmap: bool = True        # jax.vmap-able over (A, C, S, G)


@dataclasses.dataclass(frozen=True)
class BackendSpec:
    name: str
    fn: Callable                       # (A, C, S, *, reflect, G, **plan_kw)
    capability: Capability
    cost: Callable[[Problem, Plan], float]
    candidates: Callable[[Problem], List[Plan]]
    doc: str = ""


_REGISTRY: Dict[str, BackendSpec] = {}


def register(spec: BackendSpec) -> BackendSpec:
    """Register (or replace) a backend spec under ``spec.name``."""
    _REGISTRY[spec.name] = spec
    return spec


def get_backend(name: str) -> BackendSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown method {name!r}; one of {registered_methods()} "
            f"(or 'auto' via apply_rotation_sequence)"
        ) from None


def registered_methods() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


def eligible_backends(problem: Problem) -> List[BackendSpec]:
    """Backends whose capability record admits ``problem``."""
    out = []
    for spec in _REGISTRY.values():
        cap = spec.capability
        if problem.dtype not in cap.dtypes:
            continue
        if problem.platform not in cap.platforms:
            # Pallas backends stay *eligible* off-platform when they can
            # run under the interpreter, but their cost carries the
            # interpret penalty so "auto" never actually picks them.
            if not (cap.needs_pallas and cap.interpret_ok):
                continue
        if problem.signs and not cap.supports_signs:
            continue
        if problem.sharded and not cap.supports_sharding:
            continue
        out.append(spec)
    return out


# --------------------------------------------------------------------------
# cost models (paper SS6 memory-operation analysis)
# --------------------------------------------------------------------------

def _bands(k: int, k_b: int) -> int:
    return max(1, math.ceil(k / max(1, k_b)))


# latency floor keeps tiny problems from reading as free
_LATENCY_FLOOR = 2e-6


def _roofline_seconds(flop_term: float, byte_term: float) -> float:
    return max(flop_term, byte_term, _LATENCY_FLOOR)


# Each ``_components_*`` function returns the §6 traffic split into an
# explicit per-sequence **setup** term (building the accumulated path's
# Q_t factors, packing sheared tiles, streaming per-request wave panels
# — work proportional to the sequence, paid once per *distinct*
# sequence) and a per-row **stream** term (work proportional to the
# rows of A).  The returned totals are already scaled: setup terms are
# multiplied by ``Problem.sequences`` (1 for a shared-sequence batch,
# b for the serving path's per-request batches), which is the whole
# per-request pricing correction — see docs/cost-model.md.
_ZERO_SPLIT = {"setup_flops": 0.0, "setup_bytes": 0.0,
               "stream_flops": 0.0, "stream_bytes": 0.0}


def _split(setup_flops=0.0, setup_bytes=0.0,
           stream_flops=0.0, stream_bytes=0.0) -> Dict[str, float]:
    return {"setup_flops": float(setup_flops),
            "setup_bytes": float(setup_bytes),
            "stream_flops": float(stream_flops),
            "stream_bytes": float(stream_bytes)}


# ---------------------------------------------------------------------------
# inter-device communication term (repro.dist sharded executions)
# ---------------------------------------------------------------------------
#
# Row-sharded application (the ShardedSequencePlan fused path) keeps the
# rows of every shard independent — rotations act on column *pairs* — so
# the only wire traffic is replicating the C/S/G wave panels to every
# shard once per plan (a setup-side cost, per the PR 9 split).  The
# stream side of the wire is zero for row sharding; the CAQR-style
# column-panel path prices its per-panel boundary exchange separately in
# ``repro.dist.column_sharded_comm_bytes``.  A per-hop latency constant
# keeps tiny sharded problems from reading as free: broadcasting to D
# devices costs ~log2(D) link round-trips regardless of payload, which
# is exactly what makes ``method="auto"`` keep small-n problems
# replicated while large-n problems amortize the wire and go sharded.

_LINK_HOP_LATENCY = 5e-6


def _comm_components(p: Problem) -> Dict[str, float]:
    """Wire traffic + seconds of one sharded application (zero at D=1).

    ``setup_bytes`` is the wave-panel broadcast — 3 planes arrays
    (C/S/G) per *distinct* sequence, ``devices - 1`` copies leaving the
    source shard; ``stream_bytes`` is zero for the row-sharded fused
    path.  ``seconds`` prices the bytes at ``Hardware.link_bw`` plus
    ``ceil(log2(D))`` per-hop latencies.
    """
    D = max(1, p.devices)
    if not p.sharded or D <= 1:
        return {"setup_bytes": 0.0, "stream_bytes": 0.0, "bytes": 0.0,
                "hops": 0.0, "seconds": 0.0}
    panel = 3.0 * p.sequences * p.planes_total * p.itemsize
    setup_bytes = panel * (D - 1)
    hops = float(math.ceil(math.log2(D)))
    secs = setup_bytes / p.hardware.link_bw + hops * _LINK_HOP_LATENCY
    return {"setup_bytes": setup_bytes, "stream_bytes": 0.0,
            "bytes": setup_bytes, "hops": hops, "seconds": secs}


def _dist_terms(p: Problem) -> Tuple[float, float]:
    """``(stream_divisor, comm_seconds)`` of the problem's mesh.

    Per-row stream work divides across ``devices`` shards (each shard
    owns ``m_total / D`` rows); per-sequence setup work is replicated —
    every shard packs/accumulates the full sequence locally — so setup
    terms never divide.  The returned comm seconds are *additive* on
    top of the per-shard roofline.
    """
    D = max(1, p.devices)
    if not p.sharded or D <= 1:
        return 1.0, 0.0
    return float(D), _comm_components(p)["seconds"]


def _components_unoptimized(p: Problem, plan: Plan) -> Dict[str, float]:
    # Alg 1.2 touches nothing per-sequence beyond the C/S panel itself,
    # which is dominated by its 4-memop-per-rotation streaming.
    return _split(stream_flops=6.0 * p.m_total * p.n * p.k,
                  stream_bytes=4.0 * p.m_total * p.n * p.k * p.itemsize)


def cost_unoptimized(p: Problem, plan: Plan) -> float:
    """Alg 1.2: 4 memops per rotation, no reuse (paper SS6 baseline)."""
    hw = p.hardware
    c = _components_unoptimized(p, plan)
    D, comm_s = _dist_terms(p)
    return _roofline_seconds(c["stream_flops"] / hw.vpu_flops / D,
                             c["stream_bytes"] / hw.hbm_bw / D) + comm_s


def _components_wavefront(p: Problem, plan: Plan) -> Dict[str, float]:
    return _split(stream_flops=6.0 * p.m_total * p.n * p.k,
                  stream_bytes=2.0 * p.m_total * p.n * p.k * p.itemsize)


def cost_wavefront(p: Problem, plan: Plan) -> float:
    """Alg 1.3: wavefront fuses column touches to ~2 memops/rotation."""
    hw = p.hardware
    c = _components_wavefront(p, plan)
    D, comm_s = _dist_terms(p)
    return _roofline_seconds(c["stream_flops"] / hw.vpu_flops / D,
                             c["stream_bytes"] / hw.hbm_bw / D) + comm_s


def _tile_grid(p: Problem, n_b: int, k_b: int) -> Tuple[int, int, int]:
    """``(bands, tiles, w)`` of the sheared-tile decomposition (SS5)."""
    w = n_b + k_b
    bands = _bands(p.k, k_b)
    tiles = max(1, math.ceil((p.n + k_b - 1) / n_b))
    return bands, tiles, w


def _pack_bytes(p: Problem, n_b: int, k_b: int) -> float:
    """Per-sequence sheared-tile packing traffic (blocked/accumulated).

    Each band's ``k_b`` waves are gathered into ``tiles`` sheared
    ``(w, k_b)`` tiles per wave array before any row of A moves: the
    raw ``(n-1, k)`` panels are read once and the padded tile buffers
    written once.  Signs add a third array.
    """
    bands, tiles, w = _tile_grid(p, n_b, k_b)
    arrays = 3 if p.signs else 2
    read = arrays * p.planes_total
    write = arrays * bands * tiles * w * k_b
    return (read + write) * p.itemsize


def _components_blocked(p: Problem, plan: Plan) -> Dict[str, float]:
    n_b = plan.n_b or 64
    k_b = plan.k_b or 16
    return _split(
        setup_bytes=p.sequences * _pack_bytes(p, n_b, k_b),
        stream_flops=6.0 * p.m_total * p.n * p.k,
        stream_bytes=2.0 * p.m_total * p.n * p.itemsize * _bands(p.k, k_b))


def cost_blocked(p: Problem, plan: Plan) -> float:
    """Blocked wavefront: A streams once per band of k_b waves (SS5)."""
    hw = p.hardware
    c = _components_blocked(p, plan)
    D, comm_s = _dist_terms(p)
    return _roofline_seconds(
        c["stream_flops"] / hw.vpu_flops / D,
        (c["setup_bytes"] + c["stream_bytes"] / D) / hw.hbm_bw) + comm_s


def _accumulated_flops(p: Problem, n_b: int, k_b: int) -> Tuple[float, float]:
    """(MXU sweep flops, per-sequence VPU accumulation flops).

    The GEMM sweep streams every row of every batched target
    (``m_total``); accumulating the tile factors ``Q_t`` happens once
    per *sequence* — amortized by a shared-sequence batch, multiplied
    by ``b`` on the serving path's per-request batches (the cliff
    ``docs/cost-model.md`` walks through at batch 64).
    """
    w = n_b + k_b
    bands, tiles, _ = _tile_grid(p, n_b, k_b)
    sweep = bands * tiles * 2.0 * p.m_total * w * w      # (m,w) @ (w,w)
    accum = bands * tiles * 6.0 * w * n_b * k_b          # Q_t = I rotated
    return sweep, accum


def _components_accumulated(p: Problem, plan: Plan) -> Dict[str, float]:
    n_b = plan.n_b or 128
    k_b = plan.k_b or 128
    sweep, accum = _accumulated_flops(p, n_b, k_b)
    bands, tiles, w = _tile_grid(p, n_b, k_b)
    q_bytes = bands * tiles * w * w * p.itemsize  # Q_t factors written
    return _split(
        setup_flops=p.sequences * accum,
        setup_bytes=p.sequences * (_pack_bytes(p, n_b, k_b) + q_bytes),
        stream_flops=sweep,
        stream_bytes=2.0 * p.m_total * p.n * p.itemsize * _bands(p.k, k_b))


def cost_accumulated(p: Problem, plan: Plan) -> float:
    """rs_gemm: ~4/3 extra flops (n_b = k_b) priced at matmul rate.

    The sweep GEMMs run at MXU rate; the per-sequence ``Q_t``
    accumulation is short-vector VPU work, multiplied by ``b`` for
    per-request batches.
    """
    hw = p.hardware
    c = _components_accumulated(p, plan)
    D, comm_s = _dist_terms(p)
    flop_term = (c["stream_flops"] / hw.mxu_flops / D
                 + c["setup_flops"] / hw.vpu_flops)
    return _roofline_seconds(
        flop_term,
        (c["setup_bytes"] + c["stream_bytes"] / D) / hw.hbm_bw) + comm_s


def _interpret_factor(p: Problem) -> float:
    return 1.0 if p.platform == "tpu" else _INTERPRET_PENALTY


def cost_pallas_wave(p: Problem, plan: Plan) -> float:
    """VPU kernel: blocked-wavefront traffic, carry pinned in VMEM.

    ``supports_vmap=False``: a per-request batch runs as ``b`` separate
    launches, so the latency floor multiplies by the sequence count.
    Comm seconds stay outside the kernel constant and the interpret
    penalty — the wire is neither fused nor interpreted.
    """
    D, comm_s = _dist_terms(p)
    return max(0.7 * (cost_blocked(p, plan) - comm_s)
               * _interpret_factor(p),
               p.sequences * _LATENCY_FLOOR) + comm_s


def cost_pallas_mxu(p: Problem, plan: Plan) -> float:
    """MXU kernel: accumulated-path traffic at kernel-fused constants.

    Like ``pallas_wave``, per-request batches loop-launch per sequence.
    """
    D, comm_s = _dist_terms(p)
    return max(0.7 * (cost_accumulated(p, plan) - comm_s)
               * _interpret_factor(p),
               p.sequences * _LATENCY_FLOOR) + comm_s


def _components_rotseq_batched(p: Problem, plan: Plan) -> Dict[str, float]:
    # The stacked C/S/G panel streams once per grid batch element
    # whether or not the sequence is shared (the kernel's grid walks
    # batch-major), so the panel term scales with ``batch``, not
    # ``sequences`` — the kernel's per-request price is flat, which is
    # exactly why it wins serving buckets.
    return _split(
        setup_bytes=3.0 * max(1, p.batch) * p.planes_total * p.itemsize,
        stream_flops=6.0 * p.m_total * p.planes_live,
        stream_bytes=2.0 * p.m_total * p.n * p.itemsize)


def cost_rotseq_batched(p: Problem, plan: Plan) -> float:
    """Fused multi-request kernel (SS6 applied across requests).

    One launch streams every batched target through HBM exactly once
    (the whole ``(n, m_blk)`` slab lives in VMEM for all ``k`` waves, so
    there is no per-band re-read), the ``3 (n-1) k`` C/S/G panel is
    read once per batch element, and — unlike every other backend —
    the flop term scales with the *live* planes: identity padding from
    ``pad_to`` and ``seq.T`` staircases is skipped, not multiplied
    through.
    """
    hw = p.hardware
    c = _components_rotseq_batched(p, plan)
    D, comm_s = _dist_terms(p)
    secs = _roofline_seconds(
        c["stream_flops"] / hw.vpu_flops / D,
        (c["setup_bytes"] + c["stream_bytes"] / D) / hw.hbm_bw)
    # On-chip residency bounds, priced out rather than hard-filtered:
    # the (n, m_blk) slab must fit in VMEM for the single-pass
    # assumption to hold, and the scalar-indexed C/S/G panels live in
    # SMEM, whose capacity is far smaller — a (n-1, K) grid past the
    # budget cannot compile on hardware (interpret mode hides this),
    # so keep auto off the kernel there.  Budgets and the m_blk clamp
    # come from repro.kernels.limits — the same definitions the kernel
    # wrapper uses, so the kernel the model prices is the kernel that
    # launches (enforced by RA403/RA404).
    m_blk = clamp_m_blk(p.m, plan.m_blk or 256)
    panel_bytes = 3 * p.planes_total * p.itemsize
    if (p.n * m_blk * p.itemsize > VMEM_SLAB_BUDGET
            or panel_bytes > SMEM_PANEL_BUDGET):
        secs *= 1e3
    return max(secs * _interpret_factor(p), _LATENCY_FLOOR) + comm_s


# the setup/stream traffic split behind each cost model, exposed so the
# obs roofline layer attributes dispatches with the *same* numbers the
# planner ranked candidates with (pallas kernels move blocked /
# accumulated traffic; only their seconds constant differs)
_COMPONENT_FNS: Dict[str, Callable[[Problem, Plan], Dict[str, float]]] = {
    "unoptimized": _components_unoptimized,
    "wavefront": _components_wavefront,
    "blocked": _components_blocked,
    "accumulated": _components_accumulated,
    "pallas_wave": _components_blocked,
    "pallas_mxu": _components_accumulated,
    "rotseq_batched": _components_rotseq_batched,
}

# stream flops run at MXU rate for the GEMM family, VPU elsewhere;
# setup flops (Q_t accumulation) are always short-vector VPU work
_MXU_STREAM = ("accumulated", "pallas_mxu")


def cost_components(method: str, problem: Problem,
                    plan: Optional[Plan] = None) -> dict:
    """Predicted traffic + seconds for one dispatch, split by term.

    Returns ``{"flops", "bytes", "seconds", "setup": {...},
    "stream": {...}}``.  Top-level ``flops``/``bytes`` are the summed
    §6 memory-operation analysis of the named backend (zero for
    backends registered without a component entry); ``seconds`` is the
    registered cost model itself, so it always matches what
    ``select_plan`` ranked by — including the interpret penalty and
    residency guards.  The ``setup``/``stream`` sub-dicts carry the
    per-sequence vs per-row split with *additive, penalty-free*
    attribution seconds (pure traffic over peak rates), so the obs
    roofline ledger — and the bench row that watches the per-request
    accumulated cliff — can attribute ``model_fraction`` per term.
    Sharded problems (``devices > 1``) additionally carry a ``comm``
    sub-dict — the wave-panel broadcast bytes and their link-priced
    seconds (``docs/cost-model.md``, "the communication term"); the
    attribution ``stream`` seconds are *per-shard* (divided by the mesh
    size), matching what each device actually streams.
    Pure arithmetic — safe to call from metrics/snapshot paths (RA5).
    """
    spec = get_backend(method)
    plan = plan if plan is not None else Plan(method=method)
    comp_fn = _COMPONENT_FNS.get(method)
    c = comp_fn(problem, plan) if comp_fn is not None else _ZERO_SPLIT
    hw = problem.hardware
    D, _ = _dist_terms(problem)
    comm = _comm_components(problem)
    stream_rate = hw.mxu_flops if method in _MXU_STREAM else hw.vpu_flops
    setup_s = (c["setup_flops"] / hw.vpu_flops
               + c["setup_bytes"] / hw.hbm_bw)
    stream_s = (c["stream_flops"] / stream_rate
                + c["stream_bytes"] / hw.hbm_bw) / D
    return {
        "flops": float(c["setup_flops"] + c["stream_flops"]),
        "bytes": float(c["setup_bytes"] + c["stream_bytes"]),
        "seconds": float(spec.cost(problem, plan)),
        "setup": {"flops": float(c["setup_flops"]),
                  "bytes": float(c["setup_bytes"]),
                  "seconds": float(setup_s)},
        "stream": {"flops": float(c["stream_flops"]),
                   "bytes": float(c["stream_bytes"]),
                   "seconds": float(stream_s)},
        "comm": {"bytes": float(comm["bytes"]),
                 "hops": float(comm["hops"]),
                 "seconds": float(comm["seconds"])},
    }


# --------------------------------------------------------------------------
# tile candidate grids
# --------------------------------------------------------------------------

def _clip_pairs(p: Problem, pairs, cap: Capability) -> List[Tuple[int, int]]:
    lo_n, lo_k = cap.tile_min
    hi_n, hi_k = cap.tile_max
    seen, out = set(), []
    for n_b, k_b in pairs:
        n_b = max(lo_n, min(n_b, hi_n, max(8, p.n)))
        k_b = max(lo_k, min(k_b, hi_k, max(1, p.k)))
        if (n_b, k_b) not in seen:
            seen.add((n_b, k_b))
            out.append((n_b, k_b))
    return out


def no_tiles(p: Problem) -> List[Plan]:
    return [Plan(method="", n_b=None, k_b=None)]


def blocked_tiles(p: Problem) -> List[Plan]:
    pairs = [(64, 16), (32, 8), (16, 8), (8, 4), (64, 2)]
    cap = get_backend("blocked").capability
    return [Plan("", n_b=a, k_b=b) for a, b in _clip_pairs(p, pairs, cap)]


def accumulated_tiles(p: Problem) -> List[Plan]:
    pairs = [(128, 128), (96, 96), (64, 64), (32, 32), (16, 16), (8, 8),
             (64, 16)]
    cap = get_backend("accumulated").capability
    return [Plan("", n_b=a, k_b=b) for a, b in _clip_pairs(p, pairs, cap)]


def _m_blk_for(p: Problem) -> int:
    if p.platform == "tpu":
        return 256 if p.m_total >= 256 else 128
    return min(256, max(8, 1 << (max(1, p.m_total) - 1).bit_length()))


def pallas_wave_tiles(p: Problem) -> List[Plan]:
    cap = get_backend("pallas_wave").capability
    pairs = _clip_pairs(p, [(64, 16), (32, 8), (8, 4)], cap)
    mb = _m_blk_for(p)
    return [Plan("", n_b=a, k_b=b, m_blk=mb) for a, b in pairs]


def pallas_mxu_tiles(p: Problem) -> List[Plan]:
    cap = get_backend("pallas_mxu").capability
    pairs = _clip_pairs(p, [(128, 128), (64, 64), (8, 8)], cap)
    mb = _m_blk_for(p)
    return [Plan("", n_b=a, k_b=b, m_blk=mb) for a, b in pairs]


def rotseq_batched_tiles(p: Problem) -> List[Plan]:
    """The fused kernel tiles only over lanes (whole n stays in VMEM)."""
    mb = _m_blk_for(p)
    cands = [Plan("", m_blk=mb)]
    if mb != 128:
        cands.append(Plan("", m_blk=128))
    return cands


# --------------------------------------------------------------------------
# plan selection + cache
# --------------------------------------------------------------------------

_PLAN_CACHE: Dict[tuple, Plan] = {}
_CACHE_STATS = {"hits": 0, "misses": 0}


def plan_cache_stats() -> dict:
    return dict(_CACHE_STATS, size=len(_PLAN_CACHE))


def clear_plan_cache() -> None:
    """Drop the *in-memory* plan cache (the on-disk file is untouched)."""
    _PLAN_CACHE.clear()
    _CACHE_STATS["hits"] = _CACHE_STATS["misses"] = 0


# --------------------------------------------------------------------------
# persisted plan cache (measured/autotuned plans survive the process)
# --------------------------------------------------------------------------
#
# Autotuned plans are expensive (each one compiles and times real backends)
# but keyed by pure host facts — (m, n, k, dtype, platform, signs, sharded)
# plus the JAX version — so they are safe to reuse across processes.  Every
# measured plan is written through to a JSON file (atomic tmp+rename) and
# loaded back on import.  Model-ranked plans are cheap to recompute and are
# never persisted.  ``REPRO_PLAN_CACHE`` overrides the path; setting it to
# the empty string or ``off`` disables persistence entirely.

_PLAN_CACHE_ENV = "REPRO_PLAN_CACHE"
_PLAN_CACHE_FORMAT = 1
_PERSISTED_SOURCES = ("measured", "persisted")


def plan_cache_path() -> Optional[str]:
    """Resolved on-disk cache path, or ``None`` when persistence is off."""
    override = os.environ.get(_PLAN_CACHE_ENV)
    if override is not None:
        if override.strip().lower() in ("", "off", "0", "none"):
            return None
        return os.path.expanduser(override)
    base = os.environ.get("XDG_CACHE_HOME",
                          os.path.join(os.path.expanduser("~"), ".cache"))
    return os.path.join(base, "repro", "plans.json")


def _jax_version_str() -> str:
    return ".".join(map(str, compat.JAX_VERSION))


def _read_versioned_json(path: str, fmt: int) -> Optional[dict]:
    """Parse a versioned JSON store; ``None`` when the file is missing,
    corrupt, or stale (other format or JAX version) — the shared
    invalidation rule of every persisted-plan store (registry cache and
    the serving plan store alike)."""
    try:
        with open(path) as f:
            payload = json.load(f)
    except (OSError, ValueError):
        return None
    if not isinstance(payload, dict) or payload.get("format") != fmt \
            or payload.get("jax") != _jax_version_str():
        return None
    return payload


def _atomic_write_json(path: str, payload: dict,
                       prefix: str) -> Optional[str]:
    """tmp+rename atomic JSON write; ``None`` (never raises) on I/O
    errors so a read-only cache dir degrades gracefully."""
    try:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path),
                                   prefix=prefix, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=1)
            os.replace(tmp, path)  # atomic on POSIX
        except BaseException:
            os.unlink(tmp)
            raise
    except OSError:
        return None
    return path


def save_plan_cache(path: Optional[str] = None) -> Optional[str]:
    """Atomically write all measured/persisted plans to disk.

    Entries already on disk (same format + JAX version) that this
    process does not hold in memory are merged in before writing — a
    *best-effort* courtesy to concurrent processes autotuning other
    shapes (the unlocked read-merge-replace still has a lost-update
    window; a plan lost to the race is merely re-measured, never
    corrupted, so no file lock is taken).  Returns the
    path written, or ``None`` when persistence is disabled or there is
    nothing durable to save.  Never raises for I/O problems — a
    read-only cache dir degrades to in-memory planning.
    """
    path = path or plan_cache_path()
    if path is None:
        return None
    merged: Dict[tuple, dict] = {}
    on_disk = _read_versioned_json(path, _PLAN_CACHE_FORMAT)
    if on_disk is not None:  # missing/corrupt/stale file: start fresh
        for entry in on_disk.get("plans", []):
            try:
                merged[tuple(entry["key"])] = entry
            except (KeyError, TypeError):
                continue
    for key, plan in _PLAN_CACHE.items():
        if plan.source in _PERSISTED_SOURCES:
            merged[key] = {"key": list(key), "method": plan.method,
                           "n_b": plan.n_b, "k_b": plan.k_b,
                           "m_blk": plan.m_blk,
                           "est_seconds": plan.est_seconds}
    if not merged:
        return None
    payload = {"format": _PLAN_CACHE_FORMAT, "jax": _jax_version_str(),
               "plans": list(merged.values())}
    return _atomic_write_json(path, payload, prefix=".plans.")


def load_plan_cache(path: Optional[str] = None) -> int:
    """Merge persisted plans into the in-memory cache; returns count loaded.

    Entries from a different JAX version (or an unreadable/corrupt file)
    are ignored wholesale — measured timings do not transfer across
    compiler versions.  An in-memory *measured* entry wins over disk.
    """
    path = path or plan_cache_path()
    if path is None:
        return 0
    payload = _read_versioned_json(path, _PLAN_CACHE_FORMAT)
    if payload is None:
        return 0
    loaded = 0
    for entry in payload.get("plans", []):
        try:
            key = tuple(entry["key"])
            plan = Plan(method=str(entry["method"]), n_b=entry.get("n_b"),
                        k_b=entry.get("k_b"), m_blk=entry.get("m_blk"),
                        est_seconds=float(entry.get("est_seconds", 0.0)),
                        source="persisted")
        except (KeyError, TypeError, ValueError):
            continue
        if plan.method not in _REGISTRY:
            continue  # stale entry for an unregistered backend
        cached = _PLAN_CACHE.get(key)
        if cached is not None and cached.source == "measured":
            continue
        _PLAN_CACHE[key] = plan
        loaded += 1
    return loaded


# Maximum summed |log(m/m')| + |log(n/n')| + |log(k/k')| (+ batch term)
# at which a measured plan still transfers: ~4x per dimension on
# average.  Beyond this the regime can differ qualitatively
# (cache-resident vs streaming, VPU- vs MXU-bound) and the cost model is
# the better guess.
_INTERP_MAX_LOGDIST = 3 * math.log(4.0)


def _plan_key(problem: Problem) -> tuple:
    """Cache key for a problem.

    ``batch=1`` keys keep the legacy 7-tuple layout so plan caches
    persisted before the batch field existed stay valid; batched
    problems append the batch count, per-request batches
    (``shared_sequence=False``, which price setup × b and can plan
    differently) append a ``"per_req"`` marker after it, and problems
    with a static live-plane count (padded/staircase sequences, which
    plane-skipping backends price differently) append
    ``("live", count)`` last.

    Sharded problems put ``("sharded", devices)`` in the legacy
    ``sharded`` slot: the mesh size is part of the eligibility *class*
    (``_split_key``), so plans never transfer between device counts —
    or to/from single-device keys, whose slot stays the legacy
    ``False``.  Sharded plans are never persisted (``select_plan``'s
    ``can_measure`` excludes them), so the tuple-valued slot never
    reaches the JSON store.
    """
    shard = ("sharded", max(1, problem.devices)) if problem.sharded \
        else False
    base = (problem.m, problem.n, problem.k, problem.dtype,
            problem.platform, problem.signs, shard)
    if problem.batch == 1 and problem.live_planes is None:
        return base
    base = base + (problem.batch,)
    if problem.batch > 1 and not problem.shared_sequence:
        base = base + ("per_req",)
    if problem.live_planes is not None:
        base = base + ("live", problem.live_planes)
    return base


def _split_key(key: tuple):
    """``key -> ((m, n, k, batch), class, live_fraction)``.

    ``class`` is the eligibility tuple ``(dtype, platform, signs,
    sharded, shared_sequence)``.  Shared-sequence and per-request keys
    are distinct classes — a measured plan for one sequence amortized
    over a batch must not transfer at distance 0 to the same shape
    paying setup per request (the backends differ, exactly like dense
    vs live-annotated).  ``live_fraction`` decodes the optional
    trailing ``("live", count)`` marker as ``count / ((n-1) * k)``
    (``None`` when absent): liveness changes which backend wins, so
    dense and live-annotated keys are distinct classes too, with the
    live-fraction ratio added to the distance within the latter.
    """
    m, n, k = key[:3]
    batch = key[7] if len(key) > 7 else 1
    shared = True
    idx = 8
    if len(key) > idx and key[idx] == "per_req":
        shared = False
        idx += 1
    frac = None
    if len(key) > idx + 1 and key[idx] == "live":
        planes = max(1, (n - 1) * k)
        frac = max(1, int(key[idx + 1])) / planes
    return (m, n, k, batch), tuple(key[3:7]) + (shared,), frac


def _interpolated_plan(problem: Problem, key: tuple) -> Optional[Plan]:
    """Borrow the nearest *measured* plan for an unmeasured shape.

    Autotuned timings are expensive; rather than re-running the cost
    model for a shape we have never measured, reuse the closest measured
    (or disk-persisted) plan of the same eligibility class — identical
    ``(dtype, platform, signs, sharded)`` and a backend this problem is
    itself eligible for — ranked by log-distance in ``(m, n, k)`` and
    only within :data:`_INTERP_MAX_LOGDIST` (a far-away measurement
    must not override the cost model).  Borrowed plans are cached under
    the new key with ``source="interpolated"`` (never persisted, and
    upgraded in place by a later ``autotune=True`` call).
    """
    eligible = {spec.name for spec in eligible_backends(problem)}
    best: Optional[Plan] = None
    best_dist = _INTERP_MAX_LOGDIST
    (m1, n1, k1, b1), cls1, frac1 = _split_key(key)
    for cached_key, plan in _PLAN_CACHE.items():
        if plan.source not in _PERSISTED_SOURCES:
            continue
        (m2, n2, k2, b2), cls2, frac2 = _split_key(cached_key)
        if cls2 != cls1:  # (dtype, platform, signs, sharded, shared_seq)
            continue
        if (frac2 is None) != (frac1 is None):
            continue  # dense vs live-annotated: different regimes
        if plan.method not in eligible:
            continue
        if min(m2, n2, k2, b2) < 1:
            continue
        dist = (abs(math.log(m1 / m2))
                + abs(math.log(n1 / n2))
                + abs(math.log(k1 / k2))
                + abs(math.log(b1 / b2)))
        if frac1 is not None:
            dist += abs(math.log(frac1 / frac2))
        if dist < best_dist:
            best, best_dist = plan, dist
    if best is None:
        return None
    # the donor's measured wall-time belongs to the donor's shape; carry
    # the cost model's estimate for *this* problem instead
    borrowed = dataclasses.replace(best, source="interpolated")
    est = get_backend(best.method).cost(problem, borrowed)
    return dataclasses.replace(borrowed, est_seconds=est)


def _modeled_plans(problem: Problem) -> List[Plan]:
    """All eligible (backend, tile) plans, costed and sorted ascending.

    Problems small enough to hit the latency floor tie on seconds; the
    tie-break is total modeled traffic (the §6 criterion itself), not
    backend registration order — at floor-bound sizes the
    least-communication plan is still the principled pick.
    """
    plans: List[Plan] = []
    for spec in eligible_backends(problem):
        for cand in spec.candidates(problem):
            plan = dataclasses.replace(cand, method=spec.name)
            cost = spec.cost(problem, plan)
            plans.append(dataclasses.replace(plan, est_seconds=cost))

    def _rank(pl: Plan):
        comp_fn = _COMPONENT_FNS.get(pl.method)
        if comp_fn is None:
            return (pl.est_seconds, float("inf"))
        c = comp_fn(problem, pl)
        return (pl.est_seconds, c["setup_bytes"] + c["stream_bytes"])

    plans.sort(key=_rank)
    return plans


def _synthetic_waves(problem: Problem, rng):
    """One ``(C, S, G)`` wave draw matching the problem record.

    A per-entry sign array is included when ``problem.signs`` so
    sign-carrying plans are timed on the code path they will actually
    serve, and a ``live_planes`` bound identity-pads the trailing waves
    so plane-skipping backends are timed on (approximately) the live
    grid they will execute, not a dense one ~grid/live times costlier.
    """
    import numpy as np

    th = rng.standard_normal((problem.n - 1, problem.k))
    Cn, Sn = np.cos(th), np.sin(th)
    if problem.live_planes is not None \
            and problem.live_planes < problem.planes_total:
        live_waves = math.ceil(problem.live_planes
                               / max(1, problem.n - 1))
        Cn[:, live_waves:] = 1.0
        Sn[:, live_waves:] = 0.0
    Gn = None
    if problem.signs:
        Gn = np.where(rng.random((problem.n - 1, problem.k)) < 0.5,
                      1.0, -1.0)
        # identity padding must stay a rotation (a padded reflector is
        # live), or the live_planes-shaped workload above is undone
        Gn[(Cn == 1.0) & (Sn == 0.0)] = -1.0
    return Cn, Sn, Gn


def _time_median(fn: Callable, reps: int) -> float:
    import jax

    jax.block_until_ready(fn())  # compile
    ts = []
    for _ in range(reps):
        t0 = _timing.now()
        jax.block_until_ready(fn())
        ts.append(_timing.now() - t0)
    return sorted(ts)[len(ts) // 2]


def _measure_plan(problem: Problem, plan: Plan, reps: int = 2) -> float:
    """Median wall-time of one real application at ``plan``'s tiles.

    The synthetic workload matches the problem record (signs, live
    planes — see :func:`_synthetic_waves`).  Shared-sequence batches
    execute flattened (rotations are row-wise), so they are timed as
    the ``(batch*m, n)`` problem the dispatch path will actually run;
    per-request batches are timed through
    ``SequencePlan.apply_batched(A, sequences=...)`` with ``batch``
    *distinct* sequences, so the fused / vmap / loop execution strategy
    — and the per-sequence setup this problem re-pays ``b`` times — is
    measured, not a single broadcast sequence that would hide it.
    """
    import jax.numpy as jnp
    import numpy as np

    if problem.batch > 1 and not problem.shared_sequence:
        return _measure_plan_per_request(problem, plan, reps)
    rng = np.random.default_rng(0)
    dt = jnp.dtype(problem.dtype)
    A = jnp.asarray(rng.standard_normal((problem.m_total, problem.n)), dt)
    Cn, Sn, Gn = _synthetic_waves(problem, rng)
    C, S = jnp.asarray(Cn, dt), jnp.asarray(Sn, dt)
    G = None if Gn is None else jnp.asarray(Gn, dt)
    spec = get_backend(plan.method)
    fn = lambda: spec.fn(A, C, S, reflect=False, G=G, **plan.kwargs())
    return _time_median(fn, reps)


def _measure_plan_per_request(problem: Problem, plan: Plan,
                              reps: int) -> float:
    """Per-request-batch measurement: ``batch`` distinct sequences.

    Routed through the same ``apply_batched`` strategy dispatch the
    serving path uses (fused kernel, ``jax.vmap``, or per-element
    loop), because that execution shape — not the flattened broadcast —
    is what a per-request plan will actually run.
    """
    import jax.numpy as jnp
    import numpy as np

    # plan-layer import, deferred: sequence.py imports this module
    from repro.core import sequence as _sequence

    rng = np.random.default_rng(0)
    dt = jnp.dtype(problem.dtype)
    A = jnp.asarray(
        rng.standard_normal((problem.batch, problem.m, problem.n)), dt)
    seqs = []
    for _ in range(problem.batch):
        Cn, Sn, Gn = _synthetic_waves(problem, rng)
        seq = _sequence.RotationSequence(
            jnp.asarray(Cn, dt), jnp.asarray(Sn, dt),
            None if Gn is None else jnp.asarray(Gn, dt))
        if problem.live_planes is not None:
            seq = dataclasses.replace(
                seq, k_live=min(problem.live_planes, problem.planes_total))
        seqs.append(seq)
    sp = _sequence.SequencePlan(seqs[0], plan.method,
                                tuple(sorted(plan.kwargs().items())), plan)
    fn = lambda: sp.apply_batched(A, sequences=seqs, direct=True)
    return _time_median(fn, reps)


def select_plan(m: int, n: int, k: int, *, dtype="float32",
                platform: Optional[str] = None, signs: bool = False,
                sharded: bool = False, devices: int = 1, batch: int = 1,
                shared_sequence: bool = True,
                live_planes: Optional[int] = None,
                autotune: bool = False, autotune_top: int = 3) -> Plan:
    """Pick ``(method, n_b, k_b, m_blk)`` for a problem, with caching.

    Cost-model ranking by default; with ``autotune=True`` the top
    ``autotune_top`` modeled plans are measured end-to-end and the
    fastest wins.  Winning plans are cached per
    ``(m, n, k, dtype, platform, signs, sharded[, batch])`` — an
    autotuned (measured) entry overwrites a model-ranked one for the
    same key and is then reused by plain ``method="auto"`` calls too.

    ``batch`` is the number of independent ``(m, n)`` targets served per
    application (see :class:`Problem`): the amortization terms differ,
    so batch 64 can legitimately pick a different backend than batch 1.
    ``shared_sequence=False`` marks a *per-request* batch (one distinct
    sequence per target, the serving path): per-sequence setup terms
    multiply by ``b`` instead of amortizing, the cache key carries a
    ``"per_req"`` marker, and autotune measures ``b`` distinct
    sequences through the real batched dispatch — additionally timing
    the best candidate of *every* eligible backend, because the
    traffic model cannot see fused/vmap/loop execution constants
    (docs/cost-model.md, "the per-request correction").
    ``live_planes`` is the statically-known count of non-identity
    planes (``RotationSequence.k_live``): plane-skipping backends price
    padded/staircase grids by their live fraction, so a ``seq.T``
    application plans differently from a dense one of the same shape.
    ``devices`` is the mesh size of a sharded execution (``devices > 1``
    implies ``sharded=True``): stream terms divide across shards and
    the wave-panel broadcast is priced at link bandwidth, so
    ``method="auto"`` with a mesh genuinely arbitrates sharded-fused vs
    replicated.  Sharded keys form their own cache class per device
    count and are never persisted or interpolated across mesh sizes.

    Unmeasured shapes first try **cross-shape interpolation**: the
    nearest measured/persisted plan of the same eligibility class
    (identical dtype/platform/signs/sharded, eligible backend) by
    ``(m, n, k, batch)`` log-distance is borrowed
    (``source="interpolated"``) before the cost model is re-run, so
    autotune work transfers to neighbouring problem sizes.  A later
    ``autotune=True`` call upgrades a borrowed entry in place — the
    borrowed plan's tiles join the measured candidate set, and the
    winning measurement is persisted (exactly once) like any other.
    """
    import jax.numpy as jnp

    platform = platform or compat.default_platform()
    dtype = str(jnp.dtype(dtype))
    batch = max(1, int(batch))
    devices = max(1, int(devices))
    sharded = bool(sharded) or devices > 1
    # a batch of one is its own sequence either way: normalize so the
    # legacy cache key (and plan) is shared by both spellings
    shared_sequence = bool(shared_sequence) or batch <= 1
    # Measurements time THIS host's default backend; for any other
    # platform (or a shard_map sub-problem, which can't be reproduced
    # standalone) fall back to model ranking rather than cache bogus
    # numbers — and then accept a cached model-ranked entry, since a
    # measured one can never exist for this key.
    can_measure = platform == compat.default_platform() and not sharded
    autotune = autotune and can_measure
    problem = Problem(m=m, n=n, k=k, dtype=dtype, platform=platform,
                      signs=signs, sharded=sharded, batch=batch,
                      shared_sequence=shared_sequence,
                      live_planes=live_planes, devices=devices)
    key = _plan_key(problem)
    cached = _PLAN_CACHE.get(key)
    if cached is not None and (not autotune
                               or cached.source in _PERSISTED_SOURCES):
        _CACHE_STATS["hits"] += 1
        obs.inc("registry.plan_cache.hits")
        return cached
    _CACHE_STATS["misses"] += 1
    obs.inc("registry.plan_cache.misses")

    if n < 2 or k < 1 or m < 1:
        # degenerate: zero rotations (or empty A) — application is a
        # no-op; pick the cheapest backend that accepts the arguments
        best = Plan(method="blocked" if signs else "unoptimized",
                    est_seconds=0.0)
        _PLAN_CACHE[key] = best
        return best

    with obs.span("resolve", m=m, n=n, k=k, batch=batch, dtype=dtype,
                  platform=platform, autotune=autotune) as sp:
        if not autotune:
            borrowed = _interpolated_plan(problem, key)
            if borrowed is not None:
                _PLAN_CACHE[key] = borrowed
                obs.inc("registry.plan_cache.interpolated")
                sp.set(method=borrowed.method, source="interpolated")
                return borrowed
        plans = _modeled_plans(problem)
        if not plans:
            raise ValueError(
                f"no registered backend is eligible for {problem}"
            )
        best = plans[0]
        if autotune:
            candidates = plans[:max(1, autotune_top)]
            if batch > 1 and not shared_sequence:
                # Per-request batches execute through fused / vmap /
                # per-element-loop strategies whose constants the §6
                # traffic model cannot see (interpret-mode kernels
                # included), so widen the measured set to the best
                # modeled candidate of every eligible backend and let
                # measurement arbitrate — the model still prunes tiles
                # within each backend.
                seen = {pl.method for pl in candidates}
                for pl in plans:
                    if pl.method not in seen:
                        seen.add(pl.method)
                        candidates.append(pl)
            # an interpolated entry being upgraded is a real hint:
            # measure its tiles too, even when the model does not rank
            # them top-N
            if cached is not None and cached.source == "interpolated" \
                    and not any(
                        (pl.method, pl.n_b, pl.k_b, pl.m_blk)
                        == (cached.method, cached.n_b, cached.k_b,
                            cached.m_blk)
                        for pl in candidates):
                candidates = candidates + [cached]
            timed = []
            for plan in candidates:
                try:
                    secs = _measure_plan(problem, plan)
                except Exception:  # backend crashed at these tiles
                    continue
                timed.append(dataclasses.replace(
                    plan, est_seconds=secs, source="measured"))
            if timed:
                best = min(timed, key=lambda pl: pl.est_seconds)
                if cached is not None:
                    # a cached (model/interpolated) entry was replaced
                    # by a fresh measurement for the same key
                    obs.inc("registry.plan_cache.autotune_upgrade")
        _PLAN_CACHE[key] = best
        sp.set(method=best.method, source=best.source)
    if best.source == "measured":
        save_plan_cache()  # write-through; no-op when persistence is off
    return best
