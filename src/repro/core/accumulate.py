"""Accumulated (GEMM) application of rotation sequences — paper's ``rs_gemm``.

Each parallelogram tile of ``n_b`` waves x ``k_b`` rotations is accumulated
into a dense orthogonal factor ``Q_t`` of size ``w x w`` (``w = k_b + n_b``)
by applying the tile to an identity matrix with the wavefront kernel; the
sweep over ``A`` then becomes a scan of ``(m, w) @ (w, w)`` matmuls.

On CPU (the paper) this trades ~4/3 more flops for MKL GEMM throughput and
only wins for large matrices.  On TPU it is the *natural* formulation: the
MXU delivers ~50x the VPU flop rate, so paying ``2 m w^2`` MXU flops instead
of ``6 m n_b k_b`` VPU flops per tile inverts the paper's CPU conclusion.
Accumulation cost is amortized by ``m / w``.

``Q_t`` is banded (columns of ``Q_t`` mix at most ``k_b`` neighbours below),
but we apply it densely: for ``n_b ~ k_b`` the band covers most of ``Q`` and
dense matmuls keep the MXU at full tilt.

In the registry's cost split (docs/cost-model.md) the factor
accumulation and tile packing are *setup* — per-sequence work, paid
once for a shared-sequence batch but ``b`` times for the serving
path's per-request buckets — while the GEMM sweep is *stream*, scaling
with the rows of ``A``.  That asymmetry is why this backend wins
batched accumulator flushes yet loses serving buckets of the same
shape to the fused kernel.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro import compat

from .blocked import _band_inputs, apply_tile, num_tiles, pack_sheared

__all__ = [
    "accumulate_tile_factors",
    "apply_band_accumulated",
    "rot_sequence_accumulated",
]


def accumulate_tile_factors(Ct, St, Gt, *, dtype=jnp.float32):
    """Accumulate sheared tiles ``(T, n_b, k_b)`` into factors ``(T, w, w)``.

    ``X_out = X_in @ Q_t`` for each tile, so ``Q_t = apply_tile(I)``
    (application is linear and acts identically on every row).
    """
    T, n_b, k_b = Ct.shape
    w = k_b + n_b
    eye = jnp.eye(w, dtype=dtype)
    # inside shard_map the tiles may be device-varying; the identity must
    # carry the same varying-manual-axes type to be a legal loop carry
    # (no-op on JAX versions without vma tracking — see repro.compat)
    eye = compat.pvary_like(eye, Ct)
    return jax.vmap(lambda c, s, g: apply_tile(eye, c, s, g))(Ct, St, Gt)


def apply_band_accumulated(A, Q, *, k_b: int, precision=None):
    """Sweep one band using precomputed tile factors ``Q`` (T, w, w)."""
    T, w, _ = Q.shape
    n_b = w - k_b
    m, n = A.shape
    carry0, fresh = _band_inputs(A, k_b, n_b, T)
    fresh_tiles = fresh.reshape(m, T, n_b).transpose(1, 0, 2)

    def step(carry, xs):
        q, ft = xs
        X = jnp.concatenate([carry, ft], axis=1)
        X = jnp.dot(X, q.astype(X.dtype), precision=precision)
        return X[:, n_b:], X[:, :n_b]

    _, out = jax.lax.scan(step, carry0, (Q, fresh_tiles))
    O = out.transpose(1, 0, 2).reshape(m, T * n_b)
    return jax.lax.slice_in_dim(O, k_b - 1, k_b - 1 + n, axis=1)


@partial(jax.jit, static_argnames=("n_b", "k_b", "reflect"))
def rot_sequence_accumulated(A, C, S, *, n_b: int = 128, k_b: int = 128,
                             reflect: bool = False, G=None):
    """Full ``rs_gemm``-style application: accumulate tiles, apply as GEMMs."""
    m, n = A.shape
    J, k = C.shape
    assert J == n - 1
    n_b = min(n_b, max(8, n))
    T = num_tiles(n, n_b, k_b)
    for p0 in range(0, k, k_b):
        Ct, St, Gt = pack_sheared(C, S, p0, k_b, n_b, T, reflect=reflect,
                                  G=G)
        Q = accumulate_tile_factors(Ct, St, Gt, dtype=A.dtype)
        A = apply_band_accumulated(A, Q, k_b=k_b)
    return A
