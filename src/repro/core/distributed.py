"""Deprecated compat wrapper over :mod:`repro.dist` (PR 10).

The distributed layer now lives in ``repro.dist`` as a first-class
plan: :func:`repro.dist.plan_sharded` resolves mesh + specs + backend
once into a frozen :class:`~repro.dist.ShardedSequencePlan`, and the
``method="auto"`` path arbitrates sharded-fused vs replicated through
the comm-extended §6 cost model.  This module mirrors the
``core.api.apply_rotation_sequence`` precedent: every entry point
delegates to ``repro.dist`` after a ``DeprecationWarning``.

Migration table:

  ==========================================  =============================
  legacy call (this module)                   repro.dist API
  ==========================================  =============================
  ``rot_sequence_row_sharded(A, seq, mesh)``  ``dist.rot_sequence_row_sharded``
  repeated row-sharded applications           ``dist.plan_sharded(...).apply``
  ``rot_sequence_column_sharded(...)``        ``dist.rot_sequence_column_sharded``
  ``rot_sequence_column_sharded_padded(...)`` ``dist.rot_sequence_column_sharded_padded``
  ``column_sharded_comm_bytes(...)``          ``dist.column_sharded_comm_bytes``
  ==========================================  =============================

The raw-array positional form ``(A, C, S, mesh)`` — deprecated one
release ago — has been removed everywhere; wrap loose waves in a
:class:`~repro.core.sequence.RotationSequence`.
"""
from __future__ import annotations

import warnings

__all__ = [
    "rot_sequence_row_sharded",
    "rot_sequence_column_sharded",
    "rot_sequence_column_sharded_padded",
    "column_sharded_comm_bytes",
]


def _warn(name: str) -> None:
    warnings.warn(
        f"repro.core.distributed.{name} is deprecated; use "
        f"repro.dist.{name} (or plan_sharded for repeated "
        f"applications)", DeprecationWarning, stacklevel=3)


def rot_sequence_row_sharded(A, seq, mesh=None, **kw):
    """Deprecated: see :func:`repro.dist.rot_sequence_row_sharded`."""
    from repro import dist

    _warn("rot_sequence_row_sharded")
    return dist.rot_sequence_row_sharded(A, seq, mesh, **kw)


def rot_sequence_column_sharded(A, seq, mesh=None, **kw):
    """Deprecated: see :func:`repro.dist.rot_sequence_column_sharded`."""
    from repro import dist

    _warn("rot_sequence_column_sharded")
    return dist.rot_sequence_column_sharded(A, seq, mesh, **kw)


def rot_sequence_column_sharded_padded(A, seq, mesh=None, **kw):
    """Deprecated: see
    :func:`repro.dist.rot_sequence_column_sharded_padded`."""
    from repro import dist

    _warn("rot_sequence_column_sharded_padded")
    return dist.rot_sequence_column_sharded_padded(A, seq, mesh, **kw)


def column_sharded_comm_bytes(m_loc, n, k, D, n_b, k_b, itemsize=4, **kw):
    """Deprecated: see :func:`repro.dist.column_sharded_comm_bytes`."""
    from repro import dist

    _warn("column_sharded_comm_bytes")
    return dist.column_sharded_comm_bytes(m_loc, n, k, D, n_b, k_b,
                                          itemsize, **kw)
