"""Cost-model-attributed roofline records.

The registry's §6 cost model predicts memops and flops for every
candidate plan — but until this module, nothing ever compared those
predictions against what a dispatch actually did, so a mis-modelled
backend could win ``method="auto"`` forever without anyone noticing.

Every instrumented dispatch (``SequencePlan.apply`` /
``apply_batched``) records the resolved problem, chosen backend+tile,
live-plane count, the model's predicted flops / bytes / seconds
(computed by :func:`repro.core.registry.cost_components` — the same
arithmetic the planner ranked candidates with), and the measured wall
time.  ``model_fraction = predicted_s / measured_s``: ≈1 means the
model explains the dispatch, ≪1 means the backend is far off its
modelled roofline (or the model is wrong — either way, worth a look),
and drift over time is visible in the exported BENCH/OBS artifacts.

Predictions are pure arithmetic on problem shape; only ``measured_s``
and ``model_fraction`` touch the clock, and :func:`snapshot` mirrors
the metrics convention so ``metrics.zeroed_timings`` can strip exactly
those fields for determinism tests.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, List

_lock = threading.Lock()
_records: List[Dict[str, Any]] = []

# keep the per-dispatch list bounded: serving loops can dispatch
# millions of times, and per-backend aggregates carry the signal
_MAX_RECORDS = 4096


def record_dispatch(*, backend: str, m_total: int, n: int, k: int,
                    batch: int, dtype: str, tile: Dict[str, Any],
                    planes_live: int, planes_total: int,
                    predicted_flops: float, predicted_bytes: float,
                    predicted_s: float, measured_s: float,
                    predicted_setup_s: float = 0.0,
                    predicted_stream_s: float = 0.0,
                    shared_sequence: bool = True,
                    comm_bytes: float = 0.0,
                    launches_per_shard: int = 0) -> None:
    frac = predicted_s / measured_s if measured_s > 0.0 else 0.0
    rec = {
        "backend": backend,
        "m_total": int(m_total),
        "n": int(n),
        "k": int(k),
        "batch": int(batch),
        "dtype": str(dtype),
        "tile": dict(tile),
        "planes_live": int(planes_live),
        "planes_total": int(planes_total),
        # per-request batches (shared_sequence=False) pay per-sequence
        # setup b times; the setup/stream attribution seconds are the
        # penalty-free per-term split from registry.cost_components
        "shared_sequence": bool(shared_sequence),
        "predicted_flops": float(predicted_flops),
        "predicted_bytes": float(predicted_bytes),
        "predicted_setup_s": float(predicted_setup_s),
        "predicted_stream_s": float(predicted_stream_s),
        "predicted_s": float(predicted_s),
        "measured_s": float(measured_s),
        "model_fraction": float(frac),
        # sharded dispatches (repro.dist): modeled inter-device traffic
        # and planned launches per shard (acceptance bar: exactly 1 for
        # the fused row-sharded path); 0/0 for single-device rows
        "comm_bytes": float(comm_bytes),
        "launches_per_shard": int(launches_per_shard),
    }
    with _lock:
        if len(_records) < _MAX_RECORDS:
            _records.append(rec)
        else:
            _records.append(rec)
            del _records[0]


def records() -> List[Dict[str, Any]]:
    with _lock:
        return [dict(r) for r in _records]


def reset() -> None:
    with _lock:
        _records.clear()


def snapshot() -> dict:
    """Per-dispatch records + per-backend aggregates, JSON-clean."""
    recs = records()
    agg: Dict[str, Dict[str, float]] = {}
    for r in recs:
        a = agg.setdefault(r["backend"], {
            "dispatches": 0, "planes_live": 0, "planes_total": 0,
            "predicted_flops": 0.0, "predicted_bytes": 0.0,
            "predicted_setup_s": 0.0, "predicted_stream_s": 0.0,
            "predicted_s": 0.0, "measured_s": 0.0,
            "comm_bytes": 0.0, "launches_per_shard": 0,
        })
        a["dispatches"] += 1
        a["planes_live"] += r["planes_live"]
        a["planes_total"] += r["planes_total"]
        a["predicted_flops"] += r["predicted_flops"]
        a["predicted_bytes"] += r["predicted_bytes"]
        a["predicted_setup_s"] += r.get("predicted_setup_s", 0.0)
        a["predicted_stream_s"] += r.get("predicted_stream_s", 0.0)
        a["predicted_s"] += r["predicted_s"]
        a["measured_s"] += r["measured_s"]
        a["comm_bytes"] += r.get("comm_bytes", 0.0)
        a["launches_per_shard"] = max(a["launches_per_shard"],
                                      r.get("launches_per_shard", 0))
    for a in agg.values():
        a["model_fraction"] = (a["predicted_s"] / a["measured_s"]
                               if a["measured_s"] > 0.0 else 0.0)
        split = a["predicted_setup_s"] + a["predicted_stream_s"]
        # share of the modeled (penalty-free) time spent on per-sequence
        # setup: ~1 flags a backend rebuilding factors per request
        a["setup_fraction"] = (a["predicted_setup_s"] / split
                               if split > 0.0 else 0.0)
    return {"dispatches": recs,
            "by_backend": {k: agg[k] for k in sorted(agg)}}
