"""Counters, gauges, and deterministic log-spaced-bucket histograms.

Design constraints (tentpole spec + rule family RA5):

- **No wall-clock or RNG anywhere in here.**  Histograms bucket by pure
  arithmetic on the observed value; callers that want to observe a
  duration measure it themselves via :mod:`repro.obs.timing`.
- **Deterministic buckets.**  Bucket boundaries are fixed log-spaced
  points (``_BASE * 10**(i / _PER_DECADE)``), so the *structure* of a
  snapshot — which metrics exist, observation counts, bucket layout —
  is bit-identical across runs of the same workload.  Only fields
  derived from observed *values* (sum/min/max/percentiles and, for
  seconds-valued histograms, the bucket distribution itself) vary with
  machine speed; :func:`zeroed_timings` strips exactly those so tests
  can assert bit-identical snapshots.
- **Snapshot is plain JSON.**  ``snapshot()`` returns nested dicts of
  str/int/float only, sorted keys, ready for ``json.dump``.

Metric names are dotted, lowercase, ``component.thing`` (e.g.
``registry.plan_cache.hits``, ``serve.request_latency_seconds``).  The
README "Observability" section tabulates every name emitted by the
instrumented seams.
"""
from __future__ import annotations

import json
import math
import threading
from typing import Dict, Iterable, Optional

# Histogram bucket i covers [_BASE * 10**(i/_PER_DECADE),
# _BASE * 10**((i+1)/_PER_DECADE)).  _BASE=1e-7 s puts sub-100ns
# observations in bucket 0; 10 buckets per decade gives ~26% relative
# resolution, plenty for p50/p99 on serving latencies.
_BASE = 1e-7
_PER_DECADE = 10
_N_BUCKETS = 110  # covers _BASE .. _BASE * 10**11 = 1e4 s


def bucket_index(value: float) -> int:
    """Deterministic bucket for ``value`` (clamped to the range)."""
    if value <= _BASE:
        return 0
    i = int(math.floor(math.log10(value / _BASE) * _PER_DECADE))
    return min(max(i, 0), _N_BUCKETS - 1)


def bucket_bounds(i: int) -> tuple[float, float]:
    lo = _BASE * 10.0 ** (i / _PER_DECADE)
    hi = _BASE * 10.0 ** ((i + 1) / _PER_DECADE)
    return lo, hi


class Counter:
    """Thread-safe monotonic counter.

    Metrics are mutated concurrently — the stream engine's scheduler
    and dispatcher threads and the caller's thread all increment
    serving counters — so every mutator serializes on a lock
    (registry-shared when created through :class:`MetricsRegistry`, so
    snapshots are consistent cuts).  ``value += delta`` without it is a
    load/add/store race that silently drops increments.
    """

    __slots__ = ("name", "value", "_lock")

    def __init__(self, name: str, lock: Optional[threading.Lock] = None):
        self.name = name
        self.value = 0
        self._lock = lock if lock is not None else threading.Lock()

    def inc(self, delta: int = 1) -> None:
        with self._lock:
            self.value += delta


class Gauge:
    __slots__ = ("name", "value", "_lock")

    def __init__(self, name: str, lock: Optional[threading.Lock] = None):
        self.name = name
        self.value = 0.0
        self._lock = lock if lock is not None else threading.Lock()

    def set(self, value: float) -> None:
        v = float(value)  # coerce outside the lock: may raise
        with self._lock:
            self.value = v


class Histogram:
    """Log-spaced-bucket histogram; ``unit="seconds"`` marks fields as
    timing-derived for :func:`zeroed_timings`.  ``observe`` mutates
    five fields together, so concurrent observers serialize on the
    (registry-shared) lock to keep count/sum/buckets mutually
    consistent."""

    __slots__ = ("name", "unit", "count", "sum", "min", "max", "buckets",
                 "_lock")

    def __init__(self, name: str, unit: str = "seconds",
                 lock: Optional[threading.Lock] = None):
        self.name = name
        self.unit = unit
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self.buckets: Dict[int, int] = {}
        self._lock = lock if lock is not None else threading.Lock()

    def observe(self, value: float) -> None:
        v = float(value)
        i = bucket_index(v)
        with self._lock:
            self.count += 1
            self.sum += v
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v
            self.buckets[i] = self.buckets.get(i, 0) + 1

    def percentile(self, q: float) -> float:
        """Percentile estimate from the cumulative bucket counts:
        geometric midpoint of the bucket containing quantile ``q``."""
        if self.count == 0:
            return 0.0
        target = q * self.count
        seen = 0
        for i in sorted(self.buckets):
            seen += self.buckets[i]
            if seen >= target:
                lo, hi = bucket_bounds(i)
                return math.sqrt(lo * hi)
        lo, hi = bucket_bounds(max(self.buckets))
        return math.sqrt(lo * hi)


class MetricsRegistry:
    """Process-global named metrics; thread-safe creation *and*
    mutation (every metric shares the registry lock, so a snapshot is
    a consistent cut across all metrics), plain-dict snapshot export."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            with self._lock:
                c = self._counters.setdefault(name,
                                              Counter(name, self._lock))
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            with self._lock:
                g = self._gauges.setdefault(name, Gauge(name, self._lock))
        return g

    def histogram(self, name: str, unit: str = "seconds") -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            with self._lock:
                h = self._histograms.setdefault(
                    name, Histogram(name, unit, self._lock))
        return h

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()

    def snapshot(self) -> dict:
        with self._lock:
            counters = {n: c.value for n, c in sorted(self._counters.items())}
            gauges = {n: g.value for n, g in sorted(self._gauges.items())}
            hists = {}
            for n, h in sorted(self._histograms.items()):
                hists[n] = {
                    "unit": h.unit,
                    "count": h.count,
                    "sum": h.sum,
                    "min": 0.0 if h.count == 0 else h.min,
                    "max": 0.0 if h.count == 0 else h.max,
                    "p50": h.percentile(0.50),
                    "p99": h.percentile(0.99),
                    "buckets": {str(i): h.buckets[i]
                                for i in sorted(h.buckets)},
                }
        return {"counters": counters, "gauges": gauges,
                "histograms": hists}


GLOBAL = MetricsRegistry()


def zeroed_timings(snap: dict) -> dict:
    """Copy of a snapshot with machine-speed-dependent fields zeroed.

    Counters, gauges, histogram observation counts, and the bucket
    distributions of count-valued histograms (``unit != "seconds"``)
    are kept verbatim — they are deterministic for a fixed workload.
    For seconds-valued histograms the value-derived fields
    (sum/min/max/p50/p99/buckets) are zeroed; roofline records (if
    present) lose ``measured_s`` / ``model_fraction``.  Two runs of the
    same request stream must produce bit-identical zeroed snapshots.
    """
    out = json.loads(json.dumps(snap))  # cheap deep copy, JSON-clean
    for h in out.get("histograms", {}).values():
        if h.get("unit") == "seconds":
            h["sum"] = 0.0
            h["min"] = 0.0
            h["max"] = 0.0
            h["p50"] = 0.0
            h["p99"] = 0.0
            h["buckets"] = {}
    roof = out.get("roofline")
    if roof:
        for rec in roof.get("dispatches", []):
            rec["measured_s"] = 0.0
            rec["model_fraction"] = 0.0
        for agg in roof.get("by_backend", {}).values():
            agg["measured_s"] = 0.0
            agg["model_fraction"] = 0.0
    return out


def merge_names(*groups: Iterable[str]) -> list[str]:
    """Sorted union of metric-name iterables (doc/report helper)."""
    names: set[str] = set()
    for g in groups:
        names.update(g)
    return sorted(names)
