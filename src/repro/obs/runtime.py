"""Observability on/off switch and trace configuration.

``repro.obs`` is zero-overhead when disabled: every public hook checks
:func:`enabled` first and returns a shared null object.  The switch is
read once from ``REPRO_OBS`` at import (default **off** — tier-1 tests
and any code path that must stay bit-identical never pay for
instrumentation), and can be flipped programmatically for tests and
launchers via :func:`set_enabled` / :func:`override`.

``REPRO_OBS_TRACE`` optionally names a Chrome trace-event JSONL output
path; when set (and obs is on), host-side spans are buffered and
exported there by :func:`repro.obs.write_trace` /
:func:`repro.obs.flush`.

Nothing in this module touches wall clocks or RNG — it is pure
configuration state, safe to import from cost-model and plan-key code
(rule family RA5).
"""
from __future__ import annotations

import os
from contextlib import contextmanager

_TRUTHY = ("1", "on", "true", "yes")


def _env_enabled() -> bool:
    return os.environ.get("REPRO_OBS", "").strip().lower() in _TRUTHY


_enabled: bool = _env_enabled()
_trace_path: str | None = os.environ.get("REPRO_OBS_TRACE") or None


def enabled() -> bool:
    """True when instrumentation hooks should record."""
    return _enabled


def set_enabled(flag: bool) -> bool:
    """Set the obs switch; returns the previous value."""
    global _enabled
    prev = _enabled
    _enabled = bool(flag)
    return prev


@contextmanager
def override(flag: bool):
    """Temporarily force obs on/off (tests, launchers)."""
    prev = set_enabled(flag)
    try:
        yield
    finally:
        set_enabled(prev)


def trace_enabled() -> bool:
    """True when spans should be buffered for trace export."""
    return _enabled and _trace_path is not None


def trace_path() -> str | None:
    """Configured trace output path (``REPRO_OBS_TRACE``), if any."""
    return _trace_path


def set_trace_path(path: str | None) -> str | None:
    """Set the trace output path; returns the previous value."""
    global _trace_path
    prev = _trace_path
    _trace_path = path
    return prev
