"""The single sanctioned wall-clock in the tree.

Every host-side timing measurement — benchmark loops, span durations,
roofline measured seconds, launcher throughput prints — goes through
:func:`now`.  Analyzer rule RA502 bans direct ``time.perf_counter`` /
``time.time`` / ``timeit`` references everywhere else (only this
module and ``benchmarks/common.py`` are exempt), so "who is allowed to
look at the clock" is a one-line grep instead of an audit.

Keeping the clock behind one function also keeps rule family RA5
honest: cost-model and plan-key code imports :mod:`repro.obs` freely
because the clock lives *here*, never inline in key paths.
"""
from __future__ import annotations

import time


def now() -> float:
    """Monotonic seconds for interval measurement (perf_counter)."""
    return time.perf_counter()


def wall_unix() -> float:
    """Unix epoch seconds — artifact timestamps only, never keys."""
    return time.time()
