"""Host-side tracing spans → Chrome trace-event JSON (Perfetto).

Spans are recorded as complete events (``"ph": "X"``) with
microsecond timestamps relative to the first span in the buffer, one
thread lane per Python thread.  :func:`write_trace` emits the
``{"traceEvents": [...]}`` wrapper with one event per line — the file
loads directly in https://ui.perfetto.dev or ``chrome://tracing``.

Spans must only ever wrap *host* code (plan resolution, bucket drains,
flushes, blocking apply calls).  Nothing here is safe or meaningful
inside jit/traced code, which is why the instrumented seams guard with
``repro.compat.is_tracer(x)`` before opening a span.
"""
from __future__ import annotations

import json
import threading
from typing import Any, Dict, List

from repro.obs import runtime, timing

_lock = threading.Lock()
_events: List[Dict[str, Any]] = []
_origin: float | None = None


class _Span:
    """Context manager recording one complete ("X") trace event."""

    __slots__ = ("name", "args", "_t0")

    def __init__(self, name: str, args: Dict[str, Any]):
        self.name = name
        self.args = args
        self._t0 = 0.0

    def __enter__(self) -> "_Span":
        self._t0 = timing.now()
        return self

    def set(self, **kw: Any) -> None:
        """Attach extra args discovered mid-span (e.g. batch size)."""
        self.args.update(kw)

    def __exit__(self, *exc) -> None:
        t1 = timing.now()
        global _origin
        with _lock:
            if _origin is None:
                _origin = self._t0
            _events.append({
                "name": self.name,
                "ph": "X",
                "ts": round((self._t0 - _origin) * 1e6, 3),
                "dur": round((t1 - self._t0) * 1e6, 3),
                "pid": 1,
                "tid": threading.get_ident() & 0xFFFF,
                "args": self.args,
            })


class _NullSpan:
    """Shared no-op span: the disabled fast path allocates nothing."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def set(self, **kw: Any) -> None:
        pass

    def __exit__(self, *exc) -> None:
        pass


NULL_SPAN = _NullSpan()


def span(name: str, **args: Any):
    """Open a span when tracing is live; shared null object otherwise."""
    if not runtime.trace_enabled():
        return NULL_SPAN
    return _Span(name, args)


def events() -> List[Dict[str, Any]]:
    with _lock:
        return list(_events)


def reset() -> None:
    global _origin
    with _lock:
        _events.clear()
        _origin = None


def write_trace(path: str) -> int:
    """Write buffered spans as Chrome trace JSON; returns event count."""
    evs = events()
    with open(path, "w") as f:
        f.write('{"traceEvents": [\n')
        for i, ev in enumerate(evs):
            sep = ",\n" if i + 1 < len(evs) else "\n"
            f.write(json.dumps(ev, sort_keys=True) + sep)
        f.write("]}\n")
    return len(evs)
