"""repro.obs — zero-overhead-when-disabled observability.

Three pieces (tentpole of PR 7):

- **Metrics** (:mod:`repro.obs.metrics`): counters, gauges, and
  deterministic log-spaced-bucket histograms, exported as a plain-JSON
  snapshot.  No wall-clock or RNG in any metrics path (RA5).
- **Tracing** (:mod:`repro.obs.trace`): host-side spans around
  plan / resolve / rebind / apply / flush / admit / drain, exported as
  Chrome trace-event JSON viewable in Perfetto.
- **Roofline attribution** (:mod:`repro.obs.roofline`): per-dispatch
  predicted-vs-measured records driven by the registry's §6 cost
  model.

Configuration is environment-first: ``REPRO_OBS=on`` enables
recording (default off — the tier-1 suite runs with every hook on the
shared null fast path), ``REPRO_OBS_TRACE=PATH`` additionally buffers
spans for trace export.  Tests and launchers flip the switch
programmatically via :func:`set_enabled` / :func:`override`.

This package is also the **single sanctioned home for timing**
(:mod:`repro.obs.timing`); analyzer rule RA502 lint-errors ad-hoc
``time.perf_counter`` / ``time.time`` / ``timeit`` references
anywhere else in ``repro.*`` / ``benchmarks.*`` / ``examples.*``
(``benchmarks/common.py`` is the one exempt shim).
"""
from __future__ import annotations

import json
from typing import Any

from repro.obs import metrics, roofline, runtime, timing, trace
from repro.obs.metrics import zeroed_timings
from repro.obs.runtime import enabled, override, set_enabled
from repro.obs.trace import span

__all__ = [
    "enabled", "set_enabled", "override", "span", "inc", "gauge",
    "observe", "snapshot", "reset", "write_metrics_json", "write_trace",
    "zeroed_timings", "timing", "metrics", "roofline", "runtime",
    "trace",
]


def inc(name: str, delta: int = 1) -> None:
    """Bump a counter (no-op while obs is disabled)."""
    if runtime._enabled:
        metrics.GLOBAL.counter(name).inc(delta)


def gauge(name: str, value: float) -> None:
    """Set a gauge (no-op while obs is disabled)."""
    if runtime._enabled:
        metrics.GLOBAL.gauge(name).set(value)


def observe(name: str, value: float, unit: str = "seconds") -> None:
    """Record a histogram observation (no-op while obs is disabled)."""
    if runtime._enabled:
        metrics.GLOBAL.histogram(name, unit).observe(value)


def snapshot() -> dict:
    """Full metrics + roofline snapshot as a JSON-clean dict."""
    snap = metrics.GLOBAL.snapshot()
    snap["roofline"] = roofline.snapshot()
    return snap


def reset() -> None:
    """Clear all recorded metrics, spans, and roofline records."""
    metrics.GLOBAL.reset()
    roofline.reset()
    trace.reset()


def write_metrics_json(path: str, extra: dict[str, Any] | None = None) -> dict:
    """Dump :func:`snapshot` (plus optional ``extra`` meta) to ``path``."""
    snap = snapshot()
    if extra:
        snap["meta"] = extra
    with open(path, "w") as f:
        json.dump(snap, f, indent=1, sort_keys=True)
        f.write("\n")
    return snap


def write_trace(path: str | None = None) -> int:
    """Export buffered spans as Chrome trace JSON; returns event count.

    Defaults to the ``REPRO_OBS_TRACE`` path; no-ops (returns 0) when
    neither is set.
    """
    target = path or runtime.trace_path()
    if not target:
        return 0
    return trace.write_trace(target)
