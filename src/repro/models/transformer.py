"""Decoder-only transformer covering the dense / MoE / MLA / windowed
families (starcoder2, smollm, llama3, gemma3, chameleon, deepseek-v2,
kimi-k2).

Layer stacking uses **grouped scan**: the layer pattern's repeating unit
(period ``P``) is unrolled inside the scan body and weights are stacked
``(n_groups, ...)`` — HLO size stays O(period), compile time stays bounded
at 126-layer scale, and remat applies per group.  Non-divisible tails are
handled by a second short scan.

Per-slot layer kinds within a period (from ``ModelConfig``):
  * ``pattern_global`` slots use full attention (+ ``rope_base_global``);
    other slots use sliding-window attention when ``cfg.window`` is set.
  * slots below ``first_dense_layers`` (global layer index) use the dense
    MLP; all other slots use MoE when ``cfg.n_experts > 0``.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.parallel.sharding import shard

from .attention import (gqa_attention, gqa_decode, gqa_init, gqa_spec,
                        init_kv_cache, init_mla_cache, mla_attention,
                        mla_decode, mla_init, mla_spec)
from .layers import (dense, dense_init, dense_spec, embed_init, embed_spec,
                     mlp_gelu, mlp_init, mlp_spec, mlp_swiglu, rmsnorm,
                     rmsnorm_init, rmsnorm_spec, softcap)
from .moe import moe_ffn, moe_init, moe_spec

__all__ = ["Transformer"]


def _layer_kinds(cfg):
    """(attn_kind, mlp_kind) per layer index."""
    kinds = []
    for i in range(cfg.n_layers):
        slot = i % cfg.pattern_period
        attn = "global" if slot in cfg.pattern_global else "local"
        if cfg.window is None:
            attn = "global"
        mlp = "dense"
        if cfg.n_experts and i >= cfg.first_dense_layers:
            mlp = "moe"
        kinds.append((attn, mlp))
    return kinds


def _groups(cfg):
    """Split layers into (start, count, kinds-per-slot) scan groups.

    Groups are maximal runs where the kind pattern repeats with period
    ``cfg.pattern_period`` (and MoE/dense membership is uniform per slot).
    """
    kinds = _layer_kinds(cfg)
    P = cfg.pattern_period
    groups = []
    i = 0
    while i < len(kinds):
        # find the longest run of whole periods with identical slot kinds
        slot_kinds = tuple(kinds[i:i + P])
        if len(slot_kinds) < P:
            groups.append((i, len(kinds) - i, tuple(kinds[i:])))
            break
        j = i
        while (j + P <= len(kinds)
               and tuple(kinds[j:j + P]) == slot_kinds):
            j += P
        groups.append((i, j - i, slot_kinds))
        i = j
    return groups


class Transformer:
    """Functional decoder-only LM; see module docstring."""

    def __init__(self, cfg):
        self.cfg = cfg
        self.groups = _groups(cfg)

    # ----------------------------------------------------------- init ----

    def _block_init(self, key, kinds, dtype):
        cfg = self.cfg
        attn_kind, mlp_kind = kinds
        ka, km, k1, k2 = jax.random.split(key, 4)
        attn = (mla_init(ka, cfg, dtype) if cfg.mla
                else gqa_init(ka, cfg, dtype))
        if mlp_kind == "moe":
            mlp = moe_init(km, cfg, dtype)
        else:
            mlp = mlp_init(km, cfg.d_model, cfg.d_ff, cfg.mlp_gated, dtype)
        return {
            "ln1": rmsnorm_init(cfg.d_model, dtype),
            "attn": attn,
            "ln2": rmsnorm_init(cfg.d_model, dtype),
            "mlp": mlp,
        }

    def _block_spec(self, kinds):
        cfg = self.cfg
        attn_kind, mlp_kind = kinds
        attn = mla_spec(cfg) if cfg.mla else gqa_spec(cfg)
        mlp = moe_spec(cfg) if mlp_kind == "moe" else mlp_spec(cfg.mlp_gated)
        return {
            "ln1": rmsnorm_spec(),
            "attn": attn,
            "ln2": rmsnorm_spec(),
            "mlp": mlp,
        }

    def init(self, key, dtype=jnp.float32):
        cfg = self.cfg
        keys = jax.random.split(key, 2 + len(self.groups))
        params: Dict[str, Any] = {
            "embed": embed_init(keys[0], cfg.vocab, cfg.d_model, dtype),
            "ln_f": rmsnorm_init(cfg.d_model, dtype),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(keys[1], cfg.d_model, cfg.vocab,
                                           dtype)
        for gi, (start, count, slot_kinds) in enumerate(self.groups):
            P = len(slot_kinds)
            reps = count // P
            gkeys = jax.random.split(keys[2 + gi], reps * P)

            def one_rep(ks):
                return [self._block_init(ks[s], slot_kinds[s], dtype)
                        for s in range(P)]

            # stack rep-wise: list over slots of stacked (reps, ...) trees
            reptrees = [one_rep(gkeys[r * P:(r + 1) * P])
                        for r in range(reps)]
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *reptrees)
            params[f"group{gi}"] = stacked
        return params

    def param_logical(self):
        cfg = self.cfg
        spec: Dict[str, Any] = {
            "embed": embed_spec(),
            "ln_f": rmsnorm_spec(),
        }
        if not cfg.tie_embeddings:
            spec["lm_head"] = dense_spec("embed", "vocab")
        for gi, (start, count, slot_kinds) in enumerate(self.groups):
            P = len(slot_kinds)
            slots = [self._block_spec(slot_kinds[s]) for s in range(P)]
            # stacked leading axis is the scan (reps) axis: never sharded
            spec[f"group{gi}"] = jax.tree.map(
                lambda t: (None,) + t, slots,
                is_leaf=lambda t: isinstance(t, tuple),
            )
        return spec

    # -------------------------------------------------------- forward ----

    def _block_apply(self, p, kinds, x, layer_idx):
        cfg = self.cfg
        attn_kind, mlp_kind = kinds
        h = rmsnorm(p["ln1"], x)
        if cfg.mla:
            a, _ = mla_attention(p["attn"], cfg, h)
        else:
            window = cfg.window if attn_kind == "local" else None
            base = (cfg.rope_base_global
                    if (attn_kind == "global" and cfg.rope_base_global)
                    else cfg.rope_base)
            a, _ = gqa_attention(p["attn"], cfg, h, window=window,
                                 rope_base=base)
        # seq-shard the partial attention output BEFORE the residual add:
        # the partial-sum + constraint pair lowers to a reduce-scatter
        # instead of all-reduce + slice (halves SP collective volume)
        x = x + shard(a, "batch", "seq", "embed")
        h = rmsnorm(p["ln2"], x)
        if mlp_kind == "moe":
            m = moe_ffn(p["mlp"], cfg, h)
        elif cfg.mlp_gated:
            m = mlp_swiglu(p["mlp"], h)
        else:
            m = mlp_gelu(p["mlp"], h)
        x = x + shard(m, "batch", "seq", "embed")
        return shard(x, "batch", "seq", "embed")

    def forward(self, params, tokens, *, remat: bool = True):
        """tokens (B, S) int32 -> logits (B, S, vocab)."""
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        x = params["embed"]["e"].astype(dt)[tokens]
        if cfg.emb_scale:
            x = x * jnp.sqrt(jnp.asarray(cfg.d_model, dt))
        x = shard(x, "batch", "seq", "embed")

        for gi, (start, count, slot_kinds) in enumerate(self.groups):
            stacked = params[f"group{gi}"]  # list over slots, leaves (reps,..)

            def body(x, rep_p, _kinds=slot_kinds, _start=start):
                for s in range(len(_kinds)):
                    x = self._block_apply(rep_p[s], _kinds[s], x, _start + s)
                return x, None

            f = jax.checkpoint(body, prevent_cse=False) if remat else body
            x, _ = jax.lax.scan(f, x, stacked)

        x = rmsnorm(params["ln_f"], x)
        x = shard(x, "batch", None, "embed")  # SP: gather seq for lm head
        if cfg.tie_embeddings:
            logits = x @ params["embed"]["e"].astype(dt).T
        else:
            logits = dense(params["lm_head"], x)
        logits = softcap(logits, cfg.logit_softcap)
        return shard(logits, "batch", None, "vocab")

    # ---------------------------------------------------------- decode ----

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        cache = {"idx": jnp.zeros((), jnp.int32)}
        for gi, (start, count, slot_kinds) in enumerate(self.groups):
            P = len(slot_kinds)
            reps = count // P
            slots = []
            for s_ in range(P):
                if cfg.mla:
                    one = {
                        "ckv": jnp.zeros((reps, batch, max_len,
                                          cfg.kv_lora), dtype),
                        "kr": jnp.zeros((reps, batch, max_len,
                                         cfg.qk_rope_dim), dtype),
                    }
                else:
                    # sliding-window layers only ever need `window` slots
                    # (ring buffer; see gqa_decode) — 512x smaller cache
                    # for gemma3's 29 local layers at 500k tokens
                    is_local = (slot_kinds[s_][0] == "local"
                                and cfg.window is not None)
                    length = min(cfg.window, max_len) if is_local \
                        else max_len
                    one = {
                        "k": jnp.zeros((reps, batch, length,
                                        cfg.n_kv_heads, cfg.head_dim),
                                       dtype),
                        "v": jnp.zeros((reps, batch, length,
                                        cfg.n_kv_heads, cfg.head_dim),
                                       dtype),
                    }
                slots.append(one)
            cache[f"group{gi}"] = slots
        return cache

    def cache_logical(self):
        cfg = self.cfg
        spec = {"idx": ()}
        for gi, (start, count, slot_kinds) in enumerate(self.groups):
            P = len(slot_kinds)
            if cfg.mla:
                one = {"ckv": (None, "batch", "seq", None),
                       "kr": (None, "batch", "seq", None)}
            else:
                one = {"k": (None, "batch", "seq", "kv_heads", None),
                       "v": (None, "batch", "seq", "kv_heads", None)}
            spec[f"group{gi}"] = [dict(one) for _ in range(P)]
        return spec

    def decode_step(self, params, cache, tokens):
        """tokens (B, 1) -> (logits (B, 1, vocab), new cache)."""
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        idx = cache["idx"]
        x = params["embed"]["e"].astype(dt)[tokens]
        if cfg.emb_scale:
            x = x * jnp.sqrt(jnp.asarray(cfg.d_model, dt))
        new_cache = {"idx": idx + 1}

        for gi, (start, count, slot_kinds) in enumerate(self.groups):
            stacked = params[f"group{gi}"]
            gcache = cache[f"group{gi}"]

            def body(x, xs, _kinds=slot_kinds):
                rep_p, rep_c = xs
                new_c = []
                for s in range(len(_kinds)):
                    p, c = rep_p[s], rep_c[s]
                    h = rmsnorm(p["ln1"], x)
                    if cfg.mla:
                        a, ckv, kr = mla_decode(p["attn"], cfg, h,
                                                c["ckv"], c["kr"], idx)
                        new_c.append({"ckv": ckv, "kr": kr})
                    else:
                        attn_kind = _kinds[s][0]
                        window = cfg.window if attn_kind == "local" else None
                        base = (cfg.rope_base_global
                                if (attn_kind == "global"
                                    and cfg.rope_base_global)
                                else cfg.rope_base)
                        a, kc, vc = gqa_decode(p["attn"], cfg, h, c["k"],
                                               c["v"], idx, window=window,
                                               rope_base=base)
                        new_c.append({"k": kc, "v": vc})
                    x = x + a
                    h = rmsnorm(p["ln2"], x)
                    if _kinds[s][1] == "moe":
                        m = moe_ffn(p["mlp"], cfg, h)
                    elif cfg.mlp_gated:
                        m = mlp_swiglu(p["mlp"], h)
                    else:
                        m = mlp_gelu(p["mlp"], h)
                    x = x + m
                return x, new_c

            x, new_gc = jax.lax.scan(body, x, (stacked, gcache))
            new_cache[f"group{gi}"] = new_gc

        x = rmsnorm(params["ln_f"], x)
        if cfg.tie_embeddings:
            logits = x @ params["embed"]["e"].astype(dt).T
        else:
            logits = dense(params["lm_head"], x)
        return softcap(logits, cfg.logit_softcap), new_cache
