"""Whisper-style encoder-decoder transformer backbone.

Per the assignment, the conv/mel frontend is a STUB: ``input_specs``
provides precomputed frame embeddings ``(B, frames, d_model)`` that feed
the (bidirectional) encoder directly.  The decoder is a standard causal
transformer with cross-attention; positions are sinusoidal (encoder) and
learned (decoder) — no RoPE, so the paper's rotation technique reaches
this arch only via the SOAP-Givens optimizer (see DESIGN.md).

Decode: the encoder runs once (prefill), cross-attention K/V are
precomputed per layer and cached alongside the causal self-attention
cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.sharding import shard

from .attention import attn_mask, gqa_decode, gqa_init, gqa_spec, _sdpa, \
    _proj_qkv
from .layers import (dense, dense_init, dense_spec, embed_init, embed_spec,
                     layernorm, layernorm_init, layernorm_spec, mlp_gelu,
                     mlp_init, mlp_spec)

__all__ = ["WhisperBackbone"]


def _sinusoid(length: int, d: int, dtype):
    pos = jnp.arange(length)[:, None].astype(jnp.float32)
    i = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    ang = pos / jnp.power(10000.0, 2 * i / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)],
                           axis=-1).astype(dtype)


class WhisperBackbone:
    def __init__(self, cfg):
        self.cfg = cfg

    # ----------------------------------------------------------- init ----

    def _xattn_init(self, key, dtype):
        cfg = self.cfg
        d, H, Dh = cfg.d_model, cfg.n_heads, cfg.head_dim
        ks = jax.random.split(key, 4)
        return {
            "wq": dense_init(ks[0], d, H * Dh, dtype),
            "wk": dense_init(ks[1], d, H * Dh, dtype),
            "wv": dense_init(ks[2], d, H * Dh, dtype),
            "wo": dense_init(ks[3], H * Dh, d, dtype),
        }

    def _xattn_spec(self):
        return {
            "wq": dense_spec("embed", "heads"),
            "wk": dense_spec("embed", "heads"),
            "wv": dense_spec("embed", "heads"),
            "wo": dense_spec("heads", "embed"),
        }

    def _enc_block_init(self, key, dtype):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        return {
            "ln1": layernorm_init(cfg.d_model, dtype),
            "attn": gqa_init(k1, cfg, dtype),
            "ln2": layernorm_init(cfg.d_model, dtype),
            "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, False, dtype),
        }

    def _dec_block_init(self, key, dtype):
        cfg = self.cfg
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "ln1": layernorm_init(cfg.d_model, dtype),
            "attn": gqa_init(k1, cfg, dtype),
            "lnx": layernorm_init(cfg.d_model, dtype),
            "xattn": self._xattn_init(k2, dtype),
            "ln2": layernorm_init(cfg.d_model, dtype),
            "mlp": mlp_init(k3, cfg.d_model, cfg.d_ff, False, dtype),
        }

    def _enc_block_spec(self):
        return {"ln1": layernorm_spec(), "attn": gqa_spec(self.cfg),
                "ln2": layernorm_spec(), "mlp": mlp_spec(False)}

    def _dec_block_spec(self):
        return {"ln1": layernorm_spec(), "attn": gqa_spec(self.cfg),
                "lnx": layernorm_spec(), "xattn": self._xattn_spec(),
                "ln2": layernorm_spec(), "mlp": mlp_spec(False)}

    def init(self, key, dtype=jnp.float32):
        cfg = self.cfg
        kE, kD, k1, k2, k3 = jax.random.split(key, 5)
        enc = [self._enc_block_init(k, dtype)
               for k in jax.random.split(kE, cfg.enc_layers)]
        dec = [self._dec_block_init(k, dtype)
               for k in jax.random.split(kD, cfg.dec_layers)]
        return {
            "embed": embed_init(k1, cfg.vocab, cfg.d_model, dtype),
            "pos_dec": jax.random.normal(
                k2, (cfg.dec_len, cfg.d_model), dtype) * 0.01,
            "ln_enc": layernorm_init(cfg.d_model, dtype),
            "ln_dec": layernorm_init(cfg.d_model, dtype),
            "enc": jax.tree.map(lambda *xs: jnp.stack(xs), *enc),
            "dec": jax.tree.map(lambda *xs: jnp.stack(xs), *dec),
        }

    def param_logical(self):
        stack = lambda t: jax.tree.map(
            lambda l: (None,) + l, t, is_leaf=lambda l: isinstance(l, tuple))
        return {
            "embed": embed_spec(),
            "pos_dec": ("seq", "embed"),
            "ln_enc": layernorm_spec(),
            "ln_dec": layernorm_spec(),
            "enc": stack(self._enc_block_spec()),
            "dec": stack(self._dec_block_spec()),
        }

    # -------------------------------------------------------- forward ----

    def encode(self, params, frames, *, remat: bool = True):
        """frames (B, S_enc, d_model) — stub frontend output."""
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        x = frames.astype(dt) + _sinusoid(frames.shape[1], cfg.d_model, dt)
        x = shard(x, "batch", "seq", "embed")

        def body(x, bp):
            h = layernorm(bp["ln1"], x)
            B, S, _ = h.shape
            q, k, v = _proj_qkv(bp["attn"], cfg, h, {"pos": jnp.arange(S)})
            a = _sdpa(q, k, v, None, cfg.head_dim ** -0.5,
                      causal=False)  # bidirectional
            x = x + dense(bp["attn"]["wo"], a)
            x = x + mlp_gelu(bp["mlp"], layernorm(bp["ln2"], x))
            return shard(x, "batch", "seq", "embed"), None

        f = jax.checkpoint(body, prevent_cse=False) if remat else body
        x, _ = jax.lax.scan(f, x, params["enc"])
        return layernorm(params["ln_enc"], x)

    def _dec_block(self, bp, x, enc_out, idx=None, cache=None):
        cfg = self.cfg
        B = x.shape[0]
        h = layernorm(bp["ln1"], x)
        if cache is None:
            S = h.shape[1]
            q, k, v = _proj_qkv(bp["attn"], cfg, h, {"pos": jnp.arange(S)})
            a = _sdpa(q, k, v, attn_mask(S, S), cfg.head_dim ** -0.5)
            a = dense(bp["attn"]["wo"], a)
            kc = vc = None
        else:
            a, kc, vc = gqa_decode(bp["attn"], cfg, h, cache["k"],
                                   cache["v"], idx)
        x = x + a
        # cross attention
        h = layernorm(bp["lnx"], x)
        H, Dh = cfg.n_heads, cfg.head_dim
        q = dense(bp["xattn"]["wq"], h).reshape(B, -1, H, Dh)
        if cache is None or "xk" not in cache:
            xk = dense(bp["xattn"]["wk"], enc_out).reshape(
                B, -1, H, Dh)
            xv = dense(bp["xattn"]["wv"], enc_out).reshape(
                B, -1, H, Dh)
        else:
            xk, xv = cache["xk"].astype(x.dtype), cache["xv"].astype(x.dtype)
        a = _sdpa(q, xk, xv, None, Dh ** -0.5, causal=False)
        x = x + dense(bp["xattn"]["wo"], a)
        x = x + mlp_gelu(bp["mlp"], layernorm(bp["ln2"], x))
        return x, (kc, vc)

    def forward(self, params, frames, dec_tokens, *, remat: bool = True):
        """Teacher-forced: returns decoder logits (B, S_dec, vocab)."""
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        enc_out = self.encode(params, frames, remat=remat)
        S = dec_tokens.shape[1]
        x = params["embed"]["e"].astype(dt)[dec_tokens] \
            + params["pos_dec"].astype(dt)[:S]

        def body(x, bp):
            x, _ = self._dec_block(bp, x, enc_out)
            return shard(x, "batch", "seq", "embed"), None

        f = jax.checkpoint(body, prevent_cse=False) if remat else body
        x, _ = jax.lax.scan(f, x, params["dec"])
        x = layernorm(params["ln_dec"], x)
        return x @ params["embed"]["e"].astype(dt).T

    # ---------------------------------------------------------- decode ----

    def init_cache(self, params, frames, max_len: int, dtype=jnp.float32):
        """Prefill: run encoder, precompute per-layer cross K/V."""
        cfg = self.cfg
        enc_out = self.encode(params, frames, remat=False)
        B = frames.shape[0]
        H, Dh = cfg.n_heads, cfg.head_dim

        def xkv(bp):
            xk = dense(bp["xattn"]["wk"], enc_out).reshape(B, -1, H, Dh)
            xv = dense(bp["xattn"]["wv"], enc_out).reshape(B, -1, H, Dh)
            return xk.astype(dtype), xv.astype(dtype)

        xks, xvs = jax.lax.map(xkv, params["dec"])
        return {
            "idx": jnp.zeros((), jnp.int32),
            "k": jnp.zeros((cfg.dec_layers, B, max_len, cfg.n_kv_heads,
                            Dh), dtype),
            "v": jnp.zeros((cfg.dec_layers, B, max_len, cfg.n_kv_heads,
                            Dh), dtype),
            "xk": xks,
            "xv": xvs,
        }

    def cache_logical(self):
        return {
            "idx": (),
            "k": (None, "batch", "seq", "kv_heads", None),
            "v": (None, "batch", "seq", "kv_heads", None),
            "xk": (None, "batch", "seq", "heads", None),
            "xv": (None, "batch", "seq", "heads", None),
        }

    def decode_step(self, params, cache, tokens):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        idx = cache["idx"]
        x = params["embed"]["e"].astype(dt)[tokens] \
            + params["pos_dec"].astype(dt)[idx][None, None]

        def body(x, xs):
            bp, k, v, xk, xv = xs
            x, (kc, vc) = self._dec_block(
                bp, x, None, idx=idx,
                cache={"k": k, "v": v, "xk": xk, "xv": xv})
            return x, (kc, vc)

        x, (kc, vc) = jax.lax.scan(
            body, x, (params["dec"], cache["k"], cache["v"], cache["xk"],
                      cache["xv"]))
        x = layernorm(params["ln_dec"], x)
        logits = x @ params["embed"]["e"].astype(dt).T
        return logits, {**cache, "idx": idx + 1, "k": kc, "v": vc}
