"""Mixture-of-Experts FFN with capacity-based scatter dispatch (EP-ready).

Top-k routing with a per-expert capacity.  Dispatch/combine use
scatter-add / gather with ``(tokens, slots)`` index arrays rather than the
GShard ``(tokens, experts, capacity)`` one-hot mask — the mask costs an
extra factor ``E`` of memory (terabytes at kimi-k2 scale) while the
scatter formulation stays at the true activation volume
``tokens * top_k * capacity_factor * d_model``.

Expert weights are stacked ``(E, d, d_ff)`` and logically sharded on the
"experts" axis (-> "model" mesh axis = expert parallelism); under GSPMD
the dispatch scatter lowers to the EP all-to-all.  Shared experts
(DeepSeek-style) run densely for every token.  Dropped tokens (capacity
overflow) contribute zero — standard capacity semantics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.sharding import shard

from .layers import dense_init, dense_spec, mlp_init, mlp_spec, mlp_swiglu

__all__ = ["moe_init", "moe_spec", "moe_ffn", "moe_ffn_dense_ref"]


def moe_init(key, cfg, dtype=jnp.float32):
    d, E, dff = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    kr, ke, ks = jax.random.split(key, 3)
    ek = jax.random.split(ke, 3)
    p = {
        "router": dense_init(kr, d, E, dtype, scale=0.02),
        "w_gate": jax.random.normal(ek[0], (E, d, dff), dtype) * d ** -0.5,
        "w_up": jax.random.normal(ek[1], (E, d, dff), dtype) * d ** -0.5,
        "w_down": jax.random.normal(ek[2], (E, dff, d), dtype) * dff ** -0.5,
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(ks, d, dff * cfg.n_shared_experts, True,
                               dtype)
    return p


def moe_spec(cfg):
    p = {
        "router": dense_spec("embed", None),
        "w_gate": ("experts", "embed", None),
        "w_up": ("experts", "embed", None),
        "w_down": ("experts", None, "embed"),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_spec(True)
    return p


def moe_ffn(p, cfg, x, *, n_chunks: int = 1):
    """x (B, S, d) -> (B, S, d); top-k routed + optional shared experts.

    **Device-local dispatch**: tokens are split into ``n_chunks`` groups
    (aligned with the data-parallel sharding) and every chunk owns its own
    capacity slice of every expert, so all scatter/gather indices are
    chunk-local.  With a single global capacity buffer the scatter
    positions cross data shards and GSPMD must replicate the dispatch
    (measured 203 GiB/chip on deepseek); with chunk-local capacity the
    buffer shards as ("batch", "experts", ...) and each data shard
    computes only its own slice of every expert — the standard
    hierarchical-EP formulation (local capacity per device).

    Default ``n_chunks=1`` = the global-dispatch baseline: the dry-run
    measured that XLA's scatter partitioner does not yet exploit the
    chunk alignment under GSPMD (collectives grew 14x) — see
    EXPERIMENTS.md SSPerf kimi iteration; a shard_map dispatch is the
    future fix, the chunked code path is kept (and tested) for it.
    """
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    N = B * S
    xt = x.reshape(N, d)
    xt = shard(xt, "batch", None)

    logits = (xt.astype(jnp.float32) @ p["router"]["w"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)        # (N, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # exact (drop-free) routing for small token counts — serving steps
    # must be deterministic and independent of co-batched tokens;
    # capacity-bounded routing (local-capacity semantics) for training
    C = n_chunks if (N * K > 4096 and N % n_chunks == 0) else 1
    Nl = N // C
    if N * K <= 4096:
        cap = Nl * K
    else:
        cap = max(K, int(cfg.capacity_factor * Nl * K / E))

    # chunk-local slot positions (sort-based, O(N log N) memory; an
    # (N*K, E) one-hot cumsum would be terabytes at kimi-k2 scale)
    ids = gate_idx.reshape(C, Nl * K)
    order = jnp.argsort(ids, axis=1)
    sorted_ids = jnp.take_along_axis(ids, order, axis=1)
    starts = jax.vmap(
        lambda srt: jnp.searchsorted(srt, jnp.arange(E)))(sorted_ids)
    pos_sorted = (jnp.arange(Nl * K)[None]
                  - jnp.take_along_axis(starts, sorted_ids, axis=1))
    pos_flat = jnp.zeros((C, Nl * K), jnp.int32).at[
        jnp.arange(C)[:, None], order].set(pos_sorted.astype(jnp.int32))
    pos = pos_flat.reshape(C, Nl, K)
    keep = pos < cap
    posc = jnp.where(keep, pos, cap - 1)
    keepf = keep.astype(xt.dtype)
    idx_c = gate_idx.reshape(C, Nl, K)

    # dispatch: chunk-local scatter into (C, E, cap, d) buffers
    xc = shard(xt.reshape(C, Nl, d), "batch", None, None)
    buf = jnp.zeros((C, E, cap, d), xt.dtype)
    upd = xc[:, :, None, :] * keepf[..., None]           # (C, Nl, K, d)
    buf = buf.at[jnp.arange(C)[:, None, None], idx_c, posc].add(upd)
    buf = shard(buf, "batch", "experts", None, None)

    # expert FFN (stacked SwiGLU) on the MXU
    h = jnp.einsum("cend,edf->cenf", buf, p["w_gate"].astype(xt.dtype))
    u = jnp.einsum("cend,edf->cenf", buf, p["w_up"].astype(xt.dtype))
    ye = jnp.einsum("cenf,efd->cend", jax.nn.silu(h) * u,
                    p["w_down"].astype(xt.dtype))
    ye = shard(ye, "batch", "experts", None, None)

    # combine: chunk-local gather back, mix with gate values
    yk = ye[jnp.arange(C)[:, None, None], idx_c, posc]   # (C, Nl, K, d)
    ys = jnp.sum(
        yk * (gate_vals.reshape(C, Nl, K).astype(xt.dtype)
              * keepf)[..., None], axis=2)

    out = ys.reshape(B, S, d)
    if cfg.n_shared_experts:
        out = out + mlp_swiglu(p["shared"], x)
    return out


def moe_ffn_dense_ref(p, cfg, x):
    """Oracle: evaluate every expert densely, mask by top-k (tests only)."""
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    xt = x.reshape(-1, d)
    logits = xt.astype(jnp.float32) @ p["router"]["w"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)
    h = jnp.einsum("nd,edf->enf", xt, p["w_gate"].astype(xt.dtype))
    u = jnp.einsum("nd,edf->enf", xt, p["w_up"].astype(xt.dtype))
    ye = jnp.einsum("enf,efd->end", jax.nn.silu(h) * u,
                    p["w_down"].astype(xt.dtype))
    w = jnp.zeros((xt.shape[0], E), xt.dtype)
    w = jnp.sum(jax.nn.one_hot(gate_idx, E, dtype=xt.dtype)
                * gate_vals[..., None].astype(xt.dtype), axis=1)
    ys = jnp.einsum("en,end->nd", w.T, ye)
    out = ys.reshape(B, S, d)
    if cfg.n_shared_experts:
        out = out + mlp_swiglu(p["shared"], x)
    return out
