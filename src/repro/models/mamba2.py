"""Mamba-2 (SSD, state-space duality) — attention-free LM.

Chunked SSD algorithm (Dao & Gu 2024): the sequence is split into chunks;
within a chunk the dual quadratic form computes token-token interactions
masked by the discretized decay; across chunks a recurrent state
``h (B, H, P, N)`` carries.  Training/prefill use the chunked form (scan
over chunks); decode uses the pure recurrence.

The paper's technique (rotation sequences) does not apply inside an
attention-free SSM block (no positional rotations); it still reaches this
arch through the SOAP-Givens optimizer (see DESIGN.md SSArch-applicability).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.parallel.sharding import shard

from .layers import (dense, dense_init, dense_spec, embed_init, embed_spec,
                     rmsnorm, rmsnorm_init, rmsnorm_spec)

__all__ = ["Mamba2"]


def _ssd_chunked(xbar, dtA, Bm, Cm, chunk: int):
    """Chunked SSD.

    xbar (B, L, H, P): dt-scaled inputs; dtA (B, L, H): log-decay per step;
    Bm/Cm (B, L, G, N) with H = G * (H // G).
    Returns y (B, L, H, P).
    """
    B, L, H, P = xbar.shape
    G, N = Bm.shape[2], Bm.shape[3]
    Q = chunk
    # pad to a whole number of chunks: zero rows are exact no-ops in SSD
    # (dt=0 -> decay 1, B=0 -> no state update, masked outputs dropped)
    L_out = L
    Lp = -(-L // Q) * Q
    if Lp != L:
        pad = [(0, 0), (0, Lp - L)]
        xbar = jnp.pad(xbar, pad + [(0, 0), (0, 0)])
        dtA = jnp.pad(dtA, pad + [(0, 0)])
        Bm = jnp.pad(Bm, pad + [(0, 0), (0, 0)])
        Cm = jnp.pad(Cm, pad + [(0, 0), (0, 0)])
        L = Lp
    nC = L // Q
    hg = H // G

    def resh(t, extra):
        return t.reshape((B, nC, Q) + extra)

    xb = resh(xbar, (H, P))
    dA = resh(dtA, (H,))
    Bc = resh(Bm, (G, N))
    Cc = resh(Cm, (G, N))

    cum = jnp.cumsum(dA, axis=2)                      # (B,nC,Q,H)
    seg = cum[:, :, :, None] - cum[:, :, None, :]     # (B,nC,Qi,Qj,H)
    ii = jnp.arange(Q)
    causal = (ii[:, None] >= ii[None, :])
    Lmask = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)

    # intra-chunk (dual quadratic form)
    scores = jnp.einsum("bcqgn,bckgn->bcqkg", Cc, Bc)  # (B,nC,Qi,Qj,G)
    scores = jnp.repeat(scores, hg, axis=-1)           # expand to H
    M = scores * Lmask
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", M, xb)

    # chunk states: S_c = sum_j exp(cum_end - cum_j) B_j x_j^T
    decay_end = jnp.exp(cum[:, :, -1:, :] - cum)       # (B,nC,Q,H)
    Bh = jnp.repeat(Bc, hg, axis=3)                    # (B,nC,Q,H,N)
    S = jnp.einsum("bcqh,bcqhn,bcqhp->bchnp",
                   decay_end, Bh, xb)                  # (B,nC,H,N,P)

    # inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(cum[:, :, -1, :])            # (B,nC,H)

    def step(h, xs):
        dec, s = xs
        h_new = dec[:, :, None, None] * h + s
        return h_new, h                                 # emit h BEFORE chunk

    h0 = jnp.zeros((B, H, N, P), xbar.dtype)
    _, hprev = jax.lax.scan(
        step, h0, (chunk_decay.transpose(1, 0, 2), S.transpose(1, 0, 2, 3, 4)))
    hprev = hprev.transpose(1, 0, 2, 3, 4)             # (B,nC,H,N,P)

    # inter-chunk output: y_j += C_j exp(cum_j) h_prev
    decay_in = jnp.exp(cum)                            # (B,nC,Q,H)
    Ch = jnp.repeat(Cc, hg, axis=3)                    # (B,nC,Q,H,N)
    y_inter = jnp.einsum("bcqh,bcqhn,bchnp->bcqhp", decay_in, Ch, hprev)

    return (y_intra + y_inter).reshape(B, L, H, P)[:, :L_out]


class Mamba2:
    def __init__(self, cfg):
        self.cfg = cfg
        self.d_inner = cfg.ssm_expand * cfg.d_model
        self.H = self.d_inner // cfg.ssm_head_dim
        self.G = cfg.ssm_groups
        self.N = cfg.ssm_state
        self.conv_dim = self.d_inner + 2 * self.G * self.N

    # ----------------------------------------------------------- init ----

    def _block_init(self, key, dtype):
        cfg = self.cfg
        d, di, H = cfg.d_model, self.d_inner, self.H
        ks = jax.random.split(key, 4)
        proj_out = 2 * di + 2 * self.G * self.N + H
        return {
            "norm": rmsnorm_init(d, dtype),
            "in_proj": dense_init(ks[0], d, proj_out, dtype),
            "conv_w": jax.random.normal(ks[1], (cfg.conv_width,
                                                self.conv_dim), dtype) * 0.2,
            "conv_b": jnp.zeros((self.conv_dim,), dtype),
            "A_log": jnp.zeros((H,), dtype),
            "D": jnp.ones((H,), dtype),
            "dt_bias": jnp.zeros((H,), dtype),
            "out_norm": rmsnorm_init(di, dtype),
            "out_proj": dense_init(ks[2], di, d, dtype),
        }

    def _block_spec(self):
        return {
            "norm": rmsnorm_spec(),
            "in_proj": dense_spec("embed", "ff"),
            "conv_w": (None, "ff"),
            "conv_b": ("ff",),
            "A_log": (None,),
            "D": (None,),
            "dt_bias": (None,),
            "out_norm": rmsnorm_spec(),
            "out_proj": dense_spec("ff", "embed"),
        }

    def init(self, key, dtype=jnp.float32):
        cfg = self.cfg
        keys = jax.random.split(key, cfg.n_layers + 2)
        blocks = [self._block_init(keys[i], dtype)
                  for i in range(cfg.n_layers)]
        return {
            "embed": embed_init(keys[-1], cfg.vocab, cfg.d_model, dtype),
            "ln_f": rmsnorm_init(cfg.d_model, dtype),
            "blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *blocks),
        }

    def param_logical(self):
        spec = self._block_spec()
        return {
            "embed": embed_spec(),
            "ln_f": rmsnorm_spec(),
            "blocks": jax.tree.map(lambda t: (None,) + t, spec,
                                   is_leaf=lambda t: isinstance(t, tuple)),
        }

    # ------------------------------------------------------- block fwd ----

    def _split_proj(self, zxbcdt):
        di, G, N, H = self.d_inner, self.G, self.N, self.H
        z = zxbcdt[..., :di]
        xBC = zxbcdt[..., di:di + self.conv_dim]
        dt = zxbcdt[..., di + self.conv_dim:]
        return z, xBC, dt

    def _block_fwd(self, p, x):
        cfg = self.cfg
        Bsz, L, d = x.shape
        di, G, N, H, P = (self.d_inner, self.G, self.N, self.H,
                          cfg.ssm_head_dim)
        h = shard(rmsnorm(p["norm"], x), "batch", None, "embed")
        z, xBC, dt = self._split_proj(dense(p["in_proj"], h))
        # temporal mixing needs the whole sequence: batch/ff sharding only
        z = shard(z, "batch", None, "ff")
        xBC = shard(xBC, "batch", None, "ff")

        # causal depthwise conv over xBC
        w = p["conv_w"].astype(x.dtype)
        pad = jnp.pad(xBC, ((0, 0), (cfg.conv_width - 1, 0), (0, 0)))
        conv = sum(w[i] * pad[:, i:i + L] for i in range(cfg.conv_width))
        xBC = jax.nn.silu(conv + p["conv_b"].astype(x.dtype))

        xs = xBC[..., :di].reshape(Bsz, L, H, P)
        Bm = xBC[..., di:di + G * N].reshape(Bsz, L, G, N)
        Cm = xBC[..., di + G * N:].reshape(Bsz, L, G, N)

        A = -jnp.exp(p["A_log"].astype(jnp.float32))
        dt = jax.nn.softplus(dt.astype(jnp.float32)
                             + p["dt_bias"].astype(jnp.float32))
        dtA = (dt * A[None, None]).astype(x.dtype)      # (B,L,H)
        xbar = xs * dt[..., None].astype(x.dtype)

        y = _ssd_chunked(xbar, dtA, Bm, Cm, min(cfg.ssm_chunk, L))
        y = y + p["D"].astype(x.dtype)[None, None, :, None] * xs
        y = y.reshape(Bsz, L, di)
        y = rmsnorm(p["out_norm"], y * jax.nn.silu(z))
        return x + dense(p["out_proj"], y)

    def forward(self, params, tokens, *, remat: bool = True):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        x = params["embed"]["e"].astype(dt)[tokens]
        x = shard(x, "batch", "seq", "embed")

        def body(x, bp):
            return self._block_fwd(bp, x), None

        f = jax.checkpoint(body, prevent_cse=False) if remat else body
        x, _ = jax.lax.scan(f, x, params["blocks"])
        x = rmsnorm(params["ln_f"], x)
        x = shard(x, "batch", None, "embed")
        return x @ params["embed"]["e"].astype(dt).T

    # ---------------------------------------------------------- decode ----

    def init_cache(self, batch: int, max_len: int, dtype=jnp.float32):
        cfg = self.cfg
        return {
            "idx": jnp.zeros((), jnp.int32),
            "conv": jnp.zeros((cfg.n_layers, batch, cfg.conv_width - 1,
                               self.conv_dim), dtype),
            "ssm": jnp.zeros((cfg.n_layers, batch, self.H, self.N,
                              cfg.ssm_head_dim), dtype),
        }

    def cache_logical(self):
        return {
            "idx": (),
            "conv": (None, "batch", None, "ff"),
            "ssm": (None, "batch", None, None, None),
        }

    def decode_step(self, params, cache, tokens):
        cfg = self.cfg
        dtp = jnp.dtype(cfg.dtype)
        x = params["embed"]["e"].astype(dtp)[tokens]  # (B, 1, d)
        di, G, N, H, P = (self.d_inner, self.G, self.N, self.H,
                          cfg.ssm_head_dim)

        def body(x, xs):
            bp, conv_st, ssm_st = xs
            h = rmsnorm(bp["norm"], x)
            z, xBC, dt = self._split_proj(dense(bp["in_proj"], h))
            # conv via state
            hist = jnp.concatenate([conv_st, xBC], axis=1)  # (B, W, dim)
            w = bp["conv_w"].astype(x.dtype)
            conv = jnp.einsum("wd,bwd->bd", w, hist)[:, None]
            xBC_o = jax.nn.silu(conv + bp["conv_b"].astype(x.dtype))
            Bsz = x.shape[0]
            xs_ = xBC_o[..., :di].reshape(Bsz, H, P)
            Bm = xBC_o[..., di:di + G * N].reshape(Bsz, G, N)
            Cm = xBC_o[..., di + G * N:].reshape(Bsz, G, N)
            A = -jnp.exp(bp["A_log"].astype(jnp.float32))
            dts = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                                  + bp["dt_bias"].astype(jnp.float32))
            dA = jnp.exp(dts * A[None]).astype(x.dtype)      # (B,H)
            xbar = xs_ * dts[..., None].astype(x.dtype)
            Bh = jnp.repeat(Bm, H // G, axis=1)              # (B,H,N)
            Ch = jnp.repeat(Cm, H // G, axis=1)
            ssm_new = (dA[:, :, None, None] * ssm_st
                       + Bh[..., None] * xbar[:, :, None, :])
            y = jnp.einsum("bhn,bhnp->bhp", Ch, ssm_new)
            y = y + bp["D"].astype(x.dtype)[None, :, None] * xs_
            y = y.reshape(Bsz, 1, di)
            y = rmsnorm(bp["out_norm"], y * jax.nn.silu(z))
            x = x + dense(bp["out_proj"], y)
            return x, (hist[:, 1:], ssm_new)

        x, (conv_new, ssm_new) = jax.lax.scan(
            body, x, (params["blocks"], cache["conv"], cache["ssm"]))
        x = rmsnorm(params["ln_f"], x)
        logits = x @ params["embed"]["e"].astype(dtp).T
        return logits, {"idx": cache["idx"] + 1, "conv": conv_new,
                        "ssm": ssm_new}
