"""GQA / MLA attention with RoPE, sliding windows and KV caches.

Attention math is einsum-based (XLA fuses these into MXU-optimal HLO on
TPU); RoPE routes through the planar-rotation machinery of the paper
(``repro.kernels.rope``).  Both full-sequence (train/prefill) and
single-token cached (decode) paths are provided.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels.rope.ref import apply_rope_ref, rope_tables
from repro.parallel.sharding import shard

from .layers import dense, dense_init, dense_spec, rmsnorm, rmsnorm_init, \
    rmsnorm_spec, softcap

__all__ = ["gqa_init", "gqa_spec", "gqa_attention", "gqa_decode",
           "init_kv_cache", "mla_init", "mla_spec", "mla_attention",
           "mla_decode", "init_mla_cache", "attn_mask"]


# ---------------------------------------------------------------- GQA ----

def gqa_init(key, cfg, dtype=jnp.float32):
    d, H, Hk, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, H * Dh, dtype),
        "wk": dense_init(ks[1], d, Hk * Dh, dtype),
        "wv": dense_init(ks[2], d, Hk * Dh, dtype),
        "wo": dense_init(ks[3], H * Dh, d, dtype),
    }
    if cfg.qk_norm:
        p["qn"] = rmsnorm_init(Dh, dtype)
        p["kn"] = rmsnorm_init(Dh, dtype)
    return p


def gqa_spec(cfg):
    p = {
        "wq": dense_spec("embed", "heads"),
        "wk": dense_spec("embed", "kv_heads"),
        "wv": dense_spec("embed", "kv_heads"),
        "wo": dense_spec("heads", "embed"),
    }
    if cfg.qk_norm:
        p["qn"] = rmsnorm_spec()
        p["kn"] = rmsnorm_spec()
    return p


def attn_mask(q_len: int, kv_len: int, window: Optional[int] = None,
              causal: bool = True, q_offset: int = 0):
    """(q_len, kv_len) boolean mask; ``q_offset`` = absolute pos of query 0."""
    qpos = jnp.arange(q_len)[:, None] + q_offset
    kpos = jnp.arange(kv_len)[None, :]
    m = jnp.ones((q_len, kv_len), bool)
    if causal:
        m &= kpos <= qpos
    if window is not None:
        m &= kpos > qpos - window
    return m


def _proj_qkv(p, cfg, x, positions):
    B, S, d = x.shape
    H, Hk, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    x = shard(x, "batch", None, "embed")  # SP: gather seq at matmul entry
    q = dense(p["wq"], x).reshape(B, S, H, Dh)
    k = dense(p["wk"], x).reshape(B, S, Hk, Dh)
    v = dense(p["wv"], x).reshape(B, S, Hk, Dh)
    if cfg.qk_norm:
        q = rmsnorm(p["qn"], q)
        k = rmsnorm(p["kn"], k)
    if cfg.pos_type == "rope":
        base = positions.get("rope_base", cfg.rope_base)
        cos, sin = rope_tables(positions["pos"], Dh, base, dtype=q.dtype)
        q = apply_rope_ref(q, cos, sin)
        k = apply_rope_ref(k, cos, sin)
    # Megatron-SP convention: sequence is sharded BETWEEN blocks only;
    # inside attention the activations shard over batch x heads (seq must
    # be whole for the flash chunk scan — Shardy otherwise falls back to
    # full rematerialization/replication of the attention internals)
    q = shard(q, "batch", None, "heads", None)
    k = shard(k, "batch", None, "kv_heads", None)
    v = shard(v, "batch", None, "kv_heads", None)
    return q, k, v


_FLASH_CHUNK = 512


def _sdpa_dense(qg, k, v, mask, scale, cap):
    logits = jnp.einsum("bshgd,bthd->bhgst", qg, k) * scale
    logits = softcap(logits, cap)
    if mask is not None:
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    w = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(qg.dtype)
    return jnp.einsum("bhgst,bthd->bshgd", w, v)


def _sdpa_flash(qg, k, v, scale, cap, *, causal, window, q_offset):
    """Chunked online-softmax attention (flash-style, pure jnp).

    Never materializes the (S, T) score matrix OR the (S, T) mask: scans
    key/value chunks with running (max, denominator, accumulator) and
    rebuilds each chunk's causal/window mask from position arithmetic.
    This is the XLA-level form of the TPU flash kernel — it lowers on
    every backend (the dry-run compiles on the CPU backend where a Pallas
    TPU kernel cannot), and keeps attention temp memory O(S * chunk).
    """
    B, S, Hk, G, Dh = qg.shape
    T = k.shape[1]
    C = _FLASH_CHUNK
    nC = T // C
    kc = k.reshape(B, nC, C, Hk, Dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nC, C, Hk, Dh).transpose(1, 0, 2, 3, 4)
    qpos = jnp.arange(S) + q_offset

    def step(carry, xs):
        m_run, d_run, acc = carry
        kb, vb, cidx = xs
        s = jnp.einsum("bshgd,bthd->bhgst", qg, kb) * scale  # (B,Hk,G,S,C)
        s = softcap(s, cap).astype(jnp.float32)
        kpos = cidx * C + jnp.arange(C)
        mb = jnp.ones((S, C), bool)
        if causal:
            mb &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mb &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mb[None, None, None], s, -1e30)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_run - m_new)
        p = jnp.exp(s - m_new[..., None])
        d_new = d_run * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha.astype(acc.dtype)[..., None] + jnp.einsum(
            "bhgst,bthd->bhgsd", p.astype(qg.dtype), vb).astype(acc.dtype)
        return (m_new, d_new, acc), None

    m0 = jnp.full((B, Hk, G, S), -jnp.inf, jnp.float32)
    d0 = jnp.zeros((B, Hk, G, S), jnp.float32)
    acc0 = jnp.zeros((B, Hk, G, S, Dh), qg.dtype)
    # checkpoint the chunk step: the backward pass recomputes the chunk
    # probabilities instead of storing (B,H,S,C) residuals per chunk
    (m, d, acc), _ = jax.lax.scan(
        jax.checkpoint(step, prevent_cse=False), (m0, d0, acc0),
        (kc, vc, jnp.arange(nC)))
    o = acc / jnp.maximum(d, 1e-30)[..., None].astype(qg.dtype)
    return o.transpose(0, 3, 1, 2, 4)  # (B,S,Hk,G,Dh)


def _sdpa(q, k, v, mask, scale, cap=0.0, *, causal=True, window=None,
          q_offset=0):
    """q (B,S,H,D), k/v (B,T,Hk,D) with H = G*Hk.

    When the query length is large, routes to the chunked flash path and
    derives masks from ``causal``/``window``/``q_offset`` (``mask`` is
    ignored there and may be None); small-S (decode) uses the dense path
    with the explicit ``mask``.
    """
    B, S, H, Dh = q.shape
    T, Hk = k.shape[1], k.shape[2]
    G = H // Hk
    qg = q.reshape(B, S, Hk, G, Dh)
    if S >= 64 and T >= 2 * _FLASH_CHUNK and T % _FLASH_CHUNK == 0:
        o = _sdpa_flash(qg, k, v, scale, cap, causal=causal,
                        window=window, q_offset=q_offset)
    else:
        o = _sdpa_dense(qg, k, v, mask, scale, cap)
    return o.reshape(B, S, H * Dh)


def gqa_attention(p, cfg, x, *, window=None, rope_base=None, q_offset=0):
    """Full-sequence causal attention (train / prefill)."""
    B, S, _ = x.shape
    pos = jnp.arange(S) + q_offset
    q, k, v = _proj_qkv(p, cfg, x, {
        "pos": pos, "rope_base": rope_base or cfg.rope_base})
    mask = (attn_mask(S, S, window=window) if S < _FLASH_CHUNK else None)
    o = _sdpa(q, k, v, mask, cfg.head_dim ** -0.5, causal=True,
              window=window)
    o = shard(o, "batch", None, "heads")
    return dense(p["wo"], o), (k, v)


def init_kv_cache(cfg, batch: int, max_len: int, n_layers: int,
                  dtype=jnp.bfloat16):
    Hk, Dh = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((n_layers, batch, max_len, Hk, Dh), dtype),
        "v": jnp.zeros((n_layers, batch, max_len, Hk, Dh), dtype),
        "idx": jnp.zeros((), jnp.int32),
    }


def gqa_decode(p, cfg, x, k_cache, v_cache, idx, *, window=None,
               rope_base=None):
    """Single-token decode: x (B, 1, d); cache (B, T, Hk, Dh).

    When the cache is *window-sized* (``T <= window``, allocated by
    ``init_cache`` for sliding-window layers) it is treated as a ring
    buffer: slot ``idx % T`` is overwritten and, because softmax is
    permutation-invariant and RoPE phases are baked into cached keys at
    write time, no reordering is needed — a 1024-slot cache serves a
    524288-token stream (hillclimb fix for ``gemma3 long_500k``).
    """
    B, _, d = x.shape
    T = k_cache.shape[1]
    q, k, v = _proj_qkv(p, cfg, x, {
        "pos": jnp.full((1,), idx), "rope_base": rope_base or cfg.rope_base})
    ring = window is not None and T <= window
    slot = idx % T if ring else idx
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k.astype(k_cache.dtype), slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v.astype(v_cache.dtype), slot, axis=1)
    kpos = jnp.arange(T)
    mask = (kpos <= idx)  # once idx >= T every ring slot is valid
    if window is not None and not ring:
        mask &= kpos > idx - window
    o = _sdpa(q, k_cache.astype(q.dtype), v_cache.astype(q.dtype),
              mask[None, :], cfg.head_dim ** -0.5)
    return dense(p["wo"], o), k_cache, v_cache


# ---------------------------------------------------------------- MLA ----

def mla_init(key, cfg, dtype=jnp.float32):
    """DeepSeek-style multi-head latent attention."""
    d, H = cfg.d_model, cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 8)
    p = {}
    if cfg.q_lora:
        p["wq_a"] = dense_init(ks[0], d, cfg.q_lora, dtype)
        p["q_norm"] = rmsnorm_init(cfg.q_lora, dtype)
        p["wq_b"] = dense_init(ks[1], cfg.q_lora, H * (dn + dr), dtype)
    else:
        p["wq"] = dense_init(ks[0], d, H * (dn + dr), dtype)
    p["wkv_a"] = dense_init(ks[2], d, cfg.kv_lora + dr, dtype)
    p["kv_norm"] = rmsnorm_init(cfg.kv_lora, dtype)
    p["wkv_b"] = dense_init(ks[3], cfg.kv_lora, H * (dn + dv), dtype)
    p["wo"] = dense_init(ks[4], H * dv, d, dtype)
    return p


def mla_spec(cfg):
    p = {}
    if cfg.q_lora:
        p["wq_a"] = dense_spec("embed", None)
        p["q_norm"] = rmsnorm_spec()
        p["wq_b"] = dense_spec(None, "heads")
    else:
        p["wq"] = dense_spec("embed", "heads")
    p["wkv_a"] = dense_spec("embed", None)
    p["kv_norm"] = rmsnorm_spec()
    p["wkv_b"] = dense_spec(None, "heads")
    p["wo"] = dense_spec("heads", "embed")
    return p


def _mla_qkv(p, cfg, x, pos):
    B, S, d = x.shape
    x = shard(x, "batch", None, "embed")  # SP: gather seq at matmul entry
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    if cfg.q_lora:
        q = dense(p["wq_b"], rmsnorm(p["q_norm"], dense(p["wq_a"], x)))
    else:
        q = dense(p["wq"], x)
    q = q.reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    kv = dense(p["wkv_a"], x)
    c_kv, k_rope = kv[..., :cfg.kv_lora], kv[..., cfg.kv_lora:]
    c_kv = rmsnorm(p["kv_norm"], c_kv)
    cos, sin = rope_tables(pos, dr, cfg.rope_base, dtype=q.dtype)
    q_rope = apply_rope_ref(q_rope, cos, sin)
    k_rope = apply_rope_ref(k_rope[:, :, None, :], cos, sin)  # shared head
    q_nope = shard(q_nope, "batch", None, "heads", None)
    q_rope = shard(q_rope, "batch", None, "heads", None)
    c_kv = shard(c_kv, "batch", None, None)
    return q_nope, q_rope, c_kv, k_rope


def _mla_attend(p, cfg, q_nope, q_rope, c_kv, k_rope, mask, *,
                q_offset=0):
    """Latent attention: scores from compressed cache (c_kv, k_rope).

    Large query lengths route through a chunked online-softmax over the
    latent cache (the MLA flash form: the accumulator lives in the
    ``kv_lora`` latent space, up-projection happens once at the end).
    """
    B, S, H, dn = q_nope.shape
    T = c_kv.shape[1]
    dv = cfg.v_head_dim
    L = cfg.kv_lora
    wkv_b = p["wkv_b"]["w"].reshape(L, H, dn + dv)
    wk_b, wv_b = wkv_b[..., :dn], wkv_b[..., dn:]
    # fold k up-projection into q (absorbed form): q~ = q_nope @ wk_b^T
    q_lat = jnp.einsum("bshd,lhd->bshl", q_nope, wk_b.astype(q_nope.dtype))
    scale = (dn + cfg.qk_rope_dim) ** -0.5

    if S >= 64 and T >= 2 * _FLASH_CHUNK and T % _FLASH_CHUNK == 0:
        C = _FLASH_CHUNK
        nC = T // C
        ckv_c = c_kv.reshape(B, nC, C, L).transpose(1, 0, 2, 3)
        kr_c = k_rope.reshape(B, nC, C, -1).transpose(1, 0, 2, 3)
        qpos = jnp.arange(S) + q_offset

        def step(carry, xs):
            m_run, d_run, acc = carry
            ckb, krb, cidx = xs
            s = (jnp.einsum("bshl,btl->bhst", q_lat, ckb)
                 + jnp.einsum("bshd,btd->bhst", q_rope, krb)) * scale
            s = s.astype(jnp.float32)
            kpos = cidx * C + jnp.arange(C)
            mb = kpos[None, :] <= qpos[:, None]
            s = jnp.where(mb[None, None], s, -1e30)
            m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
            alpha = jnp.exp(m_run - m_new)
            pch = jnp.exp(s - m_new[..., None])
            d_new = d_run * alpha + jnp.sum(pch, axis=-1)
            acc = acc * alpha.astype(acc.dtype)[..., None] + jnp.einsum(
                "bhst,btl->bhsl", pch.astype(q_lat.dtype),
                ckb).astype(acc.dtype)
            return (m_new, d_new, acc), None

        m0 = jnp.full((B, H, S), -jnp.inf, jnp.float32)
        d0 = jnp.zeros((B, H, S), jnp.float32)
        acc0 = jnp.zeros((B, H, S, L), q_lat.dtype)
        (m, d, acc), _ = jax.lax.scan(
            jax.checkpoint(step, prevent_cse=False), (m0, d0, acc0),
            (ckv_c, kr_c, jnp.arange(nC)))
        o_lat = (acc / jnp.maximum(d, 1e-30)[..., None].astype(acc.dtype)
                 ).transpose(0, 2, 1, 3)  # (B,S,H,L)
    else:
        logits = (jnp.einsum("bshl,btl->bhst", q_lat, c_kv)
                  + jnp.einsum("bshd,btd->bhst", q_rope, k_rope)) * scale
        if mask is not None:
            logits = jnp.where(mask[None, None], logits, -1e30)
        w = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(
            q_nope.dtype)
        o_lat = jnp.einsum("bhst,btl->bshl", w, c_kv)
    o = jnp.einsum("bshl,lhd->bshd", o_lat, wv_b.astype(o_lat.dtype))
    return dense(p["wo"], o.reshape(B, S, H * dv))


def mla_attention(p, cfg, x, *, q_offset=0):
    B, S, _ = x.shape
    pos = jnp.arange(S) + q_offset
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, cfg, x, pos)
    mask = attn_mask(S, S) if S < _FLASH_CHUNK else None
    out = _mla_attend(p, cfg, q_nope, q_rope, c_kv, k_rope[:, :, 0], mask,
                      q_offset=q_offset)
    return out, (c_kv, k_rope[:, :, 0])


def init_mla_cache(cfg, batch: int, max_len: int, n_layers: int,
                   dtype=jnp.bfloat16):
    return {
        "ckv": jnp.zeros((n_layers, batch, max_len, cfg.kv_lora), dtype),
        "kr": jnp.zeros((n_layers, batch, max_len, cfg.qk_rope_dim), dtype),
        "idx": jnp.zeros((), jnp.int32),
    }


def mla_decode(p, cfg, x, ckv_cache, kr_cache, idx):
    B = x.shape[0]
    pos = jnp.full((1,), idx)
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, cfg, x, pos)
    ckv_cache = jax.lax.dynamic_update_slice_in_dim(
        ckv_cache, c_kv.astype(ckv_cache.dtype), idx, axis=1)
    kr_cache = jax.lax.dynamic_update_slice_in_dim(
        kr_cache, k_rope[:, :, 0].astype(kr_cache.dtype), idx, axis=1)
    T = ckv_cache.shape[1]
    mask = (jnp.arange(T) <= idx)[None, :]
    out = _mla_attend(p, cfg, q_nope, q_rope,
                      ckv_cache.astype(x.dtype),
                      kr_cache.astype(x.dtype), mask)
    return out, ckv_cache, kr_cache
