"""RecurrentGemma / Griffin hybrid: RG-LRU recurrent blocks + local MQA.

Pattern (R, R, A): two recurrent residual blocks per local-attention
block; every temporal block is followed by a GeGLU MLP block.  The RG-LRU
linear recurrence ``h_t = a_t h_{t-1} + sqrt(1 - a_t^2) (i_t * x_t)`` is
evaluated with ``jax.lax.associative_scan`` for train/prefill and a single
fused step for decode.  Attention layers use sliding-window MQA with RoPE
(the paper's rotations), so the KV cache is bounded by the window even for
the 500k-token cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.sharding import shard

from .attention import gqa_attention, gqa_decode, gqa_init, gqa_spec
from .layers import (dense, dense_init, dense_spec, embed_init, embed_spec,
                     mlp_gelu, mlp_init, mlp_spec, rmsnorm, rmsnorm_init,
                     rmsnorm_spec)

__all__ = ["RecurrentHybrid"]

_PATTERN = ("rec", "rec", "attn")


class RecurrentHybrid:
    def __init__(self, cfg):
        self.cfg = cfg
        self.lru = cfg.lru_width or cfg.d_model
        n = cfg.n_layers
        self.reps = n // 3
        self.tail = tuple(_PATTERN[: n % 3])

    # ----------------------------------------------------------- init ----

    def _temporal_init(self, key, kind, dtype):
        cfg = self.cfg
        d, w = cfg.d_model, self.lru
        if kind == "attn":
            return {"attn": gqa_init(key, cfg, dtype)}
        ks = jax.random.split(key, 6)
        return {
            "in_x": dense_init(ks[0], d, w, dtype),
            "in_y": dense_init(ks[1], d, w, dtype),
            "conv_w": jax.random.normal(ks[2], (cfg.conv_width, w),
                                        dtype) * 0.2,
            "conv_b": jnp.zeros((w,), dtype),
            "gate_a": dense_init(ks[3], w, w, dtype),
            "gate_i": dense_init(ks[4], w, w, dtype),
            "lam": jnp.full((w,), 2.0, dtype),  # sigmoid(2) ~ .88 decay
            "out": dense_init(ks[5], w, d, dtype),
        }

    def _temporal_spec(self, kind):
        if kind == "attn":
            return {"attn": gqa_spec(self.cfg)}
        return {
            "in_x": dense_spec("embed", "ff"),
            "in_y": dense_spec("embed", "ff"),
            "conv_w": (None, "ff"),
            "conv_b": ("ff",),
            "gate_a": dense_spec("ff", None),
            "gate_i": dense_spec("ff", None),
            "lam": ("ff",),
            "out": dense_spec("ff", "embed"),
        }

    def _block_init(self, key, kind, dtype):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        return {
            "ln1": rmsnorm_init(cfg.d_model, dtype),
            "temporal": self._temporal_init(k1, kind, dtype),
            "ln2": rmsnorm_init(cfg.d_model, dtype),
            "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, True, dtype),
        }

    def _block_spec(self, kind):
        return {
            "ln1": rmsnorm_spec(),
            "temporal": self._temporal_spec(kind),
            "ln2": rmsnorm_spec(),
            "mlp": mlp_spec(True),
        }

    def init(self, key, dtype=jnp.float32):
        cfg = self.cfg
        keys = jax.random.split(key, 2 + 3 * self.reps + len(self.tail))
        params = {
            "embed": embed_init(keys[0], cfg.vocab, cfg.d_model, dtype),
            "ln_f": rmsnorm_init(cfg.d_model, dtype),
        }
        reptrees = []
        for r in range(self.reps):
            reptrees.append([
                self._block_init(keys[2 + 3 * r + s], _PATTERN[s], dtype)
                for s in range(3)
            ])
        if self.reps:
            params["group0"] = jax.tree.map(lambda *xs: jnp.stack(xs),
                                            *reptrees)
        for ti, kind in enumerate(self.tail):
            params[f"tail{ti}"] = self._block_init(
                keys[2 + 3 * self.reps + ti], kind, dtype)
        return params

    def param_logical(self):
        spec = {"embed": embed_spec(), "ln_f": rmsnorm_spec()}
        if self.reps:
            slots = [self._block_spec(_PATTERN[s]) for s in range(3)]
            spec["group0"] = jax.tree.map(
                lambda t: (None,) + t, slots,
                is_leaf=lambda t: isinstance(t, tuple))
        for ti, kind in enumerate(self.tail):
            spec[f"tail{ti}"] = self._block_spec(kind)
        return spec

    # ------------------------------------------------------- recurrence ----

    def _rglru(self, p, xw, h0=None):
        """RG-LRU over xw (B, L, w); returns (y, h_last)."""
        r = jax.nn.sigmoid(dense(p["gate_a"], xw))
        i = jax.nn.sigmoid(dense(p["gate_i"], xw))
        log_a = (8.0 * r
                 * jax.nn.log_sigmoid(p["lam"].astype(jnp.float32))
                 ).astype(jnp.float32)  # c = 8 (Griffin)
        a = jnp.exp(log_a).astype(xw.dtype)
        gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)
                         ).astype(xw.dtype) * (i * xw)
        if h0 is not None:
            gated = gated.at[:, 0].add(a[:, 0] * h0)

        def comb(l, r):
            al, bl = l
            ar, br = r
            return al * ar, br + ar * bl

        _, h = jax.lax.associative_scan(comb, (a, gated), axis=1)
        return h, h[:, -1]

    def _temporal_fwd(self, p, kind, x):
        cfg = self.cfg
        if kind == "attn":
            out, _ = gqa_attention(p["attn"], cfg, x, window=cfg.window)
            return out
        B, L, d = x.shape
        x = shard(x, "batch", None, "embed")
        xw = shard(dense(p["in_x"], x), "batch", None, "ff")
        yw = shard(jax.nn.gelu(dense(p["in_y"], x)), "batch", None, "ff")
        w = p["conv_w"].astype(x.dtype)
        pad = jnp.pad(xw, ((0, 0), (cfg.conv_width - 1, 0), (0, 0)))
        xw = sum(w[i] * pad[:, i:i + L] for i in range(cfg.conv_width))
        xw = xw + p["conv_b"].astype(x.dtype)
        h, _ = self._rglru(p, xw)
        return dense(p["out"], h * yw)

    def _block_fwd(self, p, kind, x):
        x = x + self._temporal_fwd(p["temporal"], kind,
                                   rmsnorm(p["ln1"], x))
        x = x + mlp_gelu(p["mlp"], rmsnorm(p["ln2"], x))
        return shard(x, "batch", "seq", "embed")

    def forward(self, params, tokens, *, remat: bool = True):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        x = params["embed"]["e"].astype(dt)[tokens]
        if cfg.emb_scale:
            x = x * jnp.sqrt(jnp.asarray(cfg.d_model, dt))
        x = shard(x, "batch", "seq", "embed")

        if self.reps:
            def body(x, rep_p):
                for s in range(3):
                    x = self._block_fwd(rep_p[s], _PATTERN[s], x)
                return x, None

            f = jax.checkpoint(body, prevent_cse=False) if remat else body
            x, _ = jax.lax.scan(f, x, params["group0"])
        for ti, kind in enumerate(self.tail):
            x = self._block_fwd(params[f"tail{ti}"], kind, x)
        x = rmsnorm(params["ln_f"], x)
        x = shard(x, "batch", None, "embed")
        return x @ params["embed"]["e"].astype(dt).T

    # ---------------------------------------------------------- decode ----

    def init_cache(self, batch: int, max_len: int, dtype=jnp.float32):
        cfg = self.cfg
        W = min(cfg.window or max_len, max_len)
        reps = self.reps

        def rec_state():
            return {
                "h": jnp.zeros((reps, batch, self.lru), dtype),
                "conv": jnp.zeros((reps, batch, cfg.conv_width - 1,
                                   self.lru), dtype),
            }

        cache = {
            "idx": jnp.zeros((), jnp.int32),
            "rec0": rec_state(),
            "rec1": rec_state(),
            "attn": {  # ring buffer, window-sized (see gqa_decode)
                "k": jnp.zeros((reps, batch, W, cfg.n_kv_heads,
                                cfg.head_dim), dtype),
                "v": jnp.zeros((reps, batch, W, cfg.n_kv_heads,
                                cfg.head_dim), dtype),
            },
        }
        for ti, kind in enumerate(self.tail):
            if kind == "rec":
                cache[f"tail{ti}"] = {
                    "h": jnp.zeros((batch, self.lru), dtype),
                    "conv": jnp.zeros((batch, cfg.conv_width - 1, self.lru),
                                      dtype),
                }
        return cache

    def cache_logical(self):
        rec = {"h": (None, "batch", "ff"),
               "conv": (None, "batch", None, "ff")}
        spec = {
            "idx": (),
            "rec0": dict(rec),
            "rec1": dict(rec),
            "attn": {"k": (None, "batch", "seq", "kv_heads", None),
                     "v": (None, "batch", "seq", "kv_heads", None)},
        }
        for ti, kind in enumerate(self.tail):
            if kind == "rec":
                spec[f"tail{ti}"] = {"h": ("batch", "ff"),
                                     "conv": ("batch", None, "ff")}
        return spec

    def _rec_step(self, p, x, state):
        """Single-token recurrent block; x (B, 1, d)."""
        xw = dense(p["in_x"], x)
        yw = jax.nn.gelu(dense(p["in_y"], x))
        hist = jnp.concatenate([state["conv"], xw], axis=1)
        w = p["conv_w"].astype(x.dtype)
        xw = jnp.einsum("wd,bwd->bd", w, hist)[:, None] \
            + p["conv_b"].astype(x.dtype)
        h, h_last = self._rglru(p, xw, h0=state["h"])
        out = dense(p["out"], h * yw)
        return out, {"h": h_last, "conv": hist[:, 1:]}

    def decode_step(self, params, cache, tokens):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        idx = cache["idx"]
        x = params["embed"]["e"].astype(dt)[tokens]
        if cfg.emb_scale:
            x = x * jnp.sqrt(jnp.asarray(cfg.d_model, dt))
        new_cache = {"idx": idx + 1}

        if self.reps:
            def body(x, xs):
                rep_p, r0, r1, ac = xs
                new = []
                # slot 0, 1: recurrent
                for s, st in ((0, r0), (1, r1)):
                    p = rep_p[s]
                    h = rmsnorm(p["ln1"], x)
                    out, st_new = self._rec_step(p["temporal"], h, st)
                    x = x + out
                    x = x + mlp_gelu(p["mlp"], rmsnorm(p["ln2"], x))
                    new.append(st_new)
                # slot 2: local attention
                p = rep_p[2]
                h = rmsnorm(p["ln1"], x)
                a, kc, vc = gqa_decode(p["temporal"]["attn"], cfg, h,
                                       ac["k"], ac["v"], idx,
                                       window=cfg.window)
                x = x + a
                x = x + mlp_gelu(p["mlp"], rmsnorm(p["ln2"], x))
                return x, (new[0], new[1], {"k": kc, "v": vc})

            x, (r0, r1, ac) = jax.lax.scan(
                body, x, (params["group0"], cache["rec0"], cache["rec1"],
                          cache["attn"]))
            new_cache.update({"rec0": r0, "rec1": r1, "attn": ac})
        for ti, kind in enumerate(self.tail):
            p = params[f"tail{ti}"]
            h = rmsnorm(p["ln1"], x)
            out, st = self._rec_step(p["temporal"], h, cache[f"tail{ti}"])
            x = x + out
            x = x + mlp_gelu(p["mlp"], rmsnorm(p["ln2"], x))
            new_cache[f"tail{ti}"] = st
        x = rmsnorm(params["ln_f"], x)
        return x @ params["embed"]["e"].astype(dt).T, new_cache
