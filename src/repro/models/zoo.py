"""Model zoo: build any assigned architecture from its config."""
from __future__ import annotations

from repro.configs.base import ModelConfig

from .mamba2 import Mamba2
from .rglru import RecurrentHybrid
from .transformer import Transformer
from .whisper import WhisperBackbone

__all__ = ["build_model"]


def build_model(cfg: ModelConfig):
    if cfg.family == "ssm":
        return Mamba2(cfg)
    if cfg.family == "hybrid":
        return RecurrentHybrid(cfg)
    if cfg.family == "audio":
        return WhisperBackbone(cfg)
    # dense / moe / vlm share the decoder-only transformer
    return Transformer(cfg)
