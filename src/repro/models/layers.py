"""Shared neural-net building blocks (functional, pure JAX).

Parameters are plain nested dicts of ``jax.Array``.  Each ``init_*``
helper has a ``spec_*`` twin producing the matching pytree of *logical
axis tuples* used by ``repro.parallel.sharding`` to derive
``PartitionSpec``s — model definitions stay sharding-agnostic.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.parallel.sharding import shard

__all__ = [
    "dense_init", "dense_spec", "dense",
    "rmsnorm_init", "rmsnorm_spec", "rmsnorm",
    "layernorm_init", "layernorm_spec", "layernorm",
    "embed_init", "embed_spec",
    "mlp_init", "mlp_spec", "mlp_swiglu", "mlp_gelu",
    "softcap",
]


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32,
               scale: Optional[float] = None):
    scale = scale if scale is not None else (1.0 / jnp.sqrt(d_in))
    return {"w": jax.random.normal(key, (d_in, d_out), dtype) * scale}


def dense_spec(l_in: Optional[str], l_out: Optional[str]):
    return {"w": (l_in, l_out)}


def dense(p, x):
    return x @ p["w"].astype(x.dtype)


def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"g": jnp.zeros((d,), dtype)}  # gemma-style (1 + g)


def rmsnorm_spec():
    return {"g": (None,)}


def rmsnorm(p, x, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + p["g"].astype(jnp.float32))).astype(x.dtype)


def layernorm_init(d: int, dtype=jnp.float32):
    return {"g": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def layernorm_spec():
    return {"g": (None,), "b": (None,)}


def layernorm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["g"].astype(jnp.float32)
            + p["b"].astype(jnp.float32)).astype(x.dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return {"e": jax.random.normal(key, (vocab, d), dtype) * 0.02}


def embed_spec():
    return {"e": ("vocab", "embed")}


def mlp_init(key, d: int, d_ff: int, gated: bool, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "up": dense_init(k1, d, d_ff, dtype),
        "down": dense_init(k2, d_ff, d, dtype),
    }
    if gated:
        p["gate"] = dense_init(k3, d, d_ff, dtype)
    return p


def mlp_spec(gated: bool):
    p = {
        "up": dense_spec("embed", "ff"),
        "down": dense_spec("ff", "embed"),
    }
    if gated:
        p["gate"] = dense_spec("embed", "ff")
    return p


def mlp_swiglu(p, x):
    # Megatron-SP: gather seq before the matmuls so the ff-sharded weights
    # are used in place (otherwise GSPMD all-gathers the full weight)
    x = shard(x, "batch", None, "embed")
    h = jax.nn.silu(dense(p["gate"], x)) * dense(p["up"], x)
    h = shard(h, "batch", None, "ff")
    return dense(p["down"], h)


def mlp_gelu(p, x):
    x = shard(x, "batch", None, "embed")
    h = jax.nn.gelu(dense(p["up"], x))
    h = shard(h, "batch", None, "ff")
    return dense(p["down"], h)


def softcap(x, cap: float):
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap
