"""Serving launcher: batched LM decoding, or batched rotation serving.

LM mode (default) drives the ServeEngine::

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
      --reduced --batch 4 --max-new 16

Rotation mode drives the shape-bucketed RotationService end-to-end: a
mixed-shape stream of recorded rotation sequences is admitted into
buckets, executed through one frozen plan per bucket, checked against
per-request application, and timed::

  PYTHONPATH=src python -m repro.launch.serve --rotations \
      --requests 64 --slots 8

Stream mode drives the async continuous-batching engine
(:class:`repro.serve.StreamEngine`) over the same synthetic stream:
requests are submitted as tickets, batches close on the size-or-age
policy, and results are checked bit-for-bit against the synchronous
service::

  PYTHONPATH=src python -m repro.launch.serve --rotations --stream \
      --requests 64 --slots 8

With ``--metrics-json PATH`` the run executes with ``repro.obs``
enabled and writes the full metrics + roofline snapshot (plan-cache
counters, admit→drain latency histogram p50/p99, per-backend
model-vs-measured fractions) to ``PATH``; ``--trace PATH`` additionally
exports a Perfetto-loadable Chrome trace of the plan / admit / drain /
apply spans.  ``make obs-report`` packages the canonical invocations
(synchronous and streaming, each with its own artifact pair).
"""
from __future__ import annotations

import argparse

import jax

from repro import obs
from repro.configs import get_config
from repro.models import build_model
from repro.serve import ServeEngine


def _run_lm(args) -> None:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    eng = ServeEngine(model, cfg, params, batch=args.batch,
                      max_len=args.max_len)
    prompts = [[(7 * i + j) % cfg.vocab for j in range(4 + i)]
               for i in range(args.batch)]
    t0 = obs.timing.now()
    outs = eng.generate(prompts, max_new=args.max_new)
    dt = obs.timing.now() - t0
    toks = sum(len(o) for o in outs)
    for p, o in zip(prompts, outs):
        print(f"prompt {p} -> {o}")
    print(f"{toks} tokens in {dt:.2f}s ({toks/dt:.1f} tok/s batched)")


def _run_rotations(args) -> None:
    import jax.numpy as jnp

    from repro.core.registry import plan_cache_stats
    from repro.serve import RotationService
    from repro.serve.rotations import synthetic_stream

    # canonical mixed-shape stream: >= 3 shape buckets by construction
    requests = synthetic_stream(args.requests, seed=args.seed)

    svc = RotationService(slots=args.slots, autotune=args.autotune)
    misses0 = plan_cache_stats()["misses"]
    t0 = obs.timing.now()
    outs = svc.apply_many(requests)
    jax.block_until_ready(outs[-1])
    dt = obs.timing.now() - t0
    resolved = plan_cache_stats()["misses"] - misses0

    if args.check:
        for (seq, A), out in zip(requests, outs):
            ref = seq.plan(like=A).apply(A)
            err = float(jnp.abs(out - ref).max())
            assert err < 1e-5, f"serving diverged from per-request: {err}"
        print("check: serving matches per-request application")

    s = svc.stats
    # req/s counts *real* requests only — identity pad slots on
    # partially-full buckets are accounted separately, never toward
    # throughput
    rps = s["requests"] / dt
    print(f"{s['requests']} requests in {dt*1e3:.1f} ms "
          f"({rps:.0f} req/s batched; {s['padded_slots']} pad slots of "
          f"{s['slots_executed']} executed)")
    print(f"buckets={len(svc._plans)} batches={s['batches']} "
          f"plans_resolved={s['plans_resolved']} (registry misses "
          f"{resolved}) warm_plans={s['warm_plans']} "
          f"padded_slots={s['padded_slots']}")

    if args.metrics_json:
        snap = obs.write_metrics_json(
            args.metrics_json,
            extra={"mode": "rotations", "requests": s["requests"],
                   "slots": args.slots, "seconds": dt})
        lat = snap["histograms"].get("serve.request_latency_seconds", {})
        print(f"metrics -> {args.metrics_json} "
              f"(latency p50={lat.get('p50', 0)*1e3:.2f} ms "
              f"p99={lat.get('p99', 0)*1e3:.2f} ms)")
    if args.trace:
        n_ev = obs.write_trace(args.trace)
        print(f"trace -> {args.trace} ({n_ev} events)")


def _run_stream(args) -> None:
    import numpy as np

    from repro.serve import StreamEngine

    requests = synthetic_stream_for(args)
    with StreamEngine(slots=args.slots, autotune=args.autotune) as eng:
        t0 = obs.timing.now()
        tickets = [eng.submit(seq, A) for seq, A in requests]
        outs = [t.result(timeout=600.0) for t in tickets]
        dt = obs.timing.now() - t0
    # context exit drains: every ticket is fulfilled here
    if args.check:
        from repro.serve import RotationService

        refs = RotationService(slots=args.slots).apply_many(requests)
        for ref, out in zip(refs, outs):
            if not np.array_equal(np.asarray(ref), np.asarray(out)):
                raise AssertionError(
                    "streamed result diverged from synchronous drain")
        print("check: streamed results bit-equal to synchronous drains")

    s = eng.stats
    print(f"{s['completed']} requests in {dt*1e3:.1f} ms "
          f"({s['completed']/dt:.0f} req/s streamed; closes: "
          f"size={s['closes_size']} age={s['closes_age']} "
          f"drain={s['closes_drain']}; shed={s['shed']})")

    if args.metrics_json:
        snap = obs.write_metrics_json(
            args.metrics_json,
            extra={"mode": "stream", "requests": s["completed"],
                   "slots": args.slots, "seconds": dt})
        lat = snap["histograms"].get("serve.request_latency_seconds", {})
        print(f"metrics -> {args.metrics_json} "
              f"(latency p50={lat.get('p50', 0)*1e3:.2f} ms "
              f"p99={lat.get('p99', 0)*1e3:.2f} ms)")
    if args.trace:
        n_ev = obs.write_trace(args.trace)
        print(f"trace -> {args.trace} ({n_ev} events)")


def synthetic_stream_for(args):
    from repro.serve.rotations import synthetic_stream

    return synthetic_stream(args.requests, seed=args.seed)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rotations", action="store_true",
                    help="serve rotation-application requests instead of "
                         "LM decoding")
    ap.add_argument("--stream", action="store_true",
                    help="rotation mode: drive the async StreamEngine "
                         "instead of the synchronous service")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--requests", type=int, default=24,
                    help="rotation mode: number of requests to stream")
    ap.add_argument("--slots", type=int, default=8,
                    help="rotation mode: per-bucket batch capacity")
    ap.add_argument("--autotune", action="store_true",
                    help="rotation mode: measure bucket plans")
    ap.add_argument("--check", action="store_true",
                    help="rotation mode: verify against per-request apply")
    ap.add_argument("--metrics-json", default=None,
                    help="enable repro.obs and write the metrics + "
                         "roofline snapshot here")
    ap.add_argument("--trace", default=None,
                    help="enable span tracing and write Chrome trace "
                         "JSON here (view in ui.perfetto.dev)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.metrics_json or args.trace:
        obs.set_enabled(True)
        if args.trace:
            obs.runtime.set_trace_path(args.trace)

    if args.rotations:
        if args.stream:
            _run_stream(args)
        else:
            _run_rotations(args)
        return
    if args.arch is None:
        ap.error("--arch is required unless --rotations is given")
    _run_lm(args)


if __name__ == "__main__":
    main()
