"""Serving launcher: batched greedy decoding with the ServeEngine.

Example::

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
      --reduced --batch 4 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config
from repro.models import build_model
from repro.serve import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    eng = ServeEngine(model, cfg, params, batch=args.batch,
                      max_len=args.max_len)
    prompts = [[(7 * i + j) % cfg.vocab for j in range(4 + i)]
               for i in range(args.batch)]
    t0 = time.perf_counter()
    outs = eng.generate(prompts, max_new=args.max_new)
    dt = time.perf_counter() - t0
    toks = sum(len(o) for o in outs)
    for p, o in zip(prompts, outs):
        print(f"prompt {p} -> {o}")
    print(f"{toks} tokens in {dt:.2f}s ({toks/dt:.1f} tok/s batched)")


if __name__ == "__main__":
    main()
