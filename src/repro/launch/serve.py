"""Serving launcher: batched LM decoding, or batched rotation serving.

LM mode (default) drives the ServeEngine::

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
      --reduced --batch 4 --max-new 16

Rotation mode drives the shape-bucketed RotationService end-to-end: a
mixed-shape stream of recorded rotation sequences is admitted into
buckets, executed through one frozen plan per bucket, checked against
per-request application, and timed::

  PYTHONPATH=src python -m repro.launch.serve --rotations \
      --requests 64 --slots 8
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config
from repro.models import build_model
from repro.serve import ServeEngine


def _run_lm(args) -> None:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    eng = ServeEngine(model, cfg, params, batch=args.batch,
                      max_len=args.max_len)
    prompts = [[(7 * i + j) % cfg.vocab for j in range(4 + i)]
               for i in range(args.batch)]
    t0 = time.perf_counter()
    outs = eng.generate(prompts, max_new=args.max_new)
    dt = time.perf_counter() - t0
    toks = sum(len(o) for o in outs)
    for p, o in zip(prompts, outs):
        print(f"prompt {p} -> {o}")
    print(f"{toks} tokens in {dt:.2f}s ({toks/dt:.1f} tok/s batched)")


def _run_rotations(args) -> None:
    import jax.numpy as jnp

    from repro.core.registry import plan_cache_stats
    from repro.serve import RotationService
    from repro.serve.rotations import synthetic_stream

    # canonical mixed-shape stream: >= 3 shape buckets by construction
    requests = synthetic_stream(args.requests, seed=args.seed)

    svc = RotationService(slots=args.slots, autotune=args.autotune)
    misses0 = plan_cache_stats()["misses"]
    t0 = time.perf_counter()
    outs = svc.apply_many(requests)
    jax.block_until_ready(outs[-1])
    dt = time.perf_counter() - t0
    resolved = plan_cache_stats()["misses"] - misses0

    if args.check:
        for (seq, A), out in zip(requests, outs):
            ref = seq.plan(like=A).apply(A)
            err = float(jnp.abs(out - ref).max())
            assert err < 1e-5, f"serving diverged from per-request: {err}"
        print("check: serving matches per-request application")

    s = svc.stats
    rps = args.requests / dt
    print(f"{args.requests} requests in {dt*1e3:.1f} ms "
          f"({rps:.0f} req/s batched)")
    print(f"buckets={len(svc._plans)} batches={s['batches']} "
          f"plans_resolved={s['plans_resolved']} (registry misses "
          f"{resolved}) warm_plans={s['warm_plans']} "
          f"padded_slots={s['padded_slots']}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rotations", action="store_true",
                    help="serve rotation-application requests instead of "
                         "LM decoding")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--requests", type=int, default=24,
                    help="rotation mode: number of requests to stream")
    ap.add_argument("--slots", type=int, default=8,
                    help="rotation mode: per-bucket batch capacity")
    ap.add_argument("--autotune", action="store_true",
                    help="rotation mode: measure bucket plans")
    ap.add_argument("--check", action="store_true",
                    help="rotation mode: verify against per-request apply")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.rotations:
        _run_rotations(args)
        return
    if args.arch is None:
        ap.error("--arch is required unless --rotations is given")
    _run_lm(args)


if __name__ == "__main__":
    main()
