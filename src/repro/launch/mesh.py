"""Production mesh construction.

``make_production_mesh`` is a function (not a module-level constant) so
importing this module never touches jax device state — the dry-run sets
``xla_force_host_platform_device_count`` before first jax init.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_rules_for_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_rules_for_mesh(mesh, *, seq_parallel: bool = False):
    """AxisRules bound to a mesh (drops the "pod" axis on single-pod)."""
    from repro.parallel.sharding import AxisRules

    names = set(mesh.axis_names)
    data_axes = tuple(a for a in ("pod", "data") if a in names)
    rules = {
        "batch": data_axes,
        "heads": "model",
        "kv_heads": "model",
        "ff": "model",
        "vocab": "model",
        "experts": "model",
        "seq": "model" if seq_parallel else None,
        "embed": None,
    }
    return AxisRules(
        rules=rules,
        fsdp_axes=data_axes,
        mesh_shape={a: int(s) for a, s in
                    zip(mesh.axis_names, mesh.devices.shape)},
    )
