"""ShapeDtypeStruct input specs + sharding trees for every dry-run cell.

``input_specs(cfg, shape)`` returns the exact abstract inputs a cell's
step function is lowered with (weak-type-correct, shardable, no device
allocation), plus which step function kind applies (train / prefill /
decode).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import build_model
from repro.parallel.sharding import AxisRules, logical_to_spec, param_spec

__all__ = ["input_specs", "sharding_trees", "abstract_params",
           "abstract_opt_state", "abstract_cache"]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Abstract batch for the given (arch, shape) cell."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.is_encdec:
        if shape.kind == "train" or shape.kind == "prefill":
            D = min(cfg.dec_len, S)
            return {
                "frames": _sds((B, S, cfg.d_model), jnp.bfloat16),
                "dec_tokens": _sds((B, D), jnp.int32),
                "labels": _sds((B, D), jnp.int32),
            }
        return {"tokens": _sds((B, 1), jnp.int32)}  # decode step input
    if shape.kind == "decode":
        return {"tokens": _sds((B, 1), jnp.int32)}
    out = {"tokens": _sds((B, S), jnp.int32)}
    if shape.kind == "train":
        out["labels"] = _sds((B, S), jnp.int32)
    return out


def batch_spec_tree(cfg, shape, rules: AxisRules):
    """PartitionSpecs matching input_specs (batch over data axes)."""
    abs_tree = input_specs(cfg, shape)

    def leaf(name, logical):
        return logical_to_spec(logical, rules,
                               shape=tuple(abs_tree[name].shape))

    if cfg.is_encdec and shape.kind in ("train", "prefill"):
        return {
            "frames": leaf("frames", ("batch", "seq", "embed")),
            "dec_tokens": leaf("dec_tokens", ("batch", None)),
            "labels": leaf("labels", ("batch", None)),
        }
    if shape.kind == "decode" or (cfg.is_encdec and shape.kind == "decode"):
        return {"tokens": leaf("tokens", ("batch", None))}
    out = {"tokens": leaf("tokens", ("batch", "seq"))}
    if shape.kind == "train":
        out["labels"] = leaf("labels", ("batch", "seq"))
    return out


def abstract_params(model, dtype=jnp.float32):
    return jax.eval_shape(lambda k: model.init(k, dtype=dtype),
                          jax.random.key(0))


def abstract_opt_state(optimizer, params_abs):
    return jax.eval_shape(optimizer.init, params_abs)


def abstract_cache(model, cfg, shape, dtype=jnp.bfloat16):
    B, S = shape.global_batch, shape.seq_len
    if cfg.is_encdec:
        frames = _sds((B, S, cfg.d_model), jnp.bfloat16)
        return jax.eval_shape(
            lambda p, f: model.init_cache(p, f, cfg.dec_len, dtype=dtype),
            abstract_params(model), frames)
    return jax.eval_shape(
        lambda: model.init_cache(B, S, dtype=dtype))


def _spec_from_logical_tree(abs_tree, logical_tree, rules,
                            *, params: bool):
    """Map a logical-axis tree onto PartitionSpecs (leaf-wise)."""
    is_leaf = lambda t: isinstance(t, tuple)
    flat_abs, treedef = jax.tree_util.tree_flatten(abs_tree)
    flat_log = treedef.flatten_up_to(
        jax.tree.map(lambda t: t, logical_tree, is_leaf=is_leaf))

    out = []
    for a, l in zip(flat_abs, flat_log):
        if params:
            out.append(param_spec(a.shape, l, rules))
        else:
            out.append(logical_to_spec(l, rules, shape=tuple(a.shape)))
    return jax.tree_util.tree_unflatten(treedef, out)


def sharding_trees(model, cfg, shape, optimizer, rules: AxisRules,
                   mesh) -> Dict[str, Any]:
    """NamedSharding trees for params / opt state / batch / cache."""
    from jax.sharding import NamedSharding

    def to_named(spec_tree):
        return jax.tree.map(
            lambda s: NamedSharding(mesh, s), spec_tree,
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))

    params_abs = abstract_params(model)
    logical = model.param_logical()
    p_spec = _spec_from_logical_tree(params_abs, logical, rules,
                                     params=True)
    out = {"params_abs": params_abs, "params": to_named(p_spec)}

    if shape.kind == "train":
        opt_abs = abstract_opt_state(optimizer, params_abs)

        def opt_leaf_spec(path_leaf):
            return path_leaf  # placeholder; built below

        # m/v follow the param spec; scalars and q8 scales replicate
        from jax.sharding import PartitionSpec as P

        def follow(abs_sub):
            from repro.parallel.sharding import _dedup

            flat_p, treedef_p = jax.tree_util.tree_flatten(params_abs)
            flat_spec = treedef_p.flatten_up_to(p_spec)
            # abs_sub has same structure as params, possibly with
            # Quantized leaves (q + scale)
            def match(a, s):
                if hasattr(a, "q"):  # Quantized NamedTuple of abstracts
                    # scales share the leading axes; the blocks axis keeps
                    # the param's last-dim sharding only if it divides
                    sc = P(*_dedup(list(s), tuple(a.scale.shape), rules))
                    return type(a)(q=s, scale=sc)
                return s
            flat_a = treedef_p.flatten_up_to(abs_sub)
            return treedef_p.unflatten(
                [match(a, s) for a, s in zip(flat_a, flat_spec)])

        if "per" in opt_abs:  # SoapGivens
            o_spec = jax.tree.map(lambda _: P(), opt_abs)
        else:
            o_spec = {"step": P(),
                      "m": follow(opt_abs["m"]),
                      "v": follow(opt_abs["v"])}
        out["opt_abs"] = opt_abs
        out["opt"] = to_named(o_spec)

    out["batch"] = to_named(batch_spec_tree(cfg, shape, rules))

    if shape.kind == "decode":
        cache_abs = abstract_cache(model, cfg, shape)
        c_log = model.cache_logical()
        c_spec = _spec_from_logical_tree(cache_abs, c_log, rules,
                                         params=False)
        out["cache_abs"] = cache_abs
        out["cache"] = to_named(c_spec)
    return out
