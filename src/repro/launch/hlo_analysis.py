"""Post-SPMD HLO cost analyzer with correct while-loop accounting.

XLA's built-in ``compiled.cost_analysis()`` counts every ``while`` body
**once**, regardless of trip count (verified empirically — see
EXPERIMENTS.md SSDry-run).  Scanned-layer models are therefore
undercounted by ~n_layers.  This module parses the compiled HLO text,
recovers each loop's trip count from its condition computation, and
accumulates

  * ``flops``   — exact for dot/convolution (contraction dims resolved
                  from operand shapes), 1 flop/element for fusions,
  * ``bytes``   — HBM-traffic estimate: sum of operand + result bytes of
                  memory-touching top-level instructions (fusions, dots,
                  copies, slices, collectives, sorts, ...),
  * ``collectives`` — result bytes + op counts per collective kind,

multiplying while bodies by their trip counts (nested loops compose
multiplicatively: grad-accumulation x layer scan x flash-chunk scan).

Validated in tests against unrolled-vs-scanned programs where XLA's own
numbers are exact.
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

__all__ = ["analyze_hlo", "HloCost"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "s4": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "u4": 1,
    "pred": 1, "c64": 8, "c128": 16, "token": 0, "s2": 1, "u2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\](?:\{[^}]*\})?")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([^\s=]+)\s*=\s*(.*)$")
# header params may contain nested parens (tuple types): just grab the name
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([^\s(]+)\s*\(")

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "add-dependency", "partition-id", "replica-id", "iota",
}

# top-level opcodes whose operands+results approximate HBM traffic
_MEM_OPS_PREFIX = (
    "fusion", "dot", "convolution", "copy", "dynamic-slice",
    "dynamic-update-slice", "sort", "gather", "scatter", "reduce",
    "broadcast", "transpose", "reshape", "concatenate", "slice", "pad",
    "select-and-scatter", "rng", "cholesky", "triangular-solve",
) + _COLL_KINDS + tuple(k + "-start" for k in _COLL_KINDS) + (
    "all-gather-start", "all-reduce-start", "collective-permute-start",
)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n
    return total


@dataclass
class Instr:
    name: str
    opcode: str
    type_str: str
    operands: List[str]
    attrs: str
    raw: str = ""


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    by_name: Dict[str, Instr] = field(default_factory=dict)


@dataclass
class HloCost:
    flops: float = 0.0
    dot_flops: float = 0.0      # MXU work (dot/convolution only)
    bytes: float = 0.0          # HBM upper bound: operands + results
    bytes_lo: float = 0.0       # HBM lower bound: results only
    transcendentals: float = 0.0
    collective_bytes: Dict[str, float] = field(
        default_factory=lambda: defaultdict(float))
    collective_counts: Dict[str, float] = field(
        default_factory=lambda: defaultdict(float))

    def add(self, other: "HloCost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.dot_flops += other.dot_flops * mult
        self.bytes += other.bytes * mult
        self.bytes_lo += other.bytes_lo * mult
        self.transcendentals += other.transcendentals * mult
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] += v * mult
        for k, v in other.collective_counts.items():
            self.collective_counts[k] += v * mult


_OPCODE_RE = re.compile(r"^([a-z][a-z0-9-]*)\(")


def _parse_rhs(rhs: str) -> Tuple[str, str, List[str], str]:
    """rhs = '<type> opcode(%a, %b, ...), attrs' -> parts."""
    # type prefix ends right before ' opcode('
    m = re.search(r"\s([a-z][a-z0-9-]*)\(", rhs)
    if not m:
        return rhs, "unknown", [], ""
    type_str = rhs[: m.start()]
    opcode = m.group(1)
    rest = rhs[m.end():]
    # operands until matching close paren
    depth = 1
    i = 0
    while i < len(rest) and depth:
        if rest[i] == "(":
            depth += 1
        elif rest[i] == ")":
            depth -= 1
        i += 1
    args = rest[: i - 1]
    attrs = rest[i:]
    operands = re.findall(r"%([^\s,()]+)", args)
    return type_str, opcode, operands, attrs


def _parse_computations(text: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        stripped = line.strip()
        if cur is None:
            if stripped.endswith("{") and "->" in stripped:
                m = _COMP_HDR_RE.match(stripped)
                if m:
                    cur = Computation(m.group(1))
                    if stripped.startswith("ENTRY"):
                        entry = cur.name
            continue
        if stripped.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        type_str, opcode, operands, attrs = _parse_rhs(rhs)
        ins = Instr(name, opcode, type_str, operands, attrs, rhs)
        cur.instrs.append(ins)
        cur.by_name[name] = ins
    return comps, entry


def _dims_of(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def _dot_flops(ins: Instr, comp: Computation) -> float:
    out_elems = _shape_elems(ins.type_str)
    lhs = comp.by_name.get(ins.operands[0])
    if lhs is None:
        return 0.0
    lhs_dims = _dims_of(lhs.type_str)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.attrs)
    contract = 1
    if m:
        for d in m.group(1).split(","):
            if d:
                contract *= lhs_dims[int(d)] if int(d) < len(lhs_dims) else 1
    return 2.0 * out_elems * contract


def _conv_flops(ins: Instr, comp: Computation) -> float:
    out_elems = _shape_elems(ins.type_str)
    rhs = comp.by_name.get(ins.operands[1]) if len(ins.operands) > 1 else None
    if rhs is None:
        return 2.0 * out_elems
    k_elems = _shape_elems(rhs.type_str)
    out_dims = _dims_of(ins.type_str)
    feat = out_dims[-1] if out_dims else 1  # approximation
    return 2.0 * out_elems * max(k_elems // max(feat, 1), 1)


def _trip_count(cond: Computation) -> float:
    """Recover canonical scan trip count: s32 constant compared with LT."""
    vals = []
    for ins in cond.instrs:
        if ins.opcode == "constant" and ins.type_str.strip().startswith(
                ("s32", "s64", "u32", "u64")):
            m = re.search(r"constant\((-?[0-9]+)\)", ins.raw)
            if m:
                vals.append(int(m.group(1)))
    if not vals:
        return 1.0
    # canonical scans compare the induction variable LT length; pick the
    # largest integer constant in the condition computation
    return float(max(vals))


def _comp_cost(comp: Computation, comps: Dict[str, Computation],
               memo: Dict[str, HloCost]) -> HloCost:
    if comp.name in memo:
        return memo[comp.name]
    cost = HloCost()
    for ins in comp.instrs:
        op = ins.opcode
        if op in _FREE_OPS:
            continue
        if op == "while":
            m = re.search(r"condition=%?([^\s,]+),?\s*body=%?([^\s,]+)",
                          ins.attrs)
            if not m:
                m = re.search(r"body=%?([^\s,]+),?\s*condition=%?([^\s,]+)",
                              ins.attrs)
                cond_n, body_n = (m.group(2), m.group(1)) if m else (None,
                                                                     None)
            else:
                cond_n, body_n = m.group(1), m.group(2)
            if body_n and body_n in comps:
                trips = _trip_count(comps[cond_n]) if cond_n in comps else 1.0
                body_cost = _comp_cost(comps[body_n], comps, memo)
                cost.add(body_cost, trips)
                if cond_n in comps:
                    cost.add(_comp_cost(comps[cond_n], comps, memo),
                             trips + 1)
            continue
        if op in ("call", "conditional", "async-start"):
            for target in re.findall(
                    r"(?:to_apply|called_computations?|branch_computations)"
                    r"=\{?%?([^\s,}]+)", ins.attrs):
                if target in comps:
                    cost.add(_comp_cost(comps[target], comps, memo))
            continue

        is_coll = None
        for k in _COLL_KINDS:
            if op == k or op == k + "-start":
                is_coll = k
                break
        if is_coll:
            b = _shape_bytes(ins.type_str)
            cost.collective_bytes[is_coll] += b
            cost.collective_counts[is_coll] += 1

        if op.startswith(_MEM_OPS_PREFIX):
            b = _shape_bytes(ins.type_str)
            cost.bytes_lo += b
            for o in ins.operands:
                src = comp.by_name.get(o)
                if src is not None:
                    b += _shape_bytes(src.type_str)
            cost.bytes += b

        if op == "dot":
            f = _dot_flops(ins, comp)
            cost.flops += f
            cost.dot_flops += f
        elif op == "convolution":
            f = _conv_flops(ins, comp)
            cost.flops += f
            cost.dot_flops += f
        elif op == "fusion":
            m = re.search(r"calls=%?([^\s,]+)", ins.attrs)
            if m and m.group(1) in comps:
                inner = _comp_cost(comps[m.group(1)], comps, memo)
                cost.flops += inner.flops
                cost.transcendentals += inner.transcendentals
                # bytes already approximated at the fusion boundary
        elif op in ("exponential", "tanh", "log", "rsqrt", "sqrt", "power",
                    "sine", "cosine", "logistic", "exponential-minus-one",
                    "log-plus-one", "atan2", "erf"):
            cost.transcendentals += _shape_elems(ins.type_str)
            cost.flops += _shape_elems(ins.type_str)
        elif op in ("add", "subtract", "multiply", "divide", "maximum",
                    "minimum", "negate", "abs", "compare", "select",
                    "and", "or", "xor", "not", "clamp", "floor", "ceil",
                    "round-nearest-afz", "round-nearest-even", "sign",
                    "remainder", "convert", "reduce", "map"):
            cost.flops += _shape_elems(ins.type_str)
    memo[comp.name] = cost
    return cost


def analyze_hlo(text: str) -> HloCost:
    comps, entry = _parse_computations(text)
    if entry is None:
        return HloCost()
    memo: Dict[str, HloCost] = {}
    total = HloCost()
    total.add(_comp_cost(comps[entry], comps, memo))
    total.collective_bytes = dict(total.collective_bytes)
    total.collective_counts = dict(total.collective_counts)
    return total
