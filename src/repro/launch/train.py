"""Training launcher.

Single-process CPU runs use reduced configs directly; on a real cluster
the same script runs under ``jax.distributed`` with the production mesh
(``--mesh single|multi``).  Fault tolerance: restores the newest complete
checkpoint; straggler monitor reports slow steps.

Examples::

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --reduced --steps 50 --batch 8 --seq 64
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --reduced --steps 50 --optimizer soap_givens
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import DataConfig, SyntheticLM
from repro.models import build_model
from repro.optim import AdamW, SoapGivens, warmup_cosine
from repro.train import StragglerMonitor, TrainLoop, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="family-preserving tiny config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adamw_q8", "soap_givens"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M")

    sched = warmup_cosine(args.lr, warmup=args.steps // 10 + 1,
                          total=args.steps)
    opt = {
        "adamw": AdamW(lr=sched),
        "adamw_q8": AdamW(lr=sched, quantized=True),
        "soap_givens": SoapGivens(lr=sched),
    }[args.optimizer]

    step = jax.jit(make_train_step(model, cfg, opt, remat=False,
                                   grad_accum=args.grad_accum))
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                  global_batch=args.batch))
    mon = StragglerMonitor()
    mon.on_straggler = lambda s, dt, med: print(
        f"  [straggler] step {s}: {dt:.2f}s vs median {med:.2f}s")

    loop = TrainLoop(train_step=step, params=params,
                     opt_state=opt.init(params), data_iter=data,
                     ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                     monitor=mon)
    start = loop.maybe_restore()
    if start:
        print(f"restored checkpoint at step {start}")
    hist = loop.run(args.steps)
    for i in range(0, len(hist["loss"]), args.log_every):
        print(f"step {start + i + 1:5d}  loss {hist['loss'][i]:.4f}  "
              f"{hist['time'][i]*1e3:.0f} ms")
    print(f"final loss {hist['loss'][-1]:.4f}")


if __name__ == "__main__":
    main()
