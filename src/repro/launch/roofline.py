"""Roofline analysis over the dry-run artifacts.

Per (arch x shape) cell on the single-pod mesh, derives the three-term
roofline from the loop-corrected HLO cost analysis:

  compute    = HLO_FLOPs_global  / (chips * PEAK_FLOPS)
  memory     = HLO_bytes_global  / (chips * HBM_BW)
  collective = wire_bytes_global / (chips * LINK_BW)

where HLO_FLOPs/bytes come from ``hlo_analysis`` (per-device, x chips for
global) and collective wire bytes apply ring-model factors per kind:
  all-gather / reduce-scatter: (D-1)/D * payload
  all-reduce:               2 * (D-1)/D * payload
  all-to-all:                (D-1)/D * payload
  collective-permute:         payload
(Payload = result-shape bytes already per device; D inferred from the
op's use of the mesh is approximated by the TP width since TP collectives
dominate — documented approximation.)

Also reports MODEL_FLOPS = 6*N*D_tokens (train) / 2*N_active*D (decode/
prefill), the useful-compute ratio MODEL/HLO, the dominant term, and the
roofline fraction = MODEL_FLOPS_time / max(term).

Usage: ``python -m repro.launch.roofline [--mesh single] [--markdown]``
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.hw import PLATFORMS

# per-chip peaks come from the shared hardware table (repro.hw) —
# the single source of truth shared with the registry's cost model
_HW = PLATFORMS["tpu"]
PEAK_FLOPS = _HW.mxu_flops   # bf16 MXU / chip (v5e)
VPU_FLOPS = _HW.vpu_flops    # ~elementwise ops/s / chip (8x128 VPU, est.)
HBM_BW = _HW.hbm_bw          # B/s / chip
LINK_BW = _HW.link_bw        # B/s / link (ICI)

HERE = os.path.dirname(__file__)
DRYRUN_DIR = os.path.join(HERE, "..", "..", "..", "experiments", "dryrun")

# total params and active params per arch (from eval_shape; active =
# dense-equivalent params touched per token for MoE)
PARAMS = {
    "starcoder2-3b": (3.030e9, 3.030e9),
    "smollm-135m": (0.135e9, 0.135e9),
    "llama3-405b": (405.9e9, 405.9e9),
    "gemma3-4b": (3.880e9, 3.880e9),
    "recurrentgemma-9b": (9.396e9, 9.396e9),
    "chameleon-34b": (34.29e9, 34.29e9),
    "deepseek-v2-lite-16b": (15.71e9, 2.66e9),
    "kimi-k2-1t-a32b": (1028.3e9, 32.4e9),
    "mamba2-370m": (0.368e9, 0.368e9),
    "whisper-large-v3": (1.535e9, 1.535e9),
}


def model_flops(arch: str, kind: str, seq: int, batch: int,
                dec_len: int = 448) -> float:
    n_total, n_active = PARAMS[arch]
    if kind == "train":
        tokens = seq * batch
        if arch == "whisper-large-v3":
            tokens = (seq + min(dec_len, seq)) * batch
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = seq * batch
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * batch


def coll_seconds(coll: dict, chips: int, tp: int = 16) -> float:
    """Ring-model collective time per device (seconds)."""
    f = (tp - 1) / tp
    t = 0.0
    t += coll.get("all-gather", 0) * f
    t += coll.get("reduce-scatter", 0) * f
    t += coll.get("all-reduce", 0) * 2 * f
    t += coll.get("all-to-all", 0) * f
    t += coll.get("collective-permute", 0)
    return t / LINK_BW


def analyze(rec: dict) -> dict:
    chips = rec["chips"]
    hc = rec["hlo_cost"]
    flops_dev = hc["flops_per_device"]
    dot_dev = hc.get("dot_flops_per_device", flops_dev)
    # compute term: MXU work at MXU peak + elementwise work at VPU rate
    compute_s = dot_dev / PEAK_FLOPS + (flops_dev - dot_dev) / VPU_FLOPS
    # memory term: geometric mean of the fusion-blind upper bound
    # (operands+results) and the fusion-perfect lower bound (results only)
    b_hi = hc["bytes_per_device"]
    b_lo = hc.get("bytes_lo_per_device", b_hi)
    bytes_dev = (b_hi * max(b_lo, 1)) ** 0.5
    memory_s = bytes_dev / HBM_BW
    coll_s = coll_seconds(hc["collective_bytes_per_device"], chips)
    shape_cfg = {"train_4k": (4096, 256), "prefill_32k": (32768, 32),
                 "decode_32k": (32768, 128), "long_500k": (524288, 1)}
    seq, batch = shape_cfg[rec["shape"]]
    mf = model_flops(rec["arch"], rec["kind"], seq, batch)
    ideal_s = mf / (chips * PEAK_FLOPS)
    useful = mf / max(dot_dev * chips, 1)
    bound_s = max(compute_s, memory_s, coll_s)
    dominant = ("compute" if bound_s == compute_s
                else "memory" if bound_s == memory_s else "collective")
    return {
        "cell": rec["cell"],
        "dot_flops_global": dot_dev * chips,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": coll_s,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_global": flops_dev * chips,
        "useful_ratio": useful,
        "roofline_fraction": ideal_s / max(bound_s, 1e-30),
        "mem_gib": (rec["memory"]["argument_bytes"]
                    + rec["memory"]["temp_bytes"]
                    + rec["memory"]["output_bytes"]
                    - rec["memory"]["alias_bytes"]) / 2**30,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        rec = json.load(open(path))
        if rec.get("status") != "ok" or rec.get("mesh") != args.mesh:
            continue
        rows.append(analyze(rec))

    hdr = ("| cell | compute s | memory s | collective s | dominant | "
           "useful FLOP ratio | roofline frac | GiB/chip |")
    sep = "|" + "---|" * 8
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['cell']} | {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | {r['dominant']} "
            f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']:.2%} "
            f"| {r['mem_gib']:.1f} |")
    text = "\n".join(lines)
    print(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
        with open(args.out.replace(".md", ".json"), "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
