import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any other import (jax locks device
count at first init): the dry-run — and only the dry-run — sees 512
placeholder CPU devices so ``jax.make_mesh`` can build the production
(16, 16) single-pod and (2, 16, 16) multi-pod meshes.

For every cell this script:
  1. builds the model + step function (train_step for training shapes,
     ``forward`` for prefill, ``decode_step`` for decode),
  2. jits with explicit in/out shardings (FSDP + TP + EP rules),
  3. ``.lower(...).compile()`` over ShapeDtypeStructs (no allocation),
  4. records ``memory_analysis()`` (fits-in-HBM proof),
     ``cost_analysis()`` (per-device FLOPs/bytes) and the collective
     bytes parsed from the post-SPMD HLO,
  5. writes ``experiments/dryrun/<arch>__<shape>__<mesh>.json`` (cached:
     re-runs skip completed cells).

Usage::

  python -m repro.launch.dryrun                       # full sweep
  python -m repro.launch.dryrun --arch smollm-135m    # one arch
  python -m repro.launch.dryrun --arch X --shape train_4k --mesh multi
"""
import argparse
import json
import re
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, get_config, shape_skips
from repro.obs import timing
from repro.launch.mesh import make_production_mesh, make_rules_for_mesh
from repro.launch.specs import (abstract_cache, abstract_opt_state,
                                abstract_params, input_specs,
                                sharding_trees)
from repro.models import build_model
from repro.optim import AdamW
from repro.parallel.sharding import axis_rules
from repro.train import make_train_step

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8\w*|s64|s32|s16|s8|u64|u32|"
                       r"u16|u8|pred|c64|c128)\[([0-9,]*)\]")
_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "s32": 4,
          "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2, "u8": 1,
          "pred": 1, "c64": 8, "c128": 16}
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(txt: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(txt):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _BYTES.get(dt.split("{")[0], 4)
    return total


def collective_bytes(hlo_text: str):
    """Sum result-shape bytes of every collective op, by kind (per device)."""
    out = {k: 0 for k in _COLL_KINDS}
    counts = {k: 0 for k in _COLL_KINDS}
    for line in hlo_text.splitlines():
        if "=" not in line:
            continue
        for kind in _COLL_KINDS:
            # match "= <shape> kind(" and fused variants "kind-start("
            if f" {kind}(" in line or f" {kind}-start(" in line:
                lhs = line.split("=", 1)[1]
                op = lhs.find(kind)
                out[kind] += _shape_bytes(lhs[:op])
                counts[kind] += 1
                break
    return out, counts


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             force: bool = False, seq_parallel=None):
    os.makedirs(OUT_DIR, exist_ok=True)
    tag = f"{arch}__{shape_name}__{mesh_kind}"
    path = os.path.join(OUT_DIR, tag + ".json")
    if os.path.exists(path) and not force:
        print(f"[skip cached] {tag}")
        return json.load(open(path))

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    skip = shape_skips(cfg, shape)
    if skip:
        rec = {"cell": tag, "status": "skipped", "reason": skip}
        json.dump(rec, open(path, "w"), indent=1)
        print(f"[skip] {tag}: {skip}")
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    # sequence/context parallelism is on by default: it is required for
    # activations (train) and KV caches (decode) to fit 16 GiB/chip at
    # the production mesh; the hillclimb ablates it per-cell
    sp = True if seq_parallel is None else seq_parallel
    rules = make_rules_for_mesh(mesh, seq_parallel=sp)
    model = build_model(cfg)
    optimizer = AdamW(lr=1e-4, quantized=cfg.dryrun_q8)

    t0 = timing.now()
    with axis_rules(rules, mesh=mesh):
        trees = sharding_trees(model, cfg, shape, optimizer, rules, mesh)
        batch_abs = input_specs(cfg, shape)
        # training holds fp32 master params (unless the arch's policy says
        # bf16, e.g. kimi-k2); serving always deploys bf16 weights
        pdtype = (jnp.dtype(cfg.param_dtype) if shape.kind == "train"
                  else jnp.dtype(jnp.bfloat16))
        params_abs = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, pdtype)
            if x.dtype == jnp.float32 else x, trees["params_abs"])

        if shape.kind == "train":
            step = make_train_step(model, cfg, optimizer,
                                   grad_accum=cfg.dryrun_grad_accum,
                                   grad_shardings=trees["params"])
            opt_abs = abstract_opt_state(optimizer, params_abs)
            jf = jax.jit(
                step,
                in_shardings=(trees["params"], trees["opt"],
                              trees["batch"]),
                out_shardings=(trees["params"], trees["opt"], None),
                donate_argnums=(0, 1),
            )
            lowered = jf.lower(params_abs, opt_abs, batch_abs)
        elif shape.kind == "prefill":
            if cfg.is_encdec:
                def fwd(p, b):
                    return model.forward(p, b["frames"], b["dec_tokens"])
            else:
                def fwd(p, b):
                    return model.forward(p, b["tokens"])
            jf = jax.jit(fwd, in_shardings=(trees["params"],
                                            trees["batch"]))
            lowered = jf.lower(params_abs, batch_abs)
        else:  # decode
            def dec(p, c, b):
                return model.decode_step(p, c, b["tokens"])
            jf = jax.jit(
                dec,
                in_shardings=(trees["params"], trees["cache"],
                              trees["batch"]),
                out_shardings=(None, trees["cache"]),
                donate_argnums=(1,),
            )
            lowered = jf.lower(params_abs, trees["cache_abs"], batch_abs)

        t_lower = timing.now() - t0
        compiled = lowered.compile()
        t_compile = timing.now() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll, coll_counts = collective_bytes(hlo)
    # while-loop-aware analysis (XLA's cost_analysis counts scan bodies
    # once; see hlo_analysis.py) — this is what the roofline uses
    from repro.launch.hlo_analysis import analyze_hlo
    hc = analyze_hlo(hlo)

    rec = {
        "cell": tag,
        "status": "ok",
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "chips": int(mesh.devices.size),
        "kind": shape.kind,
        "seq_parallel": sp,
        "grad_accum": cfg.dryrun_grad_accum if shape.kind == "train" else 1,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        # per-device numbers (verified semantics; see EXPERIMENTS.md)
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
        "cost": {
            "flops_per_device": cost.get("flops", -1),
            "transcendentals": cost.get("transcendentals", -1),
            "bytes_accessed_per_device": cost.get("bytes accessed", -1),
        },
        # loop-corrected per-device totals (roofline source of truth)
        "hlo_cost": {
            "flops_per_device": hc.flops,
            "dot_flops_per_device": hc.dot_flops,
            "bytes_per_device": hc.bytes,
            "bytes_lo_per_device": hc.bytes_lo,
            "transcendentals": hc.transcendentals,
            "collective_bytes_per_device": dict(hc.collective_bytes),
            "collective_counts": dict(hc.collective_counts),
        },
        "collective_bytes_per_device": coll,
        "collective_counts": coll_counts,
    }
    json.dump(rec, open(path, "w"), indent=1)
    peak = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
            + mem.output_size_in_bytes - mem.alias_size_in_bytes)
    print(f"[ok] {tag}: lower {t_lower:.0f}s compile {t_compile:.0f}s "
          f"mem/device ~{peak/2**30:.2f} GiB "
          f"flops/device {rec['cost']['flops_per_device']:.3e}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default=None, choices=[None, "single",
                                                     "multi"])
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [args.mesh] if args.mesh else ["single", "multi"]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                try:
                    run_cell(arch, shape, mesh_kind, force=args.force)
                except Exception:
                    failures.append(f"{arch}__{shape}__{mesh_kind}")
                    print(f"[FAIL] {arch}__{shape}__{mesh_kind}")
                    traceback.print_exc()
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print("dry-run sweep complete")


if __name__ == "__main__":
    main()
