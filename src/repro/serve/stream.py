"""repro.serve.stream — async continuous-batching rotation serving.

:class:`StreamEngine` puts two daemon threads (scheduler + dispatcher)
around a depth-1 handoff queue on top of
:class:`~repro.serve.rotations.RotationService`'s shape buckets:
``submit()`` admits requests without touching JAX, buckets close on an
adaptive size-or-age policy priced by the §6 cost model, and closed
batches execute through the exact synchronous batch path — so streamed
results are bit-equal to a synchronous drain while host assembly
double-buffers against device execution.  Buckets are per-request
batches (one sequence per slot), so their plans are priced with
``shared_sequence=False`` — the serving-aware cost model that lets
``method="auto"`` run streaming workloads unpinned.  Backpressure
(``block``/``fail``/``shed``), deadlines, and every counter are
explicit; analyzer rule RA204 confines thread/queue primitives to this
module.

The full design — bucket lifecycle, warm plans, backpressure and
deadline semantics, close policy — is documented in
``docs/serving.md``; ``docs/architecture.md`` places this module in the
registry → sequence → serve → stream layer diagram, and
``docs/cost-model.md`` derives the per-request bucket pricing.
"""
from __future__ import annotations

import math
import queue
import threading
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro import obs
from repro.serve.rotations import BucketKey, RotationService

__all__ = ["StreamEngine", "StreamTicket", "Backpressure",
           "DeadlineExceeded", "EngineClosed"]


class Backpressure(RuntimeError):
    """The global pending budget is full and the policy rejects."""


class DeadlineExceeded(RuntimeError):
    """The request was shed because its deadline passed while queued."""


class EngineClosed(RuntimeError):
    """The engine stopped before this request could be served."""


# serializes lazy Event creation across racing result() waiters; held
# for pointer reads/stores only, never while waiting
_TICKET_EVENT_LOCK = threading.Lock()


class StreamTicket:
    """Future-like handle for one streamed request.

    ``result()`` blocks until the dispatcher fulfills (or fails) the
    ticket and returns the rotated target — an asynchronously-dispatched
    JAX value; materialize with ``jax.block_until_ready`` if you need
    the wall-clock cost on your thread.
    """

    __slots__ = ("key", "seq", "A", "admit_t", "deadline_t",
                 "_event", "_done", "_value", "_error")

    def __init__(self, key: BucketKey, seq, A, admit_t: float,
                 deadline_t: Optional[float]):
        self.key = key
        self.seq = seq
        self.A = A
        self.admit_t = admit_t
        self.deadline_t = deadline_t
        # the Event is lazy: allocating one per admitted request costs
        # more than the rest of the admission path combined, and a
        # caller that polls done() / collects after close never waits.
        # result() materializes it on first use; the CPython-atomic
        # attribute stores below keep the handoff safe (see _fulfill).
        self._event: Optional[threading.Event] = None
        self._done = False
        self._value = None
        self._error: Optional[BaseException] = None

    def done(self) -> bool:
        return self._done

    def result(self, timeout: Optional[float] = None):
        """The rotated target (blocks until fulfilled).

        Raises :class:`DeadlineExceeded` if the request was shed,
        :class:`EngineClosed` if the engine stopped without draining,
        ``TimeoutError`` if ``timeout`` elapses first.
        """
        if not self._done:
            ev = self._event
            if ev is None:
                with _TICKET_EVENT_LOCK:  # one event even with racing waiters
                    ev = self._event
                    if ev is None:
                        ev = self._event = threading.Event()
            # re-check after publishing the event: a fulfill that raced
            # the store above either saw the event (and set it) or
            # finished first (then _done is already visible)
            if not self._done and not ev.wait(timeout):
                raise TimeoutError(
                    "streamed result not ready within timeout")
        if self._error is not None:
            raise self._error
        return self._value

    # -- dispatcher/scheduler side ----------------------------------------
    def _fulfill(self, value) -> None:
        self._value = value
        self.seq = self.A = None  # drop request payload references
        self._done = True
        ev = self._event  # read after _done is visible (GIL ordering)
        if ev is not None:
            ev.set()

    def _fail(self, error: BaseException) -> None:
        self._error = error
        self.seq = self.A = None
        self._done = True
        ev = self._event
        if ev is not None:
            ev.set()


# one closed batch on its way to the dispatcher
_Batch = Tuple[BucketKey, List[StreamTicket], str]


class StreamEngine:
    """Async continuous-batching engine over ``RotationService`` buckets.

    Args:
      service: the bucket/plan substrate to execute through.  ``None``
        builds a private ``RotationService(slots=slots, **service_kw)``.
        Whatever is passed must not be driven synchronously while the
        engine runs — the dispatcher thread owns its plan/stat state.
      slots: per-bucket batch capacity (ignored when ``service`` given).
      max_pending: bounded global budget of queued-but-undispatched
        requests; ``submit()`` applies ``backpressure`` once it is full.
      backpressure: ``"block"`` | ``"fail"`` | ``"shed"`` (see module
        docstring).
      age_factor: age-close target = ``age_factor`` × the bucket plan's
        §6-modeled batch seconds (a bucket whose batch costs t to run
        is worth holding open ~``age_factor``·t for better fill).
      min_age_s / max_age_s: clamp for the age target; ``min_age_s`` is
        also the cold-bucket target before the first plan resolution.
      max_burst: cap on consecutive batch closes one bucket gets per
        round-robin visit.
      start: spawn the scheduler/dispatcher threads immediately
        (``False`` lets tests exercise admission policies inertly).
      service_kw: forwarded to the private ``RotationService`` (e.g.
        ``store=False``, ``method=...``, ``autotune=True``, or
        ``mesh=``/``row_axes=`` for row-sharded bucket execution via
        :mod:`repro.dist`).
    """

    def __init__(self, service: Optional[RotationService] = None, *,
                 slots: int = 8, max_pending: int = 256,
                 backpressure: str = "block", age_factor: float = 8.0,
                 min_age_s: float = 0.002, max_age_s: float = 0.25,
                 max_burst: int = 4, start: bool = True, **service_kw):
        if backpressure not in ("block", "fail", "shed"):
            raise ValueError(f"unknown backpressure policy {backpressure!r}")
        if max_pending < 1:
            raise ValueError(f"max_pending must be >= 1, got {max_pending}")
        if service is not None and service_kw:
            raise ValueError("pass service_kw only without an explicit "
                             "service")
        self.service = service if service is not None \
            else RotationService(slots=slots, **service_kw)
        self.slots = self.service.slots
        self.max_pending = int(max_pending)
        self.backpressure = backpressure
        self.age_factor = float(age_factor)
        self.min_age_s = float(min_age_s)
        self.max_age_s = float(max_age_s)
        self.max_burst = max(1, int(max_burst))

        self._lock = threading.Lock()
        self._wake = threading.Condition(self._lock)   # scheduler wakeups
        self._space = threading.Condition(self._lock)  # budget waiters
        self._buckets: Dict[BucketKey, Deque[StreamTicket]] = {}
        self._ring: List[BucketKey] = []   # round-robin visit order
        self._ring_idx = 0
        self._bursts: Dict[BucketKey, int] = {}  # consecutive closes/visit
        self._pending = 0
        self._closing = False
        self._stopped = threading.Event()
        # depth-1 handoff: at most one closed batch waits while the
        # dispatcher executes the previous one — the double buffer
        self._handoff: "queue.Queue[Optional[_Batch]]" = queue.Queue(1)
        self.stats = {"submitted": 0, "completed": 0, "shed": 0,
                      "rejected": 0, "closes_size": 0, "closes_age": 0,
                      "closes_drain": 0}
        self._scheduler: Optional[threading.Thread] = None
        self._dispatcher: Optional[threading.Thread] = None
        if start:
            self.start()

    # ------------------------------------------------------------ admission
    def submit(self, seq, A, *, deadline_s: Optional[float] = None
               ) -> StreamTicket:
        """Admit one request; returns a :class:`StreamTicket`.

        ``deadline_s`` is a relative latency budget: under the
        ``"shed"`` policy a request whose deadline passes while still
        queued may be dropped (its ticket raises
        :class:`DeadlineExceeded`) to make room for new admissions.
        """
        if not hasattr(A, "ndim"):  # lists/tuples; arrays pass untouched
            import jax.numpy as jnp

            A = jnp.asarray(A)
        if A.ndim != 2:
            raise ValueError(f"targets must be 2D (m, n); got {A.shape}")
        key = self.service._bucket_key(seq, A)
        now = obs.timing.now()
        ticket = StreamTicket(key, seq, A, now,
                              None if deadline_s is None
                              else now + float(deadline_s))
        with self._lock:
            if self._closing:
                raise EngineClosed("submit() after close()")
            while self._pending >= self.max_pending:
                if self.backpressure == "shed":
                    self._shed_expired_locked()
                    if self._pending < self.max_pending:
                        break
                if self.backpressure in ("fail", "shed"):
                    self.stats["rejected"] += 1
                    obs.inc("serve.stream.rejected")
                    raise Backpressure(
                        f"{self._pending} pending >= budget "
                        f"{self.max_pending} (policy={self.backpressure})")
                obs.inc("serve.stream.block_waits")  # block: wait for room
                self._space.wait()
                if self._closing:
                    raise EngineClosed("engine closed while blocked on "
                                       "the pending budget")
            q = self._buckets.get(key)
            if q is None:
                q = self._buckets[key] = deque()
                self._ring.append(key)
            q.append(ticket)
            self._pending += 1
            self.stats["submitted"] += 1
            obs.inc("serve.stream.submitted")
            # wake the scheduler only on a state change it can act on —
            # the bucket crossing the size threshold, or its first
            # pending request (arms the age timer).  Notifying every
            # submit makes admission and the scheduler ping-pong the
            # lock, and that contention caps the sustainable admit rate
            # (the pending gauge moves to close/shed time for the same
            # reason).
            if len(q) >= self.slots or len(q) == 1:
                self._wake.notify()
        return ticket

    def _shed_expired_locked(self) -> int:
        """Drop queued requests whose deadline has passed; returns count."""
        now = obs.timing.now()
        shed = 0
        for q in self._buckets.values():
            kept = [t for t in q
                    if t.deadline_t is None or t.deadline_t > now]
            if len(kept) != len(q):
                for t in q:
                    if t.deadline_t is not None and t.deadline_t <= now:
                        t._fail(DeadlineExceeded(
                            f"deadline passed while queued "
                            f"(budget {t.deadline_t - t.admit_t:.4f}s)"))
                        shed += 1
                q.clear()
                q.extend(kept)
        if shed:
            self._pending -= shed
            self.stats["shed"] += shed
            obs.inc("serve.stream.shed", shed)
            obs.gauge("serve.stream.pending", self._pending)
            self._space.notify_all()
        return shed

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "StreamEngine":
        if self._scheduler is not None:
            return self
        self._scheduler = threading.Thread(
            target=self._scheduler_loop, name="repro-stream-scheduler",
            daemon=True)
        self._dispatcher = threading.Thread(
            target=self._dispatcher_loop, name="repro-stream-dispatcher",
            daemon=True)
        self._scheduler.start()
        self._dispatcher.start()
        return self

    def close(self, drain: bool = True) -> None:
        """Stop the engine.

        ``drain=True`` (graceful) flushes every queued request through
        the normal batch path before the threads exit; ``drain=False``
        fails still-queued tickets with :class:`EngineClosed`.
        Idempotent.
        """
        with self._lock:
            if self._closing and self._stopped.is_set():
                return
            self._closing = True
            if not drain:
                for q in self._buckets.values():
                    for t in q:
                        t._fail(EngineClosed("engine closed without drain"))
                        self._pending -= 1
                    q.clear()
            self._wake.notify_all()
            self._space.notify_all()
        if self._scheduler is None:
            # never started: nothing to join, but honour drain semantics
            self._drain_inline()
            self._stopped.set()
            return
        self._scheduler.join()
        self._dispatcher.join()
        self._stopped.set()

    def _drain_inline(self) -> None:
        """close(drain=True) on a never-started engine: flush in-thread."""
        while True:
            batch = self._close_one_locked_wrapper()
            if batch is None:
                return
            self._execute(batch)

    def _close_one_locked_wrapper(self) -> Optional[_Batch]:
        with self._lock:
            return self._close_next_locked(draining=True)

    def __enter__(self) -> "StreamEngine":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close(drain=True)

    # ------------------------------------------------------- close policy
    def _age_target(self, key: BucketKey) -> float:
        """Per-bucket hold-open budget: §6-modeled batch seconds scaled.

        Reads the frozen bucket plan's ``est_seconds`` through the
        service; before the first resolution the floor applies (close a
        cold bucket fast so the plan exists for every later decision).
        """
        est = self.service.bucket_plan_estimate(key)
        if est is None:
            return self.min_age_s
        return min(self.max_age_s, max(self.min_age_s,
                                       self.age_factor * est))

    def _ready_locked(self, now: float, draining: bool
                      ) -> Optional[Tuple[BucketKey, str]]:
        """First ready bucket in weighted-round-robin order, with why."""
        n = len(self._ring)
        for off in range(n):
            key = self._ring[(self._ring_idx + off) % n]
            q = self._buckets.get(key)
            if not q:
                continue
            if len(q) >= self.slots:
                return key, "size"
            if now - q[0].admit_t >= self._age_target(key):
                return key, "age"
            if draining:
                return key, "drain"
        return None

    def _next_wake_locked(self, now: float) -> Optional[float]:
        """Seconds until the earliest age-close fires (None: no pending)."""
        horizon = None
        for key, q in self._buckets.items():
            if not q:
                continue
            due = q[0].admit_t + self._age_target(key) - now
            if horizon is None or due < horizon:
                horizon = due
        return None if horizon is None else max(horizon, 0.0)

    def _close_next_locked(self, draining: bool = False
                           ) -> Optional[_Batch]:
        """Pop the next batch to dispatch, or None if nothing is ready."""
        if not self._ring:
            return None
        now = obs.timing.now()
        ready = self._ready_locked(now, draining)
        if ready is None:
            return None
        key, reason = ready
        q = self._buckets[key]
        tickets = [q.popleft() for _ in range(min(self.slots, len(q)))]
        self._pending -= len(tickets)
        # weighted round-robin: a still-hot bucket keeps the ring head
        # for up to max_burst consecutive closes, then yields
        idx = self._ring.index(key)
        weight = min(self.max_burst, int(math.ceil(len(q) / self.slots)))
        if weight < 1 or self._bursts.get(key, 0) + 1 >= self.max_burst:
            self._ring_idx = (idx + 1) % len(self._ring)
            self._bursts[key] = 0
        else:
            self._ring_idx = idx
            self._bursts[key] = self._bursts.get(key, 0) + 1
        self.stats[f"closes_{reason}"] += 1
        obs.inc(f"serve.stream.closes_{reason}")
        obs.gauge("serve.stream.pending", self._pending)
        self._space.notify_all()
        return key, tickets, reason

    # ------------------------------------------------------------- threads
    def _scheduler_loop(self) -> None:
        while True:
            with self._lock:
                while True:
                    now = obs.timing.now()
                    if self._closing:
                        batch = self._close_next_locked(draining=True)
                        break
                    batch = self._close_next_locked()
                    if batch is not None:
                        break
                    self._wake.wait(self._next_wake_locked(now))
                if batch is None and self._closing:
                    done = True
                else:
                    done = False
            if done:
                break
            if batch is not None:
                # wave-normalize outside the lock: submit stays cheap,
                # and only this thread mutates stats["padded_waves"]
                key, tickets, reason = batch
                for t in tickets:
                    t.seq = self.service._normalize(t.seq, key)
                # depth-1 queue: blocks only while a previous batch is
                # already assembled AND another is executing
                self._handoff.put(batch)
        self._handoff.put(None)  # dispatcher shutdown sentinel

    def _dispatcher_loop(self) -> None:
        while True:
            item = self._handoff.get()
            if item is None:
                return
            self._execute(item)

    def _execute(self, item: _Batch) -> None:
        key, tickets, reason = item
        with obs.span("stream.dispatch", m=key.m, n=key.n,
                      k_pad=key.k_pad) as sp:
            try:
                out, pad = self.service.execute_batch(
                    key, [t.seq for t in tickets],
                    [t.A for t in tickets])
            except BaseException as e:  # fail tickets, never hang callers
                for t in tickets:
                    t._fail(e)
                return
            sp.set(requests=len(tickets), pad_slots=pad, close=reason)
            # one host materialization for the whole batch: per-request
            # results are zero-copy row views, where slicing the device
            # array would pay one gather dispatch per slot.  This blocks
            # on the in-flight batch only — the admission path and the
            # scheduler's next-batch assembly keep running (the double
            # buffer), and tickets resolve to device-complete values.
            host = np.asarray(out)
            done_t = obs.timing.now()
            record = obs.enabled()
            for i, t in enumerate(tickets):  # per-request unpadding
                if record:
                    obs.observe("serve.request_latency_seconds",
                                done_t - t.admit_t)
                t._fulfill(host[i])
            self.stats["completed"] += len(tickets)
            obs.inc("serve.stream.completed", len(tickets))
