"""Batched rotation-application serving: plan once, apply many, at scale.

The paper's amortization argument — pack many rotations per memory pass
so the cost of touching ``A`` is paid once — extends across *requests*:
independent ``(sequence, target)`` problems of the same shape can share
one dispatch decision and one batched memory pass.  Ballard, Demmel &
Dumitriu make the system-scale version of this point for eigenproblems:
batching independent instances through one communication schedule is how
you approach the machine's bandwidth lower bound.

:class:`RotationService` is the serving-shaped realization (the async
continuous-batching engine in :mod:`repro.serve.stream` layers request
queues, deadlines, and double-buffered dispatch on top of the same
buckets):

* **shape-bucketed admission** — ``submit(seq, A)`` drops each request
  into a bucket keyed by ``(m, n, dtype, k_pad, signed)``.  Wave counts
  are :meth:`~repro.core.sequence.RotationSequence.pad_to`-normalized to
  the bucket's ``k_pad`` (next power of two — identity padding is an
  exact, bitwise no-op) so every drain presents one plan-cache-stable
  problem shape.
* **one frozen plan per bucket** — the first drain of a bucket resolves
  the registry exactly once (``seq.plan(like=..., batch=slots)``, so the
  cost model prices the *batched* problem: a batch-64 bucket can
  legitimately land on a different backend than a single request);
  every later drain rebinds the frozen
  :class:`~repro.core.sequence.SequencePlan` and calls the backend
  directly via :meth:`~repro.core.sequence.SequencePlan.apply_batched`.
* **slot padding + per-request unpadding** — partial drains are padded
  to the bucket's ``slots`` with identity requests (zero targets,
  identity waves) so the jitted batched computation sees one stable
  shape; results are sliced back out per ticket.  Queued sequences keep
  their sign structure *implicit* (no dense grid per request or pad
  slot); stacking broadcasts identity signs only for genuinely
  sign-carrying batches.
* **fused bucket execution** — when the bucket plan lands on a
  ``batch_via="fused"`` backend (the ``rotseq_batched`` kernel —
  ``method="auto"`` picks it on TPU, or pass
  ``method="rotseq_batched"``), the whole drain executes in **one**
  Pallas launch gridded over ``(batch, m-blocks)``, with per-wave
  ``valid_planes`` windows skipping the ``pad_to`` identity waves
  instead of multiplying them through; per-request vmap/loop execution
  stays as the fallback capability on every other backend.
* **serialized warm starts** — resolved bucket plans write through to a
  JSON store next to the registry's persisted plan cache
  (``~/.cache/repro/serve_plans.json``; same ``REPRO_PLAN_CACHE``
  override semantics, keyed by JAX version).  A warm service restores
  them via :meth:`~repro.core.sequence.SequencePlan.from_dict` and
  performs **zero** new registry resolutions for known buckets.

Bitwise contract: per-request and bucketed execution are bit-identical
for plain-rotation sequences on every rotation-family backend
(``unoptimized`` / ``wavefront`` / ``blocked`` / ``rotseq_batched``),
for per-entry-sign sequences on the sign-capable family (``blocked``
and the fused kernel — the backends signed dispatch can reach), and —
new with the bit-stable reflector normalization — for **all-reflector**
sequences across the two: every path evaluates the canonical
``core.rotations.plane_update`` order with runtime sign arrays, so the
sign-grid normalization a signed bucket performs matches the scalar
``reflect`` path a lone request takes, to the last bit.  Only the
``accumulated``/MXU family (which reassociates rotations into GEMMs)
agrees to dtype accuracy rather than bitwise.  The contract assumes
finite targets without ``-0.0`` entries: the fused kernel's
identity-plane skipping leaves NaN/inf/-0.0 values untouched where a
multiplied-through ``0*x`` would poison or sign-normalize them.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro import obs

__all__ = ["RotationService", "BucketKey", "serve_plan_store_path",
           "synthetic_stream"]

_STORE_FORMAT = 1

# canonical mixed-shape demo workload (>= 3 buckets by construction),
# shared by `repro.launch.serve --rotations` and benchmarks/bench_serve
# so the CI bucket-count invariants track one definition
DEMO_SHAPES = ((16, 32, 8), (32, 32, 8), (16, 64, 12))


def synthetic_stream(n_requests: int, *, shapes=DEMO_SHAPES, seed: int = 0):
    """Seeded mixed-shape ``(sequence, target)`` request stream."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.rotations import random_sequence

    rng = np.random.default_rng(seed)
    out = []
    for i in range(n_requests):
        m, n, k = shapes[i % len(shapes)]
        A = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
        out.append((random_sequence(jax.random.key(seed + i), n, k), A))
    return out


def serve_plan_store_path() -> Optional[str]:
    """Default on-disk store for serialized bucket plans.

    Lives next to the registry's persisted plan cache and follows the
    same ``REPRO_PLAN_CACHE`` override: when plan persistence is off,
    serving still works — it just re-plans each bucket once per process.
    """
    from repro.core import registry

    base = registry.plan_cache_path()
    if base is None:
        return None
    return os.path.join(os.path.dirname(base), "serve_plans.json")


def _next_pow2(x: int) -> int:
    return 1 << max(0, (max(1, x) - 1).bit_length())


# ``str(dtype)`` walks numpy's dtype-name machinery — measurable on the
# per-request admission path, so bucket keys use a memoized lookup
_DTYPE_NAMES: Dict = {}


def _dtype_name(dt) -> str:
    name = _DTYPE_NAMES.get(dt)
    if name is None:
        name = _DTYPE_NAMES[dt] = str(dt)
    return name


@dataclasses.dataclass(frozen=True)
class BucketKey:
    """Shape/dtype class of one admission bucket.

    Both the target dtype and the *wave* dtype participate: stacking
    float32 and float64 waves in one bucket would silently promote the
    whole batch and break the bitwise per-request contract.
    """
    m: int
    n: int
    dtype: str
    k_pad: int
    signed: bool
    wave_dtype: str

    def as_list(self) -> list:
        return [self.m, self.n, self.dtype, self.k_pad, self.signed,
                self.wave_dtype]

    @classmethod
    def from_list(cls, parts) -> "BucketKey":
        m, n, dtype, k_pad, signed, wave_dtype = parts
        return cls(int(m), int(n), str(dtype), int(k_pad), bool(signed),
                   str(wave_dtype))


@dataclasses.dataclass
class _Pending:
    ticket: int
    seq: "object"   # pad_to/sign-normalized RotationSequence
    A: "object"
    # admission timestamp (obs.timing.now) — populated only while obs
    # is enabled, feeding the admit→drain latency histogram; None keeps
    # the disabled path allocation-identical
    admit_t: Optional[float] = None


class RotationService:
    """Shape-bucketed, batched rotation-application service.

    Args:
      slots: per-bucket batch capacity.  Admission auto-drains a bucket
        the moment it fills (fixed-slot semantics); partial
        drains are padded to ``slots`` with identity requests so the
        batched computation keeps one stable shape.
      method: dispatch method for bucket plans (``"auto"`` prices the
        *batched* problem through the registry cost model).
      autotune: measure candidate plans when first resolving a bucket.
      pad_waves: normalize each request's wave count to the bucket's
        next-power-of-two ``k_pad`` (exact identity padding).  With
        ``False``, the raw wave count becomes part of the bucket key.
      min_k_pad: floor for ``k_pad`` (avoids one bucket per tiny k).
      store: path for the serialized-plan store; ``None`` uses
        :func:`serve_plan_store_path` (which respects
        ``REPRO_PLAN_CACHE=off``), ``False`` disables persistence.
      warm_start: load serialized plans from ``store`` at construction.
      mesh: optional ``jax.sharding.Mesh`` — bucket plans resolve
        through :func:`repro.dist.plan_sharded` (row-sharded batched
        drains; ``method="auto"`` arbitrates sharded vs replicated via
        the comm-extended cost model).  Sharded bucket plans are
        process-local: the serialized warm store is bypassed, since a
        mesh cannot round-trip through JSON.
      row_axes: mesh axes bucket targets' rows shard over (with
        ``mesh``; default ``("data",)``).
      plan_kw: extra kwargs forwarded to ``RotationSequence.plan`` when
        a bucket is first resolved (e.g. explicit ``n_b``/``k_b``).
    """

    def __init__(self, *, slots: int = 8, method: str = "auto",
                 autotune: bool = False, pad_waves: bool = True,
                 min_k_pad: int = 4, store=None, warm_start: bool = True,
                 mesh=None, row_axes=("data",), **plan_kw):
        if slots < 1:
            raise ValueError(f"slots must be >= 1, got {slots}")
        self.slots = int(slots)
        self.method = method
        self.autotune = autotune
        self.pad_waves = bool(pad_waves)
        self.min_k_pad = int(min_k_pad)
        self.mesh = mesh
        self.row_axes = tuple(row_axes)
        self.plan_kw = dict(plan_kw)
        if store is False:
            self._store_path = None
        else:
            self._store_path = store if store is not None \
                else serve_plan_store_path()
        self._queues: Dict[BucketKey, List[_Pending]] = {}
        self._plans: Dict[BucketKey, "object"] = {}   # frozen SequencePlan
        self._warm: Dict[BucketKey, dict] = {}        # serialized, unbound
        self._results: Dict[int, "object"] = {}
        self._next_ticket = 0
        # "requests" counts *real* admissions only; "slots_executed" is
        # total batch slots run (real + identity pad) — keeping the two
        # separate is what stops pad slots inflating req/s accounting
        self.stats = {"requests": 0, "batches": 0, "plans_resolved": 0,
                      "warm_plans": 0, "padded_slots": 0, "padded_waves": 0,
                      "slots_executed": 0}
        if warm_start:
            self._load_store()

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        pending = sum(len(q) for q in self._queues.values())
        return (f"RotationService(slots={self.slots}, "
                f"buckets={len(self._queues)}, pending={pending}, "
                f"plans={len(self._plans)})")

    # -- admission ---------------------------------------------------------
    def _bucket_key(self, seq, A) -> BucketKey:
        m, n = A.shape
        if seq.n != n:
            raise ValueError(
                f"sequence on {seq.n} columns cannot serve a target with "
                f"{n} columns")
        k_pad = max(self.min_k_pad, _next_pow2(seq.k)) if self.pad_waves \
            else seq.k
        signed = seq.sign is not None or bool(seq.reflect)
        return BucketKey(m=int(m), n=int(n), dtype=_dtype_name(A.dtype),
                         k_pad=int(k_pad), signed=signed,
                         wave_dtype=_dtype_name(seq.dtype))

    def _normalize(self, seq, key: BucketKey):
        """pad_to the bucket wave count; sign structure stays implicit.

        Queued sequences keep their own sign representation — a plain
        (unsigned) sequence padded into a signed bucket is *not*
        materialized into a dense sign grid at admission
        (``pad_to`` keeps identity padding implicit; only genuine
        reflector sequences carry grids).  Batch stacking broadcasts
        implicit-identity signs lazily at drain time.
        """
        if seq.k < key.k_pad:
            self.stats["padded_waves"] += key.k_pad - seq.k
            seq = self._pad_concrete(seq, key.k_pad)
        return seq

    @staticmethod
    def _pad_concrete(seq, k_target: int):
        """Host-side identity padding for concrete unsigned sequences.

        ``pad_to`` issues traced concatenations per request — at serving
        volume (every admitted request of a padded bucket) that per-op
        dispatch dominates the batch period, so plain concrete
        sequences pad in numpy instead: identity waves are exact
        constants (``cos=1.0``, ``sin=0.0``), so the padded bytes are
        identical to ``pad_to``'s and the streamed-vs-sync bitwise
        contract is untouched.  Sign-carrying / reflector / traced
        sequences keep the canonical ``pad_to`` path (reflector padding
        must materialize a sign grid — see ``pad_to``).
        """
        from repro.core.sequence import RotationSequence

        from repro.compat import is_tracer

        if (seq.sign is not None or seq.reflect
                or is_tracer(seq.cos) or is_tracer(seq.sin)):
            return seq.pad_to(k_target)
        pad = k_target - seq.k
        planes = seq.cos.shape[0]
        live = seq.k_live if seq.k_live is not None else planes * seq.k
        cos = np.asarray(seq.cos)
        sin = np.asarray(seq.sin)
        cos = np.concatenate(
            [cos, np.ones((planes, pad), cos.dtype)], axis=1)
        sin = np.concatenate(
            [sin, np.zeros((planes, pad), sin.dtype)], axis=1)
        return RotationSequence(cos, sin, None, False, k_live=live)

    def submit(self, seq, A) -> int:
        """Admit one request; returns a ticket for :meth:`result`.

        A full bucket drains immediately (slot semantics); otherwise the
        request waits for :meth:`drain` / :meth:`result`.
        """
        import jax.numpy as jnp

        A = jnp.asarray(A)
        if A.ndim != 2:
            raise ValueError(f"targets must be 2D (m, n); got {A.shape}")
        with obs.span("admit"):
            key = self._bucket_key(seq, A)
            ticket = self._next_ticket
            self._next_ticket += 1
            self.stats["requests"] += 1
            obs.inc("serve.requests")
            admit_t = obs.timing.now() if obs.enabled() else None
            queue = self._queues.setdefault(key, [])
            queue.append(_Pending(ticket, self._normalize(seq, key), A,
                                  admit_t))
            obs.gauge("serve.queue_depth",
                      sum(len(q) for q in self._queues.values()))
        if len(queue) >= self.slots:
            self._drain_bucket(key)
        return ticket

    def apply_many(self, pairs) -> list:
        """Convenience: submit ``(seq, A)`` pairs, drain, return results
        in submission order."""
        tickets = [self.submit(seq, A) for seq, A in pairs]
        self.drain()
        return [self.result(t) for t in tickets]

    # -- execution ---------------------------------------------------------
    def drain(self) -> None:
        """Execute every non-empty bucket (partial batches padded)."""
        for key in list(self._queues):
            if self._queues[key]:
                self._drain_bucket(key)

    def result(self, ticket: int):
        """Return (and forget) one request's rotated target, draining
        its bucket if still pending."""
        if ticket not in self._results:
            self.drain()
        if ticket not in self._results:
            raise KeyError(f"unknown or already-collected ticket {ticket}")
        return self._results.pop(ticket)

    def _bucket_plan(self, key: BucketKey, rep_seq, like):
        """The bucket's frozen plan: warm store first, registry once."""
        from repro.core.sequence import SequencePlan

        plan = self._plans.get(key)
        if plan is not None:
            return plan
        if self.mesh is not None:
            # sharded bucket plans resolve per process (no warm store:
            # a live mesh has no JSON form) — still exactly once per
            # bucket, rebound on every later drain like the rest
            from repro import dist

            plan = dist.plan_sharded(rep_seq, like=like, mesh=self.mesh,
                                     row_axes=self.row_axes,
                                     method=self.method,
                                     autotune=self.autotune,
                                     shared_sequence=False,
                                     **self.plan_kw)
            self.stats["plans_resolved"] += 1
            obs.inc("serve.plans_resolved")
            self._plans[key] = plan
            return plan
        warm = self._warm.get(key)
        if warm is not None:
            try:
                plan = SequencePlan.from_dict(warm, rep_seq)
                self.stats["warm_plans"] += 1
            except ValueError:
                plan = None  # stale entry: fall through to the registry
        if plan is not None:
            obs.inc("serve.warm_plans")
        else:
            # shared_sequence=False: a bucket batch carries one distinct
            # sequence per slot, so the registry prices per-sequence
            # setup × slots — the correction that lets method="auto"
            # avoid setup-heavy backends on serving traffic
            plan = rep_seq.plan(like=like, method=self.method,
                                autotune=self.autotune, batch=self.slots,
                                shared_sequence=False, **self.plan_kw)
            self.stats["plans_resolved"] += 1
            obs.inc("serve.plans_resolved")
            self._warm[key] = plan.to_dict()
            self._save_store()
        self._plans[key] = plan
        return plan

    def assemble_batch(self, key: BucketKey, seqs: list, targets: list):
        """Stack one bucket batch into the plan-cache-stable shape.

        Slot-pads ``seqs``/``targets`` (already ``_normalize``-d to the
        bucket's ``k_pad``) to ``self.slots`` with identity requests
        (zero targets, identity waves — implicit-identity signs even in
        signed buckets: the stack step broadcasts them, no dense grid
        per pad slot) and picks the planning representative.  Returns
        ``(seqs, A, rep, pad)`` where ``A`` is the ``(slots, m, n)``
        target stack.  Shared verbatim by the synchronous drain and the
        :mod:`repro.serve.stream` dispatcher — running one code path is
        what makes streamed results bit-equal to synchronous drains.
        """
        import jax.numpy as jnp

        from repro.core.sequence import RotationSequence

        if not seqs or len(seqs) > self.slots:
            raise ValueError(
                f"batch of {len(seqs)} requests for slots={self.slots}")
        pad = self.slots - len(seqs)
        if pad:  # identity requests keep the jitted shape slot-stable
            self.stats["padded_slots"] += pad
            ident = RotationSequence.identity(key.n, key.k_pad,
                                              dtype=seqs[0].dtype)
            zero = jnp.zeros((key.m, key.n), targets[0].dtype)
            seqs = seqs + [ident] * pad
            targets = targets + [zero] * pad
        # concrete targets stack host-side (one memcpy; same bytes) —
        # a traced jnp.stack over ``slots`` operands costs milliseconds
        # of pure dispatch at serving batch sizes
        from repro.compat import is_tracer
        if any(is_tracer(t) for t in targets):
            A = jnp.stack(targets)
        else:
            A = np.stack([np.asarray(t) for t in targets])
        # the planning representative carries the bucket's signature: a
        # signed bucket plans (and warm-binds) on a sign-carrying
        # sequence even when the first queued request is implicit
        rep = seqs[0].with_signs() if key.signed else seqs[0]
        return seqs, A, rep, pad

    def execute_batch(self, key: BucketKey, seqs: list, targets: list):
        """Plan (exactly once per bucket) and run one assembled batch.

        Returns ``(out, pad)`` — ``out`` is the ``(slots, m, n)`` result
        stack (slice ``out[i]`` per request; pad slots are garbage) and
        ``pad`` the identity-slot count.  Does *not* block on the device
        result: ``out`` is an asynchronously-dispatched value, which is
        what lets the stream dispatcher overlap the next batch's
        assembly with this batch's device execution.
        """
        n_live = len(seqs)
        seqs, A, rep, pad = self.assemble_batch(key, seqs, targets)
        plan = self._bucket_plan(key, rep, A)
        out = plan.apply_batched(A, sequences=seqs)
        self.stats["batches"] += 1
        self.stats["slots_executed"] += self.slots
        if obs.enabled():
            obs.inc("serve.batches")
            obs.inc("serve.slots_executed", self.slots)
            obs.inc("serve.pad_slots", pad)
            obs.gauge("serve.bucket_fill_ratio", n_live / self.slots)
            obs.gauge("serve.pad_slot_fraction",
                      self.stats["padded_slots"]
                      / max(1, self.stats["slots_executed"]))
        return out, pad

    def bucket_plan_estimate(self, key: BucketKey) -> Optional[float]:
        """§6-modeled seconds for one batched drain of ``key``'s bucket.

        ``None`` until the bucket has been planned (the stream engine's
        age-based close policy falls back to its floor target then).
        """
        plan = self._plans.get(key)
        if plan is None or plan.plan is None:
            return None
        est = float(plan.plan.est_seconds)
        return est if est > 0 else None

    def _drain_bucket(self, key: BucketKey) -> None:
        queue = self._queues.get(key, [])
        if not queue:
            return
        with obs.span("drain", m=key.m, n=key.n, k_pad=key.k_pad) as sp:
            batch, self._queues[key] = (queue[: self.slots],
                                        queue[self.slots:])
            out, pad = self.execute_batch(key, [p.seq for p in batch],
                                          [p.A for p in batch])
            sp.set(requests=len(batch), pad_slots=pad)
            if obs.enabled():
                done_t = obs.timing.now()
                for p in batch:
                    if p.admit_t is not None:
                        obs.observe("serve.request_latency_seconds",
                                    done_t - p.admit_t)
            for i, p in enumerate(batch):  # per-request unpadding
                self._results[p.ticket] = out[i]
            obs.gauge("serve.queue_depth",
                      sum(len(q) for q in self._queues.values()))
        if self._queues[key]:
            self._drain_bucket(key)

    # -- serialized plan store ---------------------------------------------
    # (shares the registry cache's invalidation + atomic-write plumbing:
    # _read_versioned_json / _atomic_write_json live in core.registry)

    def _load_store(self) -> int:
        """Merge serialized bucket plans from disk; returns count loaded.

        Mirrors the registry's persisted-cache invalidation: a missing/
        corrupt file, a different format, or a different JAX version is
        ignored wholesale (individual entries are additionally validated
        by ``SequencePlan.from_dict`` when first bound).
        """
        from repro.core import registry

        path = self._store_path
        if path is None:
            return 0
        payload = registry._read_versioned_json(path, _STORE_FORMAT)
        if payload is None:
            return 0
        loaded = 0
        for entry in payload.get("plans", []):
            try:
                key = BucketKey.from_list(entry["bucket"])
                plan_dict = dict(entry["plan"])
            except (KeyError, TypeError, ValueError):
                continue
            self._warm.setdefault(key, plan_dict)
            loaded += 1
        return loaded

    def _save_store(self) -> Optional[str]:
        """Atomically write-through all known bucket plans (best-effort
        read-merge-replace, same courtesy as ``save_plan_cache``)."""
        from repro.core import registry

        path = self._store_path
        if path is None:
            return None
        merged: Dict[Tuple, dict] = {}
        on_disk = registry._read_versioned_json(path, _STORE_FORMAT)
        if on_disk is not None:
            for entry in on_disk.get("plans", []):
                try:
                    merged[tuple(entry["bucket"])] = entry
                except (KeyError, TypeError):
                    continue
        for key, plan_dict in self._warm.items():
            merged[tuple(key.as_list())] = {"bucket": key.as_list(),
                                            "plan": plan_dict}
        if not merged:
            return None
        payload = {"format": _STORE_FORMAT,
                   "jax": registry._jax_version_str(),
                   "plans": list(merged.values())}
        return registry._atomic_write_json(path, payload,
                                           prefix=".serve_plans.")
