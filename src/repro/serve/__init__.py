from .engine import ServeEngine
from .rotations import BucketKey, RotationService, serve_plan_store_path

__all__ = ["ServeEngine", "RotationService", "BucketKey",
           "serve_plan_store_path"]
