from .lm import ServeEngine
from .rotations import BucketKey, RotationService, serve_plan_store_path
from .stream import (Backpressure, DeadlineExceeded, EngineClosed,
                     StreamEngine, StreamTicket)

__all__ = ["RotationService", "BucketKey", "serve_plan_store_path",
           "StreamEngine", "StreamTicket", "Backpressure",
           "DeadlineExceeded", "EngineClosed", "ServeEngine"]
