"""Batched LM decoding: fixed-slot greedy generation.

(Relocated from ``repro.serve.engine`` — the ``serve.engine`` seed was
rewritten as the rotation streaming engine, :mod:`repro.serve.stream`;
this module keeps the unrelated token-decode workload.)

Requests (prompt token lists) are admitted into a fixed-size batch of
decode slots; each slot tracks its own cache index via per-slot masking.
Prefill is teacher-forced through ``forward`` (cheap on CPU smoke scale);
decode steps are jitted one-token steps over the whole batch.  Greedy
sampling by default.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["ServeEngine"]


@dataclass
class _Slot:
    tokens: List[int]
    out: List[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, model, cfg, params, *, batch: int, max_len: int,
                 eos: Optional[int] = None):
        self.model = model
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.max_len = max_len
        self.eos = eos
        self._step = jax.jit(model.decode_step)

    def generate(self, prompts: List[List[int]], max_new: int = 16):
        """Greedy-decode a batch of prompts (padded to the slot batch)."""
        assert len(prompts) <= self.batch
        slots = [_Slot(list(p)) for p in prompts]
        while len(slots) < self.batch:
            slots.append(_Slot([0], done=True))

        cache = self.model.init_cache(self.batch, self.max_len,
                                      dtype=jnp.float32)
        max_prompt = max(len(s.tokens) for s in slots)
        # teacher-forced prefill through the decode path (slot-uniform)
        last = np.zeros((self.batch, 1), np.int32)
        for t in range(max_prompt + max_new):
            for i, s in enumerate(slots):
                if t < len(s.tokens):
                    last[i, 0] = s.tokens[t]
            logits, cache = self._step(self.params, cache,
                                       jnp.asarray(last))
            nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
            for i, s in enumerate(slots):
                if s.done:
                    continue
                if t >= len(s.tokens) - 1:
                    tok = int(nxt[i])
                    s.out.append(tok)
                    last[i, 0] = tok
                    if (self.eos is not None and tok == self.eos) \
                            or len(s.out) >= max_new:
                        s.done = True
            if all(s.done for s in slots):
                break
        return [s.out for s in slots[: len(prompts)]]
