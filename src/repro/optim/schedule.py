"""LR schedules."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["warmup_cosine", "constant"]


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def warmup_cosine(peak: float, warmup: int, total: int,
                  floor_frac: float = 0.1):
    def f(step):
        s = step.astype(jnp.float32)
        warm = peak * s / max(warmup, 1)
        t = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak * (floor_frac + (1 - floor_frac)
                      * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(s < warmup, warm, cos)
    return f
