from .adamw import AdamW, Quantized, dequantize_q8, quantize_q8
from .schedule import constant, warmup_cosine
from .soap_givens import SoapGivens

__all__ = ["AdamW", "Quantized", "dequantize_q8", "quantize_q8",
           "constant", "warmup_cosine", "SoapGivens"]
