"""AdamW with optional 8-bit state quantization and global-norm clipping.

Functional optimizer interface (no optax dependency):
  ``opt.init(params) -> state``;
  ``opt.update(grads, state, params) -> (new_params, new_state)``.

8-bit mode stores ``m``/``v`` as int8 with per-block (256) fp32 scales —
the distributed-optimization trick that brings the 1T-param kimi-k2
optimizer state from 8 to ~2.06 bytes/param so it fits 16 GB/chip at 512
chips (see DESIGN.md SS6).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = ["AdamW", "Quantized", "quantize_q8", "dequantize_q8"]

_BLOCK = 256


class Quantized(NamedTuple):
    q: jax.Array       # int8 payload, original shape
    scale: jax.Array   # fp32 per-block scales, shape (*lead, nblocks)


def quantize_q8(x) -> Quantized:
    """Blockwise int8 along the LAST axis only: leading axes keep their
    shape — and hence their sharding.  (A flatten-then-block formulation
    would force GSPMD to replicate the full fp32 tensor: 1.6 TB/device on
    llama3-405b.)"""
    lead, last = x.shape[:-1], x.shape[-1] if x.ndim else 1
    xr = x.reshape(lead + (last,)) if x.ndim else x.reshape(1)
    pad = (-last) % _BLOCK
    xb = jnp.pad(xr, [(0, 0)] * len(lead) + [(0, pad)])
    xb = xb.reshape(lead + (-1, _BLOCK))
    scale = jnp.maximum(jnp.max(jnp.abs(xb), axis=-1) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(xb / scale[..., None]), -127, 127)
    q = q.astype(jnp.int8).reshape(lead + (last + pad,))[..., :last]
    return Quantized(q.reshape(x.shape), scale.astype(jnp.float32))


def dequantize_q8(qv: Quantized, shape):
    lead, last = shape[:-1], shape[-1] if len(shape) else 1
    pad = (-last) % _BLOCK
    xb = jnp.pad(qv.q.reshape(lead + (last,)).astype(jnp.float32),
                 [(0, 0)] * len(lead) + [(0, pad)])
    xb = xb.reshape(lead + (-1, _BLOCK)) * qv.scale[..., None]
    return xb.reshape(lead + (last + pad,))[..., :last].reshape(shape)


@dataclass(frozen=True)
class AdamW:
    lr: Callable | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    quantized: bool = False      # int8 m/v states

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else self.lr

    def init(self, params):
        def zeros_like_state(p):
            z = jnp.zeros(p.shape, jnp.float32)
            return quantize_q8(z) if self.quantized else z

        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(zeros_like_state, params),
            "v": jax.tree.map(zeros_like_state, params),
        }

    def update(self, grads, state, params, *, grad_scale: float = 1.0):
        step = state["step"] + 1
        if self.clip_norm:
            gnorm = grad_scale * jnp.sqrt(sum(
                jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(grads)))
            scale = grad_scale * jnp.minimum(
                1.0, self.clip_norm / (gnorm + 1e-9))
        else:
            gnorm = jnp.zeros(())
            scale = grad_scale
        lr = self._lr(step)
        b1c = 1 - self.b1 ** step.astype(jnp.float32)
        b2c = 1 - self.b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32) * scale
            if self.quantized:
                m_f = dequantize_q8(m, g.shape)
                v_f = dequantize_q8(v, g.shape)
            else:
                m_f, v_f = m, v
            m_f = self.b1 * m_f + (1 - self.b1) * g
            v_f = self.b2 * v_f + (1 - self.b2) * jnp.square(g)
            u = (m_f / b1c) / (jnp.sqrt(v_f / b2c) + self.eps)
            if self.quantized:
                # quantization can zero tiny v blocks -> unbounded u;
                # Adafactor-style RMS update clipping restores stability
                rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
                u = u / jnp.maximum(1.0, rms)
            u = u + self.weight_decay * p.astype(jnp.float32)
            p_new = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
            if self.quantized:
                return p_new, quantize_q8(m_f), quantize_q8(v_f)
            return p_new, m_f, v_f

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        is_q = lambda t: isinstance(t, Quantized)
        flat_m = jax.tree.flatten(state["m"], is_leaf=is_q)[0]
        flat_v = jax.tree.flatten(state["v"], is_leaf=is_q)[0]
        def upd_leaf(g, m, v, p):
            # layer-stacked q8 leaves: scan the update over the stack axis
            # so only one layer's dequant/update/requant temporaries are
            # live at a time (the whole-leaf chain keeps ~10 fp32 copies
            # of a 1.6 GiB buffer alive on llama3-405b).  Blockwise-last-
            # axis quantization commutes with leading-axis slicing, so the
            # scanned result is byte-identical to the whole-leaf update.
            if (self.quantized and p.ndim >= 3 and p.shape[0] > 1
                    and p.size >= 2 ** 24):
                def body(_, xs):
                    return None, upd(*xs)

                _, res = jax.lax.scan(body, None, (g, m, v, p))
                return res
            return upd(g, m, v, p)

        out = [upd_leaf(g, m, v, p)
               for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        new_state = {"step": step, "m": new_m, "v": new_v}
        return new_p, new_state, {"grad_norm": gnorm}
