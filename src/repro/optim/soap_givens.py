"""SOAP-Givens: Shampoo/SOAP-style preconditioning whose eigenbases are
maintained by *rotation-sequence eigensolvers*.

For each 2D parameter ``W`` (d_in, d_out) we track Kronecker covariance
factors ``L = E[G G^T]`` and ``R = E[G^T G]`` (dims capped at
``max_dim``).  Every ``update_freq`` steps the eigenbases of ``L`` and
``R`` are refreshed by a solver that *records* its pivots as a
first-class ``RotationSequence`` and applies them with the paper's
optimized kernels through ``seq.plan`` (``method="auto"`` cost-model
dispatch):

* ``solver="jacobi"`` (default) — round-robin Jacobi (``core.jacobi``),
  jit-friendly (runs inside ``lax.cond``).
* ``solver="qr"`` — tridiagonal Wilkinson-shift QR
  (``repro.eig.eigh_givens``), fewer recorded waves per refresh for
  large dims; host-driven, so it requires *eager* optimizer updates.

Between refreshes, gradients are rotated into the eigenbasis, Adam runs
there, and updates rotate back:

    G~ = Q_L^T G Q_R ;  Adam(G~) ;  U = Q_L U~ Q_R^T

This makes ``rot_sequence`` application a *training-time* hot spot for
every architecture, including attention-free ones (the paper technique's
arch-independent integration point; DESIGN.md SS3).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.jacobi import jacobi_apply_basis, jacobi_eigh

__all__ = ["SoapGivens"]


def _eligible(p) -> bool:
    return p.ndim == 2 and min(p.shape) >= 4


@dataclass(frozen=True)
class SoapGivens:
    lr: Callable | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    shampoo_beta: float = 0.95
    update_freq: int = 10          # basis refresh period
    jacobi_cycles: int = 4
    max_dim: int = 512             # cap covariance side (block to identity)
    solver: str = "jacobi"         # "jacobi" | "qr" (qr: eager-only)
    apply_method: str = "auto"     # registry dispatch for basis refresh

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else self.lr

    def _qr_refresh(self, refresh, L, R, st):
        """Eager tridiagonal-QR eigenbasis refresh (``solver="qr"``).

        The QR solver generates rotations host-side (data-dependent
        bulge chasing), so the refresh predicate must be concrete —
        i.e. the optimizer update must run outside ``jit``.
        """
        from repro.eig import eigh_givens

        try:
            do = bool(refresh)
        except jax.errors.TracerBoolConversionError as exc:
            raise RuntimeError(
                "SoapGivens(solver='qr') generates rotations host-side "
                "and cannot run under jit; use solver='jacobi' inside "
                "jitted train steps or call update() eagerly"
            ) from exc
        if not do:
            return st["QL"], st["QR"]
        _, QL = eigh_givens(L, method="qr",
                            apply_method=self.apply_method)
        _, QR = eigh_givens(R, method="qr",
                            apply_method=self.apply_method)
        return QL, QR

    def init(self, params):
        def one(p):
            st = {
                "m": jnp.zeros(p.shape, jnp.float32),
                "v": jnp.zeros(p.shape, jnp.float32),
            }
            if _eligible(p) and max(p.shape) <= self.max_dim:
                st["L"] = jnp.eye(p.shape[0], dtype=jnp.float32) * 1e-6
                st["R"] = jnp.eye(p.shape[1], dtype=jnp.float32) * 1e-6
                st["QL"] = jnp.eye(p.shape[0], dtype=jnp.float32)
                st["QR"] = jnp.eye(p.shape[1], dtype=jnp.float32)
            return st

        return {
            "step": jnp.zeros((), jnp.int32),
            "per": jax.tree.map(one, params,
                                is_leaf=lambda x: hasattr(x, "ndim")),
        }

    def update(self, grads, state, params, *, grad_scale: float = 1.0):
        step = state["step"] + 1
        lr = self._lr(step)
        b1c = 1 - self.b1 ** step.astype(jnp.float32)
        b2c = 1 - self.b2 ** step.astype(jnp.float32)
        refresh = (step % self.update_freq) == 0

        def upd(g, st, p):
            g = g.astype(jnp.float32) * grad_scale
            precond = "L" in st
            if precond:
                L = self.shampoo_beta * st["L"] \
                    + (1 - self.shampoo_beta) * (g @ g.T)
                R = self.shampoo_beta * st["R"] \
                    + (1 - self.shampoo_beta) * (g.T @ g)

                def do_refresh(_):
                    # Jacobi on the covariances; the recorded pivot
                    # RotationSequence is applied to the identity basis
                    # via seq.plan dispatch inside jacobi_apply_basis
                    resL = jacobi_eigh(L, cycles=self.jacobi_cycles)
                    resR = jacobi_eigh(R, cycles=self.jacobi_cycles)
                    QL = jacobi_apply_basis(resL, method=self.apply_method)
                    QR = jacobi_apply_basis(resR, method=self.apply_method)
                    return QL, QR

                if self.solver == "qr":
                    QL, QR = self._qr_refresh(refresh, L, R, st)
                else:
                    QL, QR = jax.lax.cond(
                        refresh, do_refresh,
                        lambda _: (st["QL"], st["QR"]), None)
                g_rot = QL.T @ g @ QR
            else:
                QL = QR = None
                L = R = None
                g_rot = g

            m = self.b1 * st["m"] + (1 - self.b1) * g_rot
            v = self.b2 * st["v"] + (1 - self.b2) * jnp.square(g_rot)
            u = (m / b1c) / (jnp.sqrt(v / b2c) + self.eps)
            if precond:
                u = QL @ u @ QR.T
            u = u + self.weight_decay * p.astype(jnp.float32)
            p_new = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
            new_st = {"m": m, "v": v}
            if precond:
                new_st.update({"L": L, "R": R, "QL": QL, "QR": QR})
            return p_new, new_st

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_s = treedef.flatten_up_to(state["per"])
        out = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_per = treedef.unflatten([o[1] for o in out])
        return new_p, {"step": step, "per": new_per}, {}
