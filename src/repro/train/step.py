"""Train / serve step factories (the functions the launcher jits).

``make_train_step`` builds ``(params, opt_state, batch) -> (params,
opt_state, metrics)`` for any zoo model; ``make_serve_step`` builds the
one-token decode step ``(params, cache, tokens) -> (logits, cache)``.
Both are pure and pjit-able; sharding comes from in/out shardings plus
the logical-axis annotations inside the models.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from .losses import softmax_cross_entropy

__all__ = ["make_train_step", "make_eval_fn", "make_serve_step",
           "make_prefill_fn"]


def _loss_fn(model, cfg, params, batch, *, remat=True):
    # cast fp32 master params to the compute dtype ONCE, at the top of the
    # differentiated function: the backward of this single cast converts
    # each weight gradient fp32 only AFTER it has been reduced/sharded.
    # Casting at every use site instead makes XLA materialize *unsharded
    # fp32 partial* weight gradients (3.25-7.8 GiB apiece on llama3-405b).
    cdt = jnp.dtype(cfg.dtype)
    params = jax.tree.map(
        lambda w: w.astype(cdt) if w.dtype == jnp.float32 else w, params)
    if cfg.is_encdec:
        logits = model.forward(params, batch["frames"],
                               batch["dec_tokens"], remat=remat)
        labels = batch["labels"]
    else:
        logits = model.forward(params, batch["tokens"], remat=remat)
        labels = batch["labels"]
    loss, z_loss = softmax_cross_entropy(logits, labels)
    return loss + 1e-4 * z_loss, {"loss": loss, "z_loss": z_loss}


def make_train_step(model, cfg, optimizer, *, remat: bool = True,
                    grad_accum: int = 1, grad_shardings=None):
    """Returns the pure train-step function (optionally micro-batched).

    ``grad_shardings``: optional pytree of ``NamedSharding`` matching the
    params — gradients (and the grad-accumulation carry) are constrained
    to it.  Without the constraint GSPMD is free to keep the accumulator
    *replicated* over the model axis, which blows per-device memory by
    the TP width (observed on llama3-405b: 7.8 GiB unsharded embed grad).
    """

    def constrain(tree):
        if grad_shardings is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree,
                            grad_shardings)

    def train_step(params, opt_state, batch):
        def forward(p, b):
            # re-assert param shardings inside the differentiated
            # function: with_sharding_constraint transposes to itself, so
            # each parameter's GRADIENT is forced to the same sharding —
            # without this GSPMD materializes unsharded (TP-replicated)
            # grads inside the microbatch loop (observed: 7.8 GiB embed
            # grad on llama3-405b)
            return _loss_fn(model, cfg, constrain(p), b, remat=remat)

        if grad_accum == 1:
            (_, metrics), grads = jax.value_and_grad(
                forward, has_aux=True)(params, batch)
            grads = constrain(grads)
        else:
            def micro(carry, mb):
                g_acc, m_acc = carry
                (_, m), g = jax.value_and_grad(
                    forward, has_aux=True)(params, mb)
                g_acc = constrain(jax.tree.map(jnp.add, g_acc, g))
                m_acc = jax.tree.map(jnp.add, m_acc, m)
                return (g_acc, m_acc), None

            mb = jax.tree.map(
                lambda x: x.reshape((grad_accum, -1) + x.shape[1:]), batch)
            zeros_g = constrain(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            zeros_m = {"loss": jnp.zeros(()), "z_loss": jnp.zeros(())}
            (grads, metrics), _ = jax.lax.scan(
                micro, (zeros_g, zeros_m), mb)
            # note: the 1/grad_accum factor is folded into the optimizer's
            # clip/scale pass (avoids a full f32 copy of the grad tree)
            metrics = jax.tree.map(lambda m: m / grad_accum, metrics)

        params, opt_state, opt_metrics = optimizer.update(
            grads, opt_state, params, grad_scale=1.0 / grad_accum)
        metrics = {**metrics, **opt_metrics}
        return params, opt_state, metrics

    return train_step


def make_eval_fn(model, cfg):
    def eval_fn(params, batch):
        _, metrics = _loss_fn(model, cfg, params, batch, remat=False)
        return metrics

    return eval_fn


def make_serve_step(model, cfg):
    """One-token decode step: (params, cache, tokens (B,1)) -> (logits, cache)."""

    def serve_step(params, cache, tokens):
        return model.decode_step(params, cache, tokens)

    return serve_step


def make_prefill_fn(model, cfg):
    """Prefill: run the full prompt, return (logits, primed cache)."""

    def prefill(params, tokens):
        return model.forward(params, tokens, remat=False)

    return prefill
