"""Losses."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["softmax_cross_entropy"]


def softmax_cross_entropy(logits, labels):
    """Mean next-token CE + z-loss term (both fp32).

    The label log-prob is picked with an iota/where reduction rather than
    ``take_along_axis``: a gather along the vocab axis forces GSPMD to
    all-gather vocab-sharded logits, while the masked reduction stays
    fully sharded.
    """
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
    ll = jnp.sum(jnp.where(vocab_iota == labels[..., None], lf, 0.0),
                 axis=-1)
    ce = jnp.mean(lse - ll)
    z = jnp.mean(jnp.square(lse))
    return ce, z
