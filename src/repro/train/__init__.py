from .loop import StragglerMonitor, TrainLoop
from .losses import softmax_cross_entropy
from .step import (make_eval_fn, make_prefill_fn, make_serve_step,
                   make_train_step)

__all__ = ["StragglerMonitor", "TrainLoop", "softmax_cross_entropy",
           "make_eval_fn", "make_prefill_fn", "make_serve_step",
           "make_train_step"]
