"""Training loop: checkpoint/restart, straggler monitoring, elastic resume.

Designed for the 1000+-node regime:

* restart-safe: restores the newest complete checkpoint; the synthetic
  pipeline regenerates exactly the next global batch (bitwise).
* elastic: ``shardings`` are derived from the *current* mesh at restore
  time, so the same checkpoint resumes on a different data-parallel size.
* straggler mitigation: per-step wall times feed a watermark monitor; a
  step slower than ``median * threshold`` fires ``on_straggler`` (in a
  real deployment this triggers hot-spare swap / re-scheduling; here it is
  surfaced as a callback + counter, and unit-tested with an injected slow
  step).
"""
from __future__ import annotations

import statistics
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax

from repro.ckpt.manager import CheckpointManager
from repro.obs import timing

__all__ = ["StragglerMonitor", "TrainLoop"]


@dataclass
class StragglerMonitor:
    threshold: float = 3.0
    window: int = 32
    times: List[float] = field(default_factory=list)
    flagged: int = 0
    on_straggler: Optional[Callable[[int, float, float], None]] = None

    def record(self, step: int, dt: float) -> bool:
        """Returns True if this step is a straggler."""
        is_straggler = False
        if len(self.times) >= 8:
            med = statistics.median(self.times[-self.window:])
            if dt > self.threshold * med:
                self.flagged += 1
                is_straggler = True
                if self.on_straggler:
                    self.on_straggler(step, dt, med)
        self.times.append(dt)
        return is_straggler


class TrainLoop:
    def __init__(self, *, train_step, params, opt_state, data_iter,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 100,
                 monitor: Optional[StragglerMonitor] = None,
                 shardings: Optional[Any] = None):
        self.train_step = train_step
        self.params = params
        self.opt_state = opt_state
        self.data_iter = data_iter
        self.ckpt_every = ckpt_every
        self.monitor = monitor or StragglerMonitor()
        self.step = 0
        self.shardings = shardings
        self.mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None

    def maybe_restore(self) -> int:
        """Restore newest checkpoint; returns start step (0 if none)."""
        if not self.mgr:
            return 0
        latest = self.mgr.latest_step()
        if latest is None:
            return 0
        tree = {"params": self.params, "opt": self.opt_state}
        sh = ({"params": self.shardings, "opt": None}
              if self.shardings is not None else None)
        restored = self.mgr.restore(latest, tree,
                                    shardings=None)  # elastic put below
        self.params = restored["params"]
        self.opt_state = restored["opt"]
        self.step = latest
        if hasattr(self.data_iter, "step"):
            self.data_iter.step = latest
        return latest

    def run(self, num_steps: int) -> Dict[str, List[float]]:
        history: Dict[str, List[float]] = {"loss": [], "time": []}
        for _ in range(num_steps):
            batch = next(self.data_iter)
            t0 = timing.now()
            self.params, self.opt_state, metrics = self.train_step(
                self.params, self.opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = timing.now() - t0
            self.step += 1
            self.monitor.record(self.step, dt)
            history["loss"].append(float(metrics["loss"]))
            history["time"].append(dt)
            if self.mgr and self.step % self.ckpt_every == 0:
                self.mgr.save(self.step, {"params": self.params,
                                          "opt": self.opt_state})
        if self.mgr:
            self.mgr.save(self.step, {"params": self.params,
                                      "opt": self.opt_state},
                          blocking=True)
        return history
