"""Jit'd wrapper for the fused RoPE kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import rope_pallas
from .ref import apply_rope_ref, rope_tables

__all__ = ["apply_rope", "rope_tables", "apply_rope_ref"]


@partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def apply_rope(q, k, cos, sin, *, use_kernel: bool = False,
               interpret: bool = True):
    """Rotate q (B, S, Hq, D) and k (B, S, Hk, D) by tables (S, D/2).

    ``use_kernel=False`` (default on CPU) routes through the jnp reference;
    ``use_kernel=True`` uses the fused Pallas kernel.
    """
    if not use_kernel:
        return apply_rope_ref(q, cos, sin), apply_rope_ref(k, cos, sin)

    B, S, Hq, D = q.shape
    Hk = k.shape[2]

    def one(qb, kb):
        qo, ko = rope_pallas(
            qb.reshape(S, Hq * D), kb.reshape(S, Hk * D), cos, sin,
            heads_q=Hq, heads_k=Hk, head_dim=D,
            blk=min(256, S), interpret=interpret,
        )
        return qo.reshape(S, Hq, D), ko.reshape(S, Hk, D)

    return jax.vmap(one)(q, k)
