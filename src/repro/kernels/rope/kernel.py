"""Pallas TPU kernel: fused RoPE application to q and k.

One grid step rotates a ``(blk, heads * head_dim)`` tile of both q and k
while the cos/sin tables stay resident in VMEM — q and k never round-trip
to HBM between their (identical-plane) rotations, the same fused-rotation
reuse argument as the paper's SS1.3 applied to the two operands that share
rotation values.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["rope_pallas"]


def _rope_kernel(cos_ref, sin_ref, q_ref, k_ref, qo_ref, ko_ref,
                 *, heads_q: int, heads_k: int, head_dim: int):
    half = head_dim // 2
    c = cos_ref[...]
    s = sin_ref[...]

    def rot(x_ref, o_ref, heads):
        blk = x_ref.shape[0]
        x = x_ref[...].reshape(blk, heads, head_dim)
        x1 = x[..., :half]
        x2 = x[..., half:]
        cc = c[:, None, :]
        ss = s[:, None, :]
        # RoPE's half-split convention rotates (x1, x2) with its own
        # sign layout; it is not part of the rotation-sequence bitwise
        # contract, so the canonical plane_update does not apply here.
        # repro-lint: disable-next=RA301
        out = jnp.concatenate([x1 * cc - x2 * ss, x1 * ss + x2 * cc],
                              axis=-1)
        o_ref[...] = out.reshape(blk, heads * head_dim)

    rot(q_ref, qo_ref, heads_q)
    rot(k_ref, ko_ref, heads_k)


@functools.partial(
    jax.jit, static_argnames=("heads_q", "heads_k", "head_dim", "blk",
                              "interpret")
)
def rope_pallas(q, k, cos, sin, *, heads_q: int, heads_k: int,
                head_dim: int, blk: int = 256, interpret: bool = True):
    """Fused RoPE for ``q`` (S, Hq*D) and ``k`` (S, Hk*D); tables (S, D/2)."""
    S = q.shape[0]
    assert S % blk == 0, (S, blk)
    grid = (S // blk,)
    half = head_dim // 2

    kernel = functools.partial(
        _rope_kernel, heads_q=heads_q, heads_k=heads_k, head_dim=head_dim
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk, half), lambda i: (i, 0)),
            pl.BlockSpec((blk, half), lambda i: (i, 0)),
            pl.BlockSpec((blk, heads_q * head_dim), lambda i: (i, 0)),
            pl.BlockSpec((blk, heads_k * head_dim), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((blk, heads_q * head_dim), lambda i: (i, 0)),
            pl.BlockSpec((blk, heads_k * head_dim), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(q.shape, q.dtype),
            jax.ShapeDtypeStruct(k.shape, k.dtype),
        ],
        interpret=interpret,
    )(cos, sin, q, k)
