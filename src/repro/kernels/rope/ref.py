"""Pure-jnp oracle for rotary position embeddings (RoPE).

RoPE is the degenerate planar-rotation sequence: one wave (``k = 1``) of
*disjoint* rotations — dimension pairs ``(i, i + d/2)`` of each head vector
rotate by ``pos * theta_i`` (half-split / "rotate_half" convention).
Because the planes are disjoint the wave vectorizes; the connection to the
paper's machinery is the representation, and the fused Pallas kernel
applies the same VMEM-residency argument (rotate q and k in one pass, no
HBM round-trip for the intermediates).
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["rope_tables", "apply_rope_ref"]


def rope_tables(positions, head_dim: int, base: float = 10000.0,
                dtype=jnp.float32):
    """cos/sin tables ``(len(positions), head_dim // 2)``."""
    half = head_dim // 2
    inv_freq = base ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[:, None] * inv_freq[None, :]
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def apply_rope_ref(x, cos, sin):
    """Rotate ``x`` (..., seq, heads, head_dim) by per-position tables.

    ``cos``/``sin``: (seq, head_dim // 2).
    """
    half = x.shape[-1] // 2
    x1 = x[..., :half]
    x2 = x[..., half:]
    c = cos[..., :, None, :]
    s = sin[..., :, None, :]
    # RoPE half-split convention, not the rotation-sequence contract
    # (see kernels/rope/kernel.py).
    # repro-lint: disable-next=RA301
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c],
                           axis=-1)
