"""Jit'd wrapper for the fused multi-request rotation kernel.

Handles target packing (transpose + lane padding), sign materialization
(the bit-stable runtime sign grid — see ``core.rotations.plane_update``),
and the live-plane window computation that lets the kernel *skip*
identity padding (``pad_to`` waves, ``seq.T`` staircases) instead of
multiplying it through.  Public entry: :func:`rot_sequence_batched`.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat, obs
from repro.kernels.limits import clamp_m_blk, round_up

from .kernel import rotseq_batched_pallas

__all__ = ["rot_sequence_batched", "wave_windows", "count_live_planes"]


def wave_windows(C, S, G):
    """Per-wave live-plane windows ``(starts, counts)`` of shape (bs, K).

    A plane is *dead* (exactly skippable) iff it is the identity
    rotation ``c = 1, s = 0, g = -1`` — a padded 2x2 *reflector* with
    the same cos/sin is ``diag(1, -1)``, not the identity, so the sign
    participates in the test.  Each wave's live planes are reduced to
    their contiguous hull ``[start, start + count)``: interior dead
    planes (rare; only in hand-built sequences) are applied as exact
    no-ops, while the hull bounds skip the ``pad_to`` tails and the
    ``seq.T`` staircase triangles that dominate padded workloads.

    Skipping is exact for finite targets free of ``-0.0`` entries:
    backends that multiply an identity plane through compute ``0*x``
    terms, which a NaN/inf target column turns into NaN and a ``-0.0``
    entry normalizes to ``+0.0`` — the skip leaves such values
    untouched instead.  Non-finite and negative-zero targets are
    therefore outside the bitwise bucketed==per-request contract.
    """
    live = ~((C == 1) & (S == 0) & (G < 0))          # (bs, J, K)
    any_live = live.any(axis=1)                       # (bs, K)
    first = jnp.argmax(live, axis=1).astype(jnp.int32)
    last = (live.shape[1] - 1
            - jnp.argmax(live[:, ::-1, :], axis=1)).astype(jnp.int32)
    starts = jnp.where(any_live, first, 0)
    counts = jnp.where(any_live, last - first + 1, 0)
    return starts.astype(jnp.int32), counts.astype(jnp.int32)


def count_live_planes(seq) -> int:
    """Concrete hull-plane count of one RotationSequence (test helper).

    Derived from :func:`wave_windows` itself so the plane-skip witness
    tests always assert against the kernel's actual liveness rule.
    """
    C = jnp.asarray(seq.cos)[None]
    S = jnp.asarray(seq.sin)[None]
    if seq.sign is not None:
        G = jnp.asarray(seq.sign)[None]
    else:
        G = jnp.full(C.shape, 1.0 if seq.reflect else -1.0, C.dtype)
    _, counts = wave_windows(C, S, G)
    return int(counts.sum())


def rot_sequence_batched(A, C, S, *, reflect: bool = False, G=None,
                         m_blk: int = 256, interpret: bool | None = None,
                         return_planes: bool = False):
    """Apply shared or per-request wave stacks to a batch of targets.

    One Pallas launch per call — the fused serving path.

    Args:
      A: targets ``(b, m, n)``, or a single ``(m, n)`` target.
      C, S: waves — shared ``(n-1, K)`` (every target gets the same
        sequence) or stacked ``(b, n-1, K)`` (per-request sequences).
      G: optional per-entry signs, matching ``C``'s shape; ``reflect``
        marks an all-reflector stack when ``G`` is ``None``.
      m_blk: target rows (lanes) per grid step.
      return_planes: also return the kernel's per-grid-step processed
        plane counts (the identity-skip witness used by tests).

    Returns:
      The rotated targets with ``A``'s shape (and the ``(b, R)`` int32
      plane counts when ``return_planes``).

    This host wrapper only adds obs accounting (launches, planes
    applied vs skipped, modeled bytes moved) around the jitted core —
    a no-op while obs is off or under tracing.
    """
    if obs.enabled() and not any(
            compat.is_tracer(x) for x in (A, C, S, G) if x is not None):
        _record_launch(A, C, S, G, reflect)
    return _rot_sequence_batched_jit(
        A, C, S, reflect=reflect, G=G, m_blk=m_blk, interpret=interpret,
        return_planes=return_planes)


def _record_launch(A, C, S, G, reflect: bool) -> None:
    # accounting runs on every obs-enabled launch of the serving hot
    # path, so the liveness hull is computed host-side in numpy: the
    # jnp formulation dispatches a dozen traced ops and syncs on
    # ``counts.sum()``, which costs more than the kernel itself at
    # serving batch sizes.  Same boolean rule as :func:`wave_windows`.
    b = int(A.shape[0]) if A.ndim == 3 else 1
    Cb = np.asarray(C)
    if Cb.ndim == 2:
        Cb = Cb[None]
    Sb = np.asarray(S).reshape(Cb.shape)
    if G is None:
        # reflect: g = +1 everywhere, so no plane passes the identity
        # test; plain: g = -1 everywhere, the test reduces to cos/sin
        live = np.ones(Cb.shape, bool) if reflect \
            else (Cb != 1) | (Sb != 0)
    else:
        Gb = np.asarray(G).reshape(Cb.shape)
        live = ~((Cb == 1) & (Sb == 0) & (Gb < 0))
    bs, J, K = Cb.shape
    any_live = live.any(axis=1)                       # (bs, K)
    first = live.argmax(axis=1)
    last = J - 1 - live[:, ::-1, :].argmax(axis=1)
    counts = np.where(any_live, last - first + 1, 0)
    # hull planes each target actually executes; shared waves (bs=1)
    # replay the same windows on every target
    applied = int(counts.sum()) * (b // bs)
    total = J * K * b
    itemsize = jnp.dtype(A.dtype).itemsize
    m = int(A.shape[-2]) if A.ndim == 3 else int(A.shape[0])
    n = int(A.shape[-1])
    moved = (2 * b * m * n + 3 * bs * J * K) * itemsize
    obs.inc("kernels.rotseq_batched.launches")
    obs.inc("kernels.rotseq_batched.planes_applied", applied)
    obs.inc("kernels.rotseq_batched.planes_skipped", total - applied)
    obs.inc("kernels.rotseq_batched.bytes_moved", int(moved))


@partial(
    jax.jit,
    static_argnames=("m_blk", "reflect", "interpret", "return_planes"),
)
def _rot_sequence_batched_jit(A, C, S, *, reflect: bool = False, G=None,
                              m_blk: int = 256,
                              interpret: bool | None = None,
                              return_planes: bool = False):
    if interpret is None:
        interpret = compat.pallas_interpret_default()
    single = A.ndim == 2
    if single:
        A = A[None]
    b, m, n = A.shape
    if C.ndim == 2:
        C = C[None]
        S = S[None]
        if G is not None:
            G = G[None]
    bs, J, K = C.shape
    assert J == n - 1, (C.shape, A.shape)
    assert bs in (1, b), (C.shape, A.shape)
    if G is None:
        G = jnp.full(C.shape, 1.0 if reflect else -1.0, C.dtype)
    starts, counts = wave_windows(C, S, G)

    # never tile (and pad) wider than the target: small serve-bucket
    # rows would otherwise pay m_blk lanes of identity work per plane
    # (multiples of 8 keep sublane alignment; use 128+ on hardware)
    m_blk = clamp_m_blk(m, m_blk)
    m_pad = round_up(m, m_blk)
    AT = jnp.pad(jnp.swapaxes(A, 1, 2), ((0, 0), (0, 0), (0, m_pad - m)))
    out, planes = rotseq_batched_pallas(
        AT, C, S, G, starts, counts,
        m_blk=m_blk, interpret=interpret,
    )
    out = jnp.swapaxes(out[:, :, :m], 1, 2)
    if single:
        out = out[0]
    if return_planes:
        return out, planes
    return out
