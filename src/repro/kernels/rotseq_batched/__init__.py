from repro.kernels.rotseq_batched.ops import rot_sequence_batched

__all__ = ["rot_sequence_batched"]
