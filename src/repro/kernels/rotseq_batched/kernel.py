"""Pallas TPU kernel: fused multi-request rotation-sequence application.

One launch serves a whole ``RotationService`` bucket: the grid runs over
``(batch, m-blocks)`` and each step rotates one ``(n, m_blk)`` slab of
one request entirely in VMEM — the packed C/S/G panel is loaded once per
batch element (reused across its m-blocks) instead of once per request
launch, and the target streams through HBM exactly once regardless of
the wave count.  This is the paper's communication argument applied
across *requests*: the bucket's batched memory pass replaces ``b``
vmap'd/looped per-request launches.

Identity padding is *skipped*, not multiplied through.  Buckets
normalize wave counts with ``pad_to`` (whole trailing waves of
``c=1, s=0`` no-ops) and ``seq.T`` packs a ``k``-wave sequence into an
``n+k-2``-wave anti-diagonal staircase that is mostly identity; both
paddings leave each wave's *live* planes in one contiguous window.  The
host computes a per-wave ``(start, count)`` window (``valid_planes``)
and the kernel loops over ``count`` planes only — ``count = 0`` waves
cost nothing.  A per-grid-step plane counter is emitted so tests can
assert the skip actually happened.

Layout matches the VPU wavefront kernel ("packing", paper SS4): targets
are transposed to ``(n, m)`` so matrix columns are sublane rows and the
row dimension ``m`` lies along TPU lanes; every plane update is a dense
``(1, m_blk)`` x scalar VPU op through the canonical
:func:`~repro.core.rotations.plane_update` evaluation order (bit-stable
against every jnp backend).

Residency: the whole ``(n, m_blk)`` slab stays in VMEM for all ``K``
waves, and the scalar-indexed C/S/G panels stay in SMEM — the cost
model (``registry.cost_rotseq_batched``) prices the kernel out of
``method="auto"`` when either exceeds its on-chip budget
(``repro.kernels.limits.SMEM_PANEL_BUDGET`` for the panels), since
interpret mode would happily run grids Mosaic could never compile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat
from repro.core.rotations import plane_update

__all__ = ["rotseq_batched_pallas"]


def _batched_kernel(starts_ref, counts_ref, c_ref, s_ref, g_ref, a_ref,
                    out_ref, planes_ref, *, K: int):
    """Apply all K waves to one (n, m_blk) slab, skipping dead planes."""
    x0 = a_ref[0]  # (n, m_blk)

    def wave(p, carry):
        x, total = carry
        start = starts_ref[0, p]
        count = counts_ref[0, p]

        def rot(jj, x):
            j = start + jj
            c = c_ref[0, j, p].astype(x.dtype)
            s = s_ref[0, j, p].astype(x.dtype)
            g = g_ref[0, j, p].astype(x.dtype)
            pair = jax.lax.dynamic_slice_in_dim(x, j, 2, axis=0)
            xn, yn = plane_update(pair[0], pair[1], c, s, g)
            return jax.lax.dynamic_update_slice_in_dim(
                x, jnp.stack([xn, yn], axis=0), j, axis=0
            )

        x = jax.lax.fori_loop(0, count, rot, x)
        return x, total + count

    x, total = jax.lax.fori_loop(0, K, wave, (x0, jnp.int32(0)))
    out_ref[0] = x
    planes_ref[0, 0] = total


@functools.partial(
    jax.jit, static_argnames=("m_blk", "interpret")
)
def rotseq_batched_pallas(AT, C, S, G, starts, counts, *, m_blk: int,
                          interpret: bool = True):
    """One fused launch over a batch of packed targets.

    Args:
      AT: ``(b, n, m_pad)`` packed (transposed) targets, ``m_pad`` a
        multiple of ``m_blk``.
      C, S, G: ``(bs, n-1, K)`` wave stacks — ``bs = b`` for per-request
        sequences or ``bs = 1`` for one shared sequence.  ``G`` is the
        per-entry sign of the unified update (``-1`` rotation, ``+1``
        reflector), always materialized.
      starts, counts: ``(bs, K)`` int32 — first live plane and number of
        contiguous live planes per wave; ``count = 0`` skips the wave.
      m_blk: lanes of the target per grid step.

    Returns:
      ``(out, planes)``: the rotated ``(b, n, m_pad)`` stack and an
      ``(b, R)`` int32 count of planes actually processed per grid step
      (the plane-skip witness; ``R = m_pad // m_blk``).
    """
    b, n, m_pad = AT.shape
    bs, J, K = C.shape
    assert J == n - 1, (C.shape, AT.shape)
    assert bs in (1, b), (bs, b)
    assert m_pad % m_blk == 0, (m_pad, m_blk)
    R = m_pad // m_blk
    grid = (b, R)

    if bs == 1:
        panel_ix = lambda ib, i: (0, 0, 0)
        window_ix = lambda ib, i: (0, 0)
    else:
        panel_ix = lambda ib, i: (ib, 0, 0)
        window_ix = lambda ib, i: (ib, 0)

    panel_spec = pl.BlockSpec((1, J, K), panel_ix,
                              memory_space=pltpu.SMEM)
    window_spec = pl.BlockSpec((1, K), window_ix,
                               memory_space=pltpu.SMEM)
    kernel = functools.partial(_batched_kernel, K=K)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            window_spec,
            window_spec,
            panel_spec,
            panel_spec,
            panel_spec,
            pl.BlockSpec((1, n, m_blk), lambda ib, i: (ib, 0, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, n, m_blk), lambda ib, i: (ib, 0, i)),
            pl.BlockSpec((1, 1), lambda ib, i: (ib, i),
                         memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, n, m_pad), AT.dtype),
            jax.ShapeDtypeStruct((b, R), jnp.int32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(starts, counts, C, S, G, AT)
