"""Pure-jnp oracle for the fused multi-request kernel.

Per-request application through the (numpy-validated) blocked host
algorithm — what a ``RotationService`` bucket would do without the
fused launch.  The fused kernel must match it bit-for-bit on the
rotation and per-entry-sign families.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.blocked import rot_sequence_blocked


def rot_sequence_batched_ref(A, C, S, *, reflect: bool = False, G=None,
                             n_b: int = 64, k_b: int = 16):
    """b separate blocked applications (shared or per-request waves)."""
    single = A.ndim == 2
    if single:
        A = A[None]
    outs = []
    for i in range(A.shape[0]):
        Ci = C if C.ndim == 2 else C[i]
        Si = S if S.ndim == 2 else S[i]
        Gi = None if G is None else (G if G.ndim == 2 else G[i])
        outs.append(rot_sequence_blocked(A[i], Ci, Si, n_b=n_b, k_b=k_b,
                                         reflect=reflect, G=Gi))
    out = jnp.stack(outs)
    return out[0] if single else out
