"""Pallas TPU kernel: wavefront application of rotation sequences (VPU path).

Faithful TPU adaptation of the paper's register-reuse kernel (SS3).  The
paper pins ``m_r`` rows x ``k_r + 1`` columns of ``A`` in AVX registers and
streams waves of rotations through them; here a ``(k_b + n_b, m_blk)`` block
of the *packed* (transposed) matrix is pinned in VMEM and ``k_b`` waves of
rotations stream through it.  The ``k_b`` trailing columns carry over to the
next grid step in a VMEM scratch buffer — they never round-trip to HBM,
which is exactly the paper's fused-rotation reuse argument one level up the
memory hierarchy.

Layout ("packing", paper SS4): the kernel operates on ``AT`` of shape
``(n_cols, m)`` so that matrix *columns* are rows of vregs — the row
dimension ``m`` lies along TPU lanes and every rotation is a dense
``(1, m_blk)`` x scalar VPU op.  The caller transposes once (the packing
cost; negligible for ``k >> 1``) or keeps the operand packed across calls
(paper's ``rs_kernel_v2``).

Grid: ``(num_row_blocks, T)`` with the tile dimension ``T`` innermost and
sequential ("arbitrary" semantics): the carry scratch persists across ``t``
and is re-initialized at ``t == 0``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat
from repro.core.rotations import plane_update

__all__ = ["rotseq_wave_pallas"]


def _wave_kernel(ct_ref, st_ref, gt_ref, init_ref, fresh_ref, out_ref,
                 carry_ref, *, n_b: int, k_b: int):
    """One parallelogram tile: k_b waves over X = [carry; fresh] (w, m_blk)."""
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        carry_ref[...] = init_ref[...]

    x = jnp.concatenate([carry_ref[...], fresh_ref[...]], axis=0)

    def wave(p, x):
        def rot(jj, x):
            jl = k_b - 1 - p + jj
            c = ct_ref[0, jj, p].astype(x.dtype)
            s = st_ref[0, jj, p].astype(x.dtype)
            g = gt_ref[0, jj, p].astype(x.dtype)
            pair = jax.lax.dynamic_slice_in_dim(x, jl, 2, axis=0)
            xn, yn = plane_update(pair[0], pair[1], c, s, g)
            return jax.lax.dynamic_update_slice_in_dim(
                x, jnp.stack([xn, yn], axis=0), jl, axis=0
            )

        return jax.lax.fori_loop(0, n_b, rot, x)

    x = jax.lax.fori_loop(0, k_b, wave, x)
    out_ref[...] = x[:n_b]
    carry_ref[...] = x[n_b:]


@functools.partial(
    jax.jit,
    static_argnames=("n_b", "k_b", "m_blk", "interpret"),
)
def rotseq_wave_pallas(ATfresh, Ct, St, Gt, init, *, n_b: int, k_b: int,
                       m_blk: int, interpret: bool = True):
    """Apply one band of ``k_b`` waves to the packed operand.

    Args:
      ATfresh: ``(T * n_b, m)`` — fresh column stream, packed layout
        (``ATfresh[i] = A[:, i + 1]`` zero-padded; see ``core.blocked``).
      Ct, St, Gt: ``(T, n_b, k_b)`` sheared rotation tiles (no-op padded;
        ``Gt`` is the rotation/reflector sign, see ``pack_sheared``).
      init: ``(k_b, m)`` initial carry (``[0...0, A[:, 0]]``).
      n_b, k_b: tile diagonals / band waves (k_b = paper's ``k_b``,
        n_b plays the role of the paper's L1 block ``n_b``).
      m_blk: rows of ``A`` per grid step (lane dimension; multiple of 128
        on hardware).

    Returns:
      ``(T * n_b, m)`` output stream ``O`` with
      ``O[i] = A_final[:, i - (k_b - 1)]``.
    """
    U, m = ATfresh.shape
    T = U // n_b
    assert U == T * n_b, (U, n_b)
    assert m % m_blk == 0, (m, m_blk)
    R = m // m_blk
    grid = (R, T)

    kernel = functools.partial(_wave_kernel, n_b=n_b, k_b=k_b)
    cs_spec = pl.BlockSpec((1, n_b, k_b), lambda i, t: (t, 0, 0),
                           memory_space=pltpu.SMEM)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            cs_spec,
            cs_spec,
            cs_spec,
            pl.BlockSpec((k_b, m_blk), lambda i, t: (0, i)),
            pl.BlockSpec((n_b, m_blk), lambda i, t: (t, i)),
        ],
        out_specs=pl.BlockSpec((n_b, m_blk), lambda i, t: (t, i)),
        out_shape=jax.ShapeDtypeStruct((T * n_b, m), ATfresh.dtype),
        scratch_shapes=[pltpu.VMEM((k_b, m_blk), ATfresh.dtype)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(Ct, St, Gt, init, ATfresh)
