"""Pure-jnp oracle for the rotation-sequence Pallas kernels.

The oracle is the (already numpy-validated) blocked host algorithm from
``repro.core``; tests additionally cross-check against the pure-numpy
Algorithm 1.2 oracle in ``repro.core.ref``.
"""
from __future__ import annotations

from repro.core.blocked import rot_sequence_blocked


def rot_sequence_ref(A, C, S, *, n_b: int = 64, k_b: int = 16,
                     reflect: bool = False):
    return rot_sequence_blocked(A, C, S, n_b=n_b, k_b=k_b, reflect=reflect)
