"""Jit'd wrapper for the wavefront rotation-sequence Pallas kernel.

Handles the packing (transpose to column-major-of-rows layout, paper SS4),
identity padding, band loop over ``k_b`` waves, and unpacking.  Public entry:
:func:`rot_sequence_wave`.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro import compat, obs
from repro.core.blocked import num_tiles, pack_sheared
from repro.kernels.limits import round_up

from .kernel import rotseq_wave_pallas

__all__ = ["rot_sequence_wave"]


def rot_sequence_wave(A, C, S, *, n_b: int = 64, k_b: int = 16,
                      m_blk: int = 256, reflect: bool = False, G=None,
                      interpret: bool | None = None):
    """Apply the rotation sequence ``(C, S)`` to ``A`` from the right.

    Drop-in equivalent of ``repro.core.ref.rot_sequence_numpy`` computed by
    the Pallas wavefront kernel.  ``m_blk`` is clamped/padded so any ``m``
    works; on hardware use multiples of 128.  ``interpret=None`` resolves
    via the compat shim: compiled on TPU, interpreter elsewhere.

    The host wrapper only adds obs accounting (launches, planes, modeled
    bytes per the blocked-traffic term) around the jitted core — a no-op
    while obs is off or under tracing.
    """
    if obs.enabled() and not compat.is_tracer(A):
        m, n = A.shape
        J, k = C.shape
        itemsize = jnp.dtype(A.dtype).itemsize
        bands = max(1, math.ceil(k / max(1, k_b)))
        obs.inc("kernels.rotseq.launches")
        obs.inc("kernels.rotseq.planes_applied", J * k)
        obs.inc("kernels.rotseq.bytes_moved",
                int((2 * m * n * bands + 3 * J * k) * itemsize))
    return _rot_sequence_wave_jit(A, C, S, n_b=n_b, k_b=k_b, m_blk=m_blk,
                                  reflect=reflect, G=G,
                                  interpret=interpret)


@partial(
    jax.jit,
    static_argnames=("n_b", "k_b", "m_blk", "reflect", "interpret"),
)
def _rot_sequence_wave_jit(A, C, S, *, n_b: int = 64, k_b: int = 16,
                           m_blk: int = 256, reflect: bool = False,
                           G=None, interpret: bool | None = None):
    if interpret is None:
        interpret = compat.pallas_interpret_default()
    m, n = A.shape
    J, k = C.shape
    assert J == n - 1, (C.shape, A.shape)
    n_b = min(n_b, max(8, n))
    T = num_tiles(n, n_b, k_b)

    m_pad = round_up(m, m_blk)
    AT = jnp.pad(A.T, ((0, 0), (0, m_pad - m)))  # packed layout (n, m_pad)

    for p0 in range(0, k, k_b):
        Ct, St, Gt = pack_sheared(C, S, p0, k_b, n_b, T, reflect=reflect,
                                  G=G)
        init = jnp.concatenate(
            [jnp.zeros((k_b - 1, m_pad), AT.dtype), AT[:1]], axis=0
        )
        fresh = jnp.pad(AT[1:], ((0, T * n_b - (n - 1)), (0, 0)))
        O = rotseq_wave_pallas(
            fresh, Ct, St, Gt, init,
            n_b=n_b, k_b=k_b, m_blk=min(m_blk, m_pad),
            interpret=interpret,
        )
        AT = jax.lax.slice_in_dim(O, k_b - 1, k_b - 1 + n, axis=0)

    return AT[:, :m].T  # unpack
