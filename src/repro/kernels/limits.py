"""Single source of truth for on-chip kernel budgets and tile clamps.

Every constant that prices a Pallas kernel's on-chip residency — and
every helper that derives a block shape from one — lives here and
nowhere else.  PR 5 shipped with the fused kernel's ``m_blk`` clamp
duplicated between ``rotseq_batched/ops.py`` and the registry cost
guard, coupled only by a comment ("mirror the kernel wrapper's clamp");
the analyzer rule RA403/RA404 (``repro.analysis``) now *enforces* that
budget constants and clamp helpers are defined in this module and
imported everywhere else, so the cost model can never silently price a
kernel off a stale copy of its own limits.

No jax imports: this module is pure host arithmetic, importable from
the registry (which must stay cheap to import) and from every kernel
wrapper without ordering constraints.
"""
from __future__ import annotations

__all__ = [
    "SUBLANES", "SMEM_PANEL_BUDGET", "VMEM_SLAB_BUDGET",
    "round_up", "clamp_m_blk",
]

# TPU sublane count: block shapes keep the second-minor dimension a
# multiple of this so Mosaic never pads a tile internally.
SUBLANES = 8

# SMEM bytes one request's scalar-indexed C/S/G panels may occupy in the
# fused rotseq_batched kernel.  Scalar memory is orders of magnitude
# smaller than VMEM: serve-bucket grids are a few KB, while a (255, 263)
# staircase panel set is ~800KB and would fail Mosaic compilation —
# interpret mode would happily run it, which is why the cost model
# prices the kernel out (rather than crashing) past this budget.
SMEM_PANEL_BUDGET = 128 * 2**10

# VMEM bytes one (n, m_blk) target slab may occupy: the fused kernel's
# single-HBM-pass argument assumes the whole slab stays resident for all
# K waves.
VMEM_SLAB_BUDGET = 8 * 2**20


def round_up(x: int, mult: int) -> int:
    """``x`` rounded up to the next multiple of ``mult``."""
    return ((x + mult - 1) // mult) * mult


def clamp_m_blk(m: int, m_blk: int) -> int:
    """Clamp a lane-tile request to the target's (sublane-padded) rows.

    Never tile (and pad) wider than the target: small serve-bucket rows
    would otherwise pay ``m_blk`` lanes of identity work per plane.
    Multiples of :data:`SUBLANES` keep sublane alignment; use 128+ on
    hardware.  Both the ``rotseq_batched`` wrapper and the registry cost
    guard call this one definition, so the kernel the cost model prices
    is the kernel that actually launches.
    """
    return min(m_blk, round_up(max(1, m), SUBLANES))
