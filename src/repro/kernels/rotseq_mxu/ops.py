"""Jit'd wrapper for the MXU rotation-sequence kernel."""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro import compat, obs
from repro.core.accumulate import accumulate_tile_factors
from repro.core.blocked import num_tiles, pack_sheared
from repro.kernels.limits import round_up

from .kernel import rotseq_mxu_pallas

__all__ = ["rot_sequence_mxu"]


def rot_sequence_mxu(A, C, S, *, n_b: int = 128, k_b: int = 128,
                     m_blk: int = 256, reflect: bool = False, G=None,
                     interpret: bool | None = None):
    """Apply ``(C, S)`` to ``A`` from the right via accumulated MXU tiles.

    ``interpret=None`` resolves via the compat shim: compiled on TPU,
    interpreter elsewhere.

    The host wrapper only adds obs accounting (launches, planes, modeled
    bytes per the accumulated-traffic term) around the jitted core — a
    no-op while obs is off or under tracing.
    """
    if obs.enabled() and not compat.is_tracer(A):
        m, n = A.shape
        J, k = C.shape
        itemsize = jnp.dtype(A.dtype).itemsize
        bands = max(1, math.ceil(k / max(1, k_b)))
        obs.inc("kernels.rotseq_mxu.launches")
        obs.inc("kernels.rotseq_mxu.planes_applied", J * k)
        obs.inc("kernels.rotseq_mxu.bytes_moved",
                int((2 * m * n * bands + 3 * J * k) * itemsize))
    return _rot_sequence_mxu_jit(A, C, S, n_b=n_b, k_b=k_b, m_blk=m_blk,
                                 reflect=reflect, G=G, interpret=interpret)


@partial(
    jax.jit,
    static_argnames=("n_b", "k_b", "m_blk", "reflect", "interpret"),
)
def _rot_sequence_mxu_jit(A, C, S, *, n_b: int = 128, k_b: int = 128,
                          m_blk: int = 256, reflect: bool = False,
                          G=None, interpret: bool | None = None):
    if interpret is None:
        interpret = compat.pallas_interpret_default()
    m, n = A.shape
    J, k = C.shape
    assert J == n - 1
    n_b = min(n_b, max(8, n))
    T = num_tiles(n, n_b, k_b)

    m_pad = round_up(m, m_blk)
    Ap = jnp.pad(A, ((0, m_pad - m), (0, 0)))

    for p0 in range(0, k, k_b):
        Ct, St, Gt = pack_sheared(C, S, p0, k_b, n_b, T, reflect=reflect,
                                  G=G)
        Q = accumulate_tile_factors(Ct, St, Gt, dtype=Ap.dtype)
        init = jnp.concatenate(
            [jnp.zeros((m_pad, k_b - 1), Ap.dtype), Ap[:, :1]], axis=1
        )
        fresh = jnp.pad(Ap[:, 1:], ((0, 0), (0, T * n_b - (n - 1))))
        O = rotseq_mxu_pallas(
            fresh, Q, init, n_b=n_b, k_b=k_b,
            m_blk=min(m_blk, m_pad), interpret=interpret,
        )
        Ap = jax.lax.slice_in_dim(O, k_b - 1, k_b - 1 + n, axis=1)

    return Ap[:m]
