"""Pure-jnp oracle for the MXU rotation-sequence kernel."""
from __future__ import annotations

from repro.core.accumulate import rot_sequence_accumulated


def rot_sequence_mxu_ref(A, C, S, *, n_b: int = 128, k_b: int = 128,
                         reflect: bool = False):
    return rot_sequence_accumulated(A, C, S, n_b=n_b, k_b=k_b,
                                    reflect=reflect)
