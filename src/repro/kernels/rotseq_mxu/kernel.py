"""Pallas TPU kernel: accumulated (MXU) application of rotation sequences.

The beyond-paper TPU formulation of ``rs_gemm`` (paper SS8): parallelogram
tiles of rotations are pre-accumulated into ``(w, w)`` orthogonal factors
(``w = k_b + n_b``), and this kernel sweeps the matrix through them with a
carry, turning the whole rotation band into a chain of MXU matmuls::

    X_t   = [carry_t | fresh_t]          # (m_blk, w)
    Y_t   = X_t @ Q_t                    # MXU
    out_t = Y_t[:, :n_b];  carry_{t+1} = Y_t[:, n_b:]

The carry column block stays in VMEM between grid steps — the same
communication-avoidance as the VPU kernel, but at MXU flop rates.  With
``n_b = k_b`` the factor is dense and only 4/3 extra flops are paid
relative to the direct method (on a unit ~50x faster than the VPU).

Natural (row-major) layout: ``m`` on sublanes, columns on lanes; all matmul
dims are multiples of 128 when ``n_b = k_b = 128``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

__all__ = ["rotseq_mxu_pallas"]


def _mxu_kernel(q_ref, init_ref, fresh_ref, out_ref, carry_ref,
                *, n_b: int, k_b: int):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        carry_ref[...] = init_ref[...]

    x = jnp.concatenate([carry_ref[...], fresh_ref[...]], axis=1)
    y = jnp.dot(x, q_ref[0], preferred_element_type=jnp.float32)
    y = y.astype(x.dtype)
    out_ref[...] = y[:, :n_b]
    carry_ref[...] = y[:, n_b:]


@functools.partial(
    jax.jit, static_argnames=("n_b", "k_b", "m_blk", "interpret")
)
def rotseq_mxu_pallas(fresh, Q, init, *, n_b: int, k_b: int, m_blk: int,
                      interpret: bool = True):
    """Sweep one band using tile factors ``Q`` (T, w, w).

    Args:
      fresh: ``(m, T * n_b)`` fresh-column stream (natural layout,
        ``fresh[:, i] = A[:, i + 1]`` zero-padded).
      Q: ``(T, w, w)`` accumulated tile factors, ``w = k_b + n_b``.
      init: ``(m, k_b)`` initial carry.

    Returns:
      ``(m, T * n_b)`` output stream ``O``, ``O[:, i] = A_final[:, i - k_b + 1]``.
    """
    m, U = fresh.shape
    T, w, _ = Q.shape
    assert w == n_b + k_b and U == T * n_b
    assert m % m_blk == 0
    grid = (m // m_blk, T)

    kernel = functools.partial(_mxu_kernel, n_b=n_b, k_b=k_b)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, w, w), lambda i, t: (t, 0, 0)),
            pl.BlockSpec((m_blk, k_b), lambda i, t: (i, 0)),
            pl.BlockSpec((m_blk, n_b), lambda i, t: (i, t)),
        ],
        out_specs=pl.BlockSpec((m_blk, n_b), lambda i, t: (i, t)),
        out_shape=jax.ShapeDtypeStruct((m, T * n_b), fresh.dtype),
        scratch_shapes=[pltpu.VMEM((m_blk, k_b), fresh.dtype)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(Q, init, fresh)
