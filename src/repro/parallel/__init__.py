from .compression import (compress_lowrank, compressed_psum,
                          decompress_lowrank, error_feedback_update,
                          lowrank_error_feedback, lowrank_wire_bytes,
                          svd_lowrank)
from .sharding import (AxisRules, DEFAULT_RULES, axis_rules, current_rules,
                       logical_to_spec, param_spec, shard)

__all__ = ["AxisRules", "DEFAULT_RULES", "axis_rules", "current_rules",
           "logical_to_spec", "param_spec", "shard",
           "compressed_psum", "error_feedback_update",
           "svd_lowrank", "compress_lowrank", "decompress_lowrank",
           "lowrank_error_feedback", "lowrank_wire_bytes"]
