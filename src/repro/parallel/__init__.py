from .sharding import (AxisRules, DEFAULT_RULES, axis_rules, current_rules,
                       logical_to_spec, param_spec, shard)

__all__ = ["AxisRules", "DEFAULT_RULES", "axis_rules", "current_rules",
           "logical_to_spec", "param_spec", "shard"]
