"""Gradient compression for cross-pod communication.

``compressed_psum`` quantizes a tensor to int8 (per-chunk scales) before
an all-reduce-style exchange: on low-bandwidth cross-pod links (DCN) the
4x volume reduction dominates the quantization noise, which is further
suppressed by *error feedback* (the residual is carried to the next
step — standard EF-SGD).  Used via ``CompressedGradSync`` around the
data-parallel gradient reduction.

``compress_lowrank`` is the rank-r alternative for 2D gradients: a
Golub-Kahan SVD (``repro.eig.svd_givens`` — singular vectors accumulated
through the rotation-sequence registry) truncated to rank ``r`` sends
``r (m + n)`` floats instead of ``m n``.  Pairs with the same error
feedback via :func:`lowrank_error_feedback`.

Implementation note: quantized values cannot be summed directly (scales
differ per shard), so the exchange is an all-to-all-free two-phase
ring-style reduction expressed with ``psum`` over dequantized chunks; the
bandwidth accounting (what would cross the wire) is the int8 payload +
fp32 scales, which the tests assert.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["quantize_for_allreduce", "dequantize_after_allreduce",
           "compressed_psum", "error_feedback_update",
           "svd_lowrank", "compress_lowrank", "decompress_lowrank",
           "lowrank_error_feedback", "lowrank_wire_bytes"]

_CHUNK = 256


def quantize_for_allreduce(x) -> Tuple[jax.Array, jax.Array]:
    """int8 payload + fp32 per-chunk scales (wire format)."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % _CHUNK
    blocks = jnp.pad(flat, (0, pad)).reshape(-1, _CHUNK)
    scale = jnp.maximum(jnp.max(jnp.abs(blocks), 1, keepdims=True) / 127.0,
                        1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def dequantize_after_allreduce(q, scale, shape):
    blocks = q.astype(jnp.float32) * scale[:, None]
    n = 1
    for d in shape:
        n *= d
    return blocks.reshape(-1)[:n].reshape(shape)


def compressed_psum(x, axis_name: str):
    """psum with int8 wire format (inside shard_map)."""
    q, s = quantize_for_allreduce(x)
    xq = dequantize_after_allreduce(q, s, x.shape)
    return jax.lax.psum(xq, axis_name)


def error_feedback_update(grad, residual):
    """EF: quantize (grad + residual); return (compressed, new residual)."""
    total = grad + residual
    q, s = quantize_for_allreduce(total)
    sent = dequantize_after_allreduce(q, s, grad.shape)
    return sent, total - sent


def wire_bytes(x) -> int:
    """Bytes on the wire for the compressed format vs fp32."""
    n = x.size
    chunks = -(-n // _CHUNK)
    return n + 4 * chunks  # int8 payload + fp32 scales


# --------------------------------------------------------------- low-rank --

def svd_lowrank(W, rank: int, *, apply_method: str = "auto",
                k_delay: int = 32):
    """Truncated SVD of a 2D array via the rotation-sequence SVD solver.

    Returns ``(U_r, s_r, Vt_r)`` with ``U_r (m, r)``, ``s_r (r,)``,
    ``Vt_r (r, n)`` — the best rank-``r`` approximation factors.  The
    singular vectors are accumulated from the solver's recorded
    ``RotationSequence`` waves through one cached ``SequencePlan`` per
    accumulator shape; ``apply_method``/``k_delay`` parameterize that
    plan-once/apply-many path (see ``repro.eig``).
    """
    from repro.eig import svd_givens  # lazy: parallel must not need eig

    if W.ndim != 2:
        raise ValueError(f"svd_lowrank expects a 2D array, got {W.shape}")
    r = min(int(rank), min(W.shape))
    U, s, Vt = svd_givens(W, apply_method=apply_method, k_delay=k_delay)
    return U[:, :r], s[:r], Vt[:r, :]


def compress_lowrank(W, rank: int, **svd_kw) -> Tuple[jax.Array, jax.Array]:
    """Rank-``r`` wire format for a 2D gradient: ``(P, Q)``.

    ``P = U_r * s_r`` (m, r) and ``Q = Vt_r`` (r, n);
    ``decompress_lowrank(P, Q) = P @ Q`` is the best rank-``r``
    approximation of ``W``.  ``svd_kw`` (``apply_method``, ``k_delay``)
    reaches the rotation-sequence application plan in
    :func:`svd_lowrank`.
    """
    U, s, Vt = svd_lowrank(W, rank, **svd_kw)
    return U * s[None, :], Vt


def decompress_lowrank(P, Q) -> jax.Array:
    return P @ Q


def lowrank_error_feedback(grad, residual, rank: int, **svd_kw):
    """EF-SGD with a low-rank code: compress ``grad + residual``.

    Returns ``(sent, new_residual)`` like :func:`error_feedback_update`;
    the discarded singular directions are carried to the next step.
    """
    total = grad + residual
    P, Q = compress_lowrank(total, rank, **svd_kw)
    sent = decompress_lowrank(P, Q)
    return sent, total - sent


def lowrank_wire_bytes(shape, rank: int, itemsize: int = 4) -> int:
    """Bytes on the wire for the ``(P, Q)`` format."""
    m, n = shape
    r = min(int(rank), m, n)
    return itemsize * r * (m + n)
