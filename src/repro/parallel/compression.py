"""Gradient compression for cross-pod communication.

``compressed_psum`` quantizes a tensor to int8 (per-chunk scales) before
an all-reduce-style exchange: on low-bandwidth cross-pod links (DCN) the
4x volume reduction dominates the quantization noise, which is further
suppressed by *error feedback* (the residual is carried to the next
step — standard EF-SGD).  Used via ``CompressedGradSync`` around the
data-parallel gradient reduction.

Implementation note: quantized values cannot be summed directly (scales
differ per shard), so the exchange is an all-to-all-free two-phase
ring-style reduction expressed with ``psum`` over dequantized chunks; the
bandwidth accounting (what would cross the wire) is the int8 payload +
fp32 scales, which the tests assert.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["quantize_for_allreduce", "dequantize_after_allreduce",
           "compressed_psum", "error_feedback_update"]

_CHUNK = 256


def quantize_for_allreduce(x) -> Tuple[jax.Array, jax.Array]:
    """int8 payload + fp32 per-chunk scales (wire format)."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % _CHUNK
    blocks = jnp.pad(flat, (0, pad)).reshape(-1, _CHUNK)
    scale = jnp.maximum(jnp.max(jnp.abs(blocks), 1, keepdims=True) / 127.0,
                        1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def dequantize_after_allreduce(q, scale, shape):
    blocks = q.astype(jnp.float32) * scale[:, None]
    n = 1
    for d in shape:
        n *= d
    return blocks.reshape(-1)[:n].reshape(shape)


def compressed_psum(x, axis_name: str):
    """psum with int8 wire format (inside shard_map)."""
    q, s = quantize_for_allreduce(x)
    xq = dequantize_after_allreduce(q, s, x.shape)
    return jax.lax.psum(xq, axis_name)


def error_feedback_update(grad, residual):
    """EF: quantize (grad + residual); return (compressed, new residual)."""
    total = grad + residual
    q, s = quantize_for_allreduce(total)
    sent = dequantize_after_allreduce(q, s, grad.shape)
    return sent, total - sent


def wire_bytes(x) -> int:
    """Bytes on the wire for the compressed format vs fp32."""
    n = x.size
    chunks = -(-n // _CHUNK)
    return n + 4 * chunks  # int8 payload + fp32 scales
