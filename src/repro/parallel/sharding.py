"""Logical-axis sharding rules (Megatron TP + ZeRO-3 FSDP + EP).

Model code annotates activations and parameters with *logical* axis names;
this module resolves them to mesh ``PartitionSpec``s via the active
``AxisRules``.  Outside a rules context every annotation is a no-op, so
the same model code runs single-device smoke tests and 512-chip dry-runs.

Default production rules:

  batch   -> ("pod", "data")        activations data-parallel
  heads / kv_heads / ff / vocab / experts -> "model"   tensor/expert parallel
  fsdp    -> parameters additionally shard their largest non-TP axis over
             ("pod", "data")  (ZeRO-3); optimizer state inherits

Sequence parallelism ("seq" -> "model") is an opt-in rule used by the
perf hillclimb.
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

__all__ = ["AxisRules", "axis_rules", "current_rules", "shard",
           "logical_to_spec", "param_spec", "DEFAULT_RULES"]

_state = threading.local()


@dataclass(frozen=True)
class AxisRules:
    """logical name -> mesh axis (or tuple of axes, or None)."""
    rules: Dict[str, object] = field(default_factory=dict)
    fsdp_axes: Tuple[str, ...] = ()     # axes used to shard params (ZeRO)
    mesh_shape: Dict[str, int] = field(default_factory=dict)

    def resolve(self, logical: Optional[str]):
        if logical is None:
            return None
        return self.rules.get(logical)


DEFAULT_RULES = AxisRules(
    rules={
        "batch": ("pod", "data"),
        "heads": "model",
        "kv_heads": "model",
        "ff": "model",
        "vocab": "model",
        "experts": "model",
        "seq": None,
        "embed": None,
    },
    fsdp_axes=("pod", "data"),
)


def current_rules() -> Optional[AxisRules]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def axis_rules(rules: Optional[AxisRules], mesh=None):
    prev = getattr(_state, "rules", None)
    prev_mesh = getattr(_state, "mesh", None)
    _state.rules = rules
    _state.mesh = mesh
    try:
        yield
    finally:
        _state.rules = prev
        _state.mesh = prev_mesh


def current_mesh():
    return getattr(_state, "mesh", None)


def _dedup(spec_axes, shape=None, rules=None):
    """Drop mesh axes already used earlier in the spec (GSPMD requirement)
    and, when ``shape`` is known, axes that do not divide the dimension."""
    used = set()
    out = []
    for i, a in enumerate(spec_axes):
        if a is None:
            out.append(None)
            continue
        axes = a if isinstance(a, tuple) else (a,)
        axes = tuple(x for x in axes if x not in used)
        if shape is not None and rules is not None:
            kept = []
            size = 1
            for x in axes:
                nx = rules.mesh_shape.get(x, 1)
                if shape[i] % (size * nx) == 0:
                    kept.append(x)
                    size *= nx
            axes = tuple(kept)
        used.update(axes)
        out.append(axes if len(axes) > 1 else (axes[0] if axes else None))
    return out


def logical_to_spec(logical: Tuple[Optional[str], ...],
                    rules: Optional[AxisRules] = None,
                    shape: Optional[Tuple[int, ...]] = None) -> P:
    rules = rules or current_rules()
    if rules is None:
        return P()
    return P(*_dedup([rules.resolve(l) for l in logical], shape, rules))


def shard(x, *logical: Optional[str]):
    """Annotate an activation with logical axes (no-op without rules)."""
    rules = current_rules()
    if rules is None:
        return x
    spec = logical_to_spec(logical, rules, shape=tuple(x.shape))
    mesh = current_mesh()
    if mesh is not None:
        spec = jax.sharding.NamedSharding(mesh, spec)
    return jax.lax.with_sharding_constraint(x, spec)


def param_spec(shape: Tuple[int, ...],
               logical: Tuple[Optional[str], ...],
               rules: Optional[AxisRules] = None) -> P:
    """PartitionSpec for a parameter: TP axes from rules + FSDP on the
    largest remaining dimension (ZeRO-3)."""
    rules = rules or current_rules()
    if rules is None:
        return P()
    resolved = [rules.resolve(l) for l in logical]
    # drop TP axes that do not divide their dimension first
    resolved = _dedup(resolved, shape, rules)
    if rules.fsdp_axes:
        used = set()
        for r in resolved:
            used.update(r if isinstance(r, tuple) else (r,))
        free = [i for i, r in enumerate(resolved) if r is None]
        if free:
            # largest free dim that divides the fsdp axis product
            fsdp_size = int(np.prod([rules.mesh_shape.get(a, 1)
                                     for a in rules.fsdp_axes])) or 1
            cand = sorted(free, key=lambda i: -shape[i])
            for i in cand:
                if shape[i] % max(fsdp_size, 1) == 0:
                    resolved[i] = tuple(
                        a for a in rules.fsdp_axes if a not in used)
                    break
    return P(*_dedup(resolved))
