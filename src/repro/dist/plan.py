"""Sharded execution as a first-class plan: :class:`ShardedSequencePlan`.

The distributed path rides the same plan-once/apply-many spine as
everything else: :func:`plan_sharded` resolves a mesh +
``PartitionSpec`` + backend **exactly once** into a frozen, serializable
:class:`ShardedSequencePlan`, whose ``apply``/``apply_batched`` then
execute row-sharded ``(m, n)`` and batched ``(b, m, n)`` targets through
**one fused ``rotseq_batched`` launch per shard** under ``shard_map``
(or one shard-local call of whatever backend the plan resolved).

Row sharding is communication-free on the stream side — rotations act
on column *pairs*, so row shards are independent and the result is
bit-identical to the replicated execution; the only wire traffic is
replicating the C/S/G wave panels once per plan, which is exactly the
setup-side communication term the §6 cost model now prices
(``repro.core.registry._comm_components``, ``docs/cost-model.md``).
``method="auto"`` therefore genuinely arbitrates **sharded-fused vs
replicated**: the planner resolves both the sharded (``devices=D``,
its own plan-cache class) and the replicated problem, compares their
comm-extended ``cost_components`` seconds, and freezes the winner into
the plan — small-``n`` problems stay replicated (the per-hop link
latency dominates), large-``n`` problems amortize the wire and shard.

Column-sharded (CAQR-style panel) targets delegate to
:mod:`repro.dist.colsharded`, which exchanges boundary planes once per
``k_b``-wave panel instead of per wave.

Autodiff: shard-local execution calls the planned ``custom_vjp`` pair
from :mod:`repro.core.sequence` *inside* ``shard_map``, so
``jax.grad`` through :meth:`ShardedSequencePlan.apply` runs the
transposed-sequence VJP shard-locally with zero extra collectives.

Observability: kernel-side launch accounting is tracer-guarded and
cannot fire under ``shard_map`` tracing, so the plan self-accounts
host-side — ``dist.launches_per_shard``, ``dist.comm_bytes``, and
roofline rows attributed with the same comm-extended components the
planner ranked by.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat, obs
from repro.core import registry
from repro.core.sequence import (RotationSequence, SequencePlan,
                                 planned_apply, planned_apply_batched,
                                 planned_run, stack_request_waves)

__all__ = ["ShardedSequencePlan", "plan_sharded",
           "SHARDED_PLAN_DICT_FORMAT"]


# sentinel method of degenerate (zero-rotation) plans, mirroring
# SequencePlan's identity dispatch
_IDENTITY = "identity"

# JSON format version of ShardedSequencePlan.to_dict
SHARDED_PLAN_DICT_FORMAT = 1


def _mesh_devices(mesh, axes) -> int:
    """Product of the mesh extents over ``axes`` (the shard count)."""
    if isinstance(axes, str):
        axes = (axes,)
    d = 1
    for a in axes:
        if a not in mesh.shape:
            raise ValueError(
                f"mesh has no axis {a!r}; axes are {tuple(mesh.shape)}")
        d *= mesh.shape[a]
    return int(d)


def plan_sharded(seq: RotationSequence, like=None, *, mesh,
                 row_axes=("data",), m: Optional[int] = None,
                 batch: Optional[int] = None, method: str = "auto",
                 autotune: bool = False, platform: Optional[str] = None,
                 shared_sequence: bool = True,
                 partition: str = "row", col_axis: str = "model",
                 n_b: Optional[int] = None, k_b: Optional[int] = None,
                 **kw) -> "ShardedSequencePlan":
    """Resolve mesh + specs + backend once into a frozen sharded plan.

    ``like``/``m``/``batch`` describe the *global* target exactly as in
    :meth:`RotationSequence.plan` (a 3D ``like`` supplies the batch).
    ``mesh`` is required; ``row_axes`` names the mesh axes the row
    dimension shards over (``devices`` = their extent product).

    ``method="auto"`` resolves **two** problems through the registry —
    the sharded one (``devices=D``, keyed into its own ``"sharded"``
    plan-cache class that never transfers to single-device keys) and
    the replicated one — and freezes whichever the comm-extended cost
    model prices cheaper (:attr:`ShardedSequencePlan.execute_sharded`).
    A named ``method`` must be shard_map-capable
    (``Capability.supports_sharding``) and always executes sharded.

    ``partition="column"`` plans the CAQR-style column-panel pipeline
    instead (plain 2D rotation sequences only); ``col_axis`` names its
    mesh axis and ``n_b``/``k_b`` its panel tiles.
    """
    if mesh is None:
        raise TypeError("plan_sharded() missing required argument: 'mesh'")
    if partition not in ("row", "column"):
        raise ValueError(f"partition must be 'row' or 'column', "
                         f"got {partition!r}")
    like_shape = getattr(like, "shape", None)
    if like_shape is not None and len(like_shape) == 3:
        if batch is None:
            batch = like_shape[0]
        if m is None:
            m = like_shape[1]
    if m is None:
        m = like_shape[0] if like_shape is not None else max(seq.n, 1)
    batch = 1 if batch is None else max(1, int(batch))
    dtype = getattr(like, "dtype", None) or seq.dtype
    n, k = seq.n, seq.k

    if partition == "column":
        devices = _mesh_devices(mesh, col_axis)
        if seq.sign is not None or seq.reflect:
            raise ValueError(
                "column-sharded pipeline supports plain rotation "
                "sequences only")
        planned = dict(kw)
        planned["n_b"] = 64 if n_b is None else n_b
        planned["k_b"] = 16 if k_b is None else k_b
        col_method = method if method != "auto" else "blocked"
        return ShardedSequencePlan(
            sequence=seq, mesh=mesh, row_axes=_as_tuple(row_axes),
            method=col_method, kwargs=tuple(sorted(planned.items())),
            plan=None, devices=devices, execute_sharded=True,
            partition="column", col_axis=col_axis)

    devices = _mesh_devices(mesh, row_axes)
    if n < 2 or k < 1 or m < 1:
        return ShardedSequencePlan(
            sequence=seq, mesh=mesh, row_axes=_as_tuple(row_axes),
            method=_IDENTITY, kwargs=(), plan=None, devices=devices,
            execute_sharded=False)

    signs = seq.sign is not None
    if method != "auto":
        spec = registry.get_backend(method)  # raises on unknown
        if signs and not spec.capability.supports_signs:
            raise ValueError(
                f"method {method!r} does not support per-entry signs")
        if not spec.capability.supports_sharding:
            raise ValueError(
                f"method {method!r} cannot run inside shard_map")
        planned = dict(kw)
        if spec.candidates is not registry.no_tiles:
            planned["n_b"] = 64 if n_b is None else n_b
            planned["k_b"] = 16 if k_b is None else k_b
        return ShardedSequencePlan(
            sequence=seq, mesh=mesh, row_axes=_as_tuple(row_axes),
            method=method, kwargs=tuple(sorted(planned.items())),
            plan=None, devices=devices, execute_sharded=True)

    with obs.span("dist.plan", m=m, n=n, k=k, batch=batch,
                  devices=devices) as sp:
        sh_plan = registry.select_plan(
            m, n, k, dtype=dtype, platform=platform, signs=signs,
            sharded=True, devices=devices, batch=batch,
            shared_sequence=shared_sequence, live_planes=seq.k_live,
            autotune=autotune)
        rep_plan = registry.select_plan(
            m, n, k, dtype=dtype, platform=platform, signs=signs,
            batch=batch, shared_sequence=shared_sequence,
            live_planes=seq.k_live, autotune=autotune)
        sh_s, rep_s = modeled_crossover(
            m, n, k, devices=devices, dtype=dtype, platform=platform,
            signs=signs, batch=batch, shared_sequence=shared_sequence,
            live_planes=seq.k_live, sharded_plan=sh_plan,
            replicated_plan=rep_plan)
        execute_sharded = sh_s < rep_s
        chosen = sh_plan if execute_sharded else rep_plan
        sp.set(method=chosen.method, sharded=execute_sharded)
    planned = chosen.kwargs()
    if n_b is not None:
        planned["n_b"] = n_b
    if k_b is not None:
        planned["k_b"] = k_b
    planned.update(kw)
    return ShardedSequencePlan(
        sequence=seq, mesh=mesh, row_axes=_as_tuple(row_axes),
        method=chosen.method, kwargs=tuple(sorted(planned.items())),
        plan=chosen, devices=devices, execute_sharded=execute_sharded)


def modeled_crossover(m: int, n: int, k: int, *, devices: int,
                      dtype="float32", platform: Optional[str] = None,
                      signs: bool = False, batch: int = 1,
                      shared_sequence: bool = True,
                      live_planes: Optional[int] = None,
                      sharded_plan: Optional[registry.Plan] = None,
                      replicated_plan: Optional[registry.Plan] = None
                      ) -> Tuple[float, float]:
    """``(sharded_seconds, replicated_seconds)`` the arbitration compares.

    Both sides are the registered cost models via ``cost_components``
    (the sharded side carries the comm term and per-shard stream), so a
    test — or a curious caller — can reproduce the ``method="auto"``
    sharded-vs-replicated decision to the digit.
    """
    platform = platform or compat.default_platform()
    if sharded_plan is None:
        sharded_plan = registry.select_plan(
            m, n, k, dtype=dtype, platform=platform, signs=signs,
            sharded=True, devices=devices, batch=batch,
            shared_sequence=shared_sequence, live_planes=live_planes)
    if replicated_plan is None:
        replicated_plan = registry.select_plan(
            m, n, k, dtype=dtype, platform=platform, signs=signs,
            batch=batch, shared_sequence=shared_sequence,
            live_planes=live_planes)
    p_sh = registry.Problem(
        m=m, n=n, k=k, dtype=str(jnp.dtype(dtype)), platform=platform,
        signs=signs, sharded=True, batch=batch,
        shared_sequence=shared_sequence, live_planes=live_planes,
        devices=devices)
    p_rep = dataclasses.replace(p_sh, sharded=False, devices=1)
    sh_s = registry.cost_components(
        sharded_plan.method, p_sh, sharded_plan)["seconds"]
    rep_s = registry.cost_components(
        replicated_plan.method, p_rep, replicated_plan)["seconds"]
    return float(sh_s), float(rep_s)


def _as_tuple(axes) -> Tuple[str, ...]:
    return (axes,) if isinstance(axes, str) else tuple(axes)


@dataclasses.dataclass(frozen=True, eq=False)
class ShardedSequencePlan:
    """A frozen sharded dispatch decision bound to one sequence + mesh.

    Mirrors :class:`~repro.core.sequence.SequencePlan` — frozen,
    rebindable (:meth:`rebind`), serializable (:meth:`to_dict` /
    :meth:`from_dict`), obs-instrumented, differentiable w.r.t. the
    target through the planned ``custom_vjp`` — with the mesh, the
    partition specs, and the sharded-vs-replicated arbitration resolved
    exactly once at plan time.

    ``execute_sharded=False`` (an ``method="auto"`` outcome) means the
    comm-extended cost model priced the replicated execution cheaper:
    ``apply`` then runs the inner single-device :class:`SequencePlan`
    unchanged.  Named methods always execute sharded.
    """

    sequence: RotationSequence
    mesh: Any
    row_axes: Tuple[str, ...]
    method: str
    kwargs: Tuple[Tuple[str, Any], ...]
    plan: Optional[registry.Plan] = None
    devices: int = 1
    execute_sharded: bool = True
    partition: str = "row"
    col_axis: str = "model"

    def __repr__(self) -> str:
        return (f"ShardedSequencePlan(method={self.method!r}, "
                f"devices={self.devices}, "
                f"sharded={self.execute_sharded}, "
                f"partition={self.partition!r}, "
                f"kwargs={dict(self.kwargs)}, seq={self.sequence!r})")

    # -- inner single-device plan (replicated path / shard-local fields) --
    def _inner(self) -> SequencePlan:
        return SequencePlan(self.sequence, self.method, self.kwargs,
                            self.plan)

    # -- execution --------------------------------------------------------
    def apply(self, A, *, direct: bool = False):
        """Apply the planned sequence to a ``(m, n)`` target.

        Sharded execution shards rows over ``row_axes`` (``m`` must
        divide by ``devices``) and runs **one** shard-local planned
        backend call per shard; ``direct=True`` keeps the backend's
        native autodiff instead of the transposed-sequence
        ``custom_vjp`` (the ``apply_direct`` analogue).
        """
        if self.method == _IDENTITY:
            return A
        if self.partition == "column":
            return self._column_sharded(A)
        if not self.execute_sharded:
            inner = self._inner()
            return inner.apply_direct(A) if direct else inner.apply(A)
        self._check_rows(A.shape[-2])
        if not obs.enabled() or compat.is_tracer(A):
            return self._row_sharded_2d(A, direct)
        with obs.span("dist.apply", method=self.method,
                      devices=self.devices, m=int(A.shape[0]),
                      n=int(A.shape[1])):
            t0 = obs.timing.now()
            out = jax.block_until_ready(self._row_sharded_2d(A, direct))
            dt = obs.timing.now() - t0
        self._record_dispatch(A, dt, launches=1)
        return out

    __call__ = apply

    def apply_batched(self, A, sequences=None, *, direct: bool = False):
        """Apply to a batched ``(b, m, n)`` target, sharding rows.

        The batch axis is replicated and ``m`` shards over
        ``row_axes`` — every shard sees all ``b`` targets' row slices,
        so a fused-capable plan (``rotseq_batched``) executes the whole
        bucket in exactly **one launch per shard**.  ``sequences``
        carries per-request waves exactly as in
        :meth:`SequencePlan.apply_batched` (stacked host-side,
        replicated across the mesh).
        """
        A = jnp.asarray(A)
        if A.ndim != 3:
            raise ValueError(
                f"apply_batched expects A of shape (b, m, n); "
                f"got {A.shape} — use apply() for a single target")
        if self.method == _IDENTITY:
            return A
        if self.partition == "column":
            raise ValueError(
                "column-sharded plans take 2D targets; batch rows "
                "instead (partition='row')")
        if not self.execute_sharded:
            return self._inner().apply_batched(A, sequences=sequences,
                                               direct=direct)
        self._check_rows(A.shape[1])
        launches = self._launches_per_shard(int(A.shape[0]))
        if not obs.enabled() or compat.is_tracer(A):
            return self._row_sharded_batched(A, sequences, direct)
        with obs.span("dist.apply_batched", method=self.method,
                      devices=self.devices, batch=int(A.shape[0]),
                      m=int(A.shape[1]), n=int(A.shape[2])):
            t0 = obs.timing.now()
            out = jax.block_until_ready(
                self._row_sharded_batched(A, sequences, direct))
            dt = obs.timing.now() - t0
        self._record_dispatch(A, dt, launches=launches,
                              shared=sequences is None)
        return out

    # -- sharded executors ------------------------------------------------
    # The shard_map closure + its jit compilation are resolved once per
    # (mode, direct, sign-structure) and cached on the instance —
    # plan-once/apply-many must not pay a re-trace per application.
    # ``rebind`` carries the cache across same-structure rebinds (the
    # closures see waves only as call arguments).
    def _cached_fn(self, key, builder):
        cache = self.__dict__.get("_fn_cache")
        if cache is None:
            cache = {}
            object.__setattr__(self, "_fn_cache", cache)
        fn = cache.get(key)
        if fn is None:
            fn = cache[key] = builder()
        return fn

    def _row_sharded_2d(self, A, direct: bool):
        fn = self._cached_fn(("2d", direct),
                             lambda: self._build_2d(direct))
        return fn(A, self.sequence)

    def _build_2d(self, direct: bool):
        run = planned_run if direct else planned_apply
        method, kwargs = self.method, self.kwargs
        reflect = self.sequence.reflect

        def local_fn(a, sq):
            return run(method, kwargs, reflect, a, sq.cos, sq.sin, sq.sign)

        seq_specs = jax.tree_util.tree_map(lambda _: P(None, None),
                                           self.sequence)
        return jax.jit(compat.shard_map(
            local_fn, mesh=self.mesh,
            in_specs=(P(self.row_axes, None), seq_specs),
            out_specs=P(self.row_axes, None)))

    def _row_sharded_batched(self, A, sequences, direct: bool):
        seq = self.sequence
        b = int(A.shape[0])

        if sequences is None:
            C, S, G = seq.cos, seq.sin, seq.sign
            shared = True
        else:
            seqs = list(sequences)
            if len(seqs) != b:
                raise ValueError(
                    f"{len(seqs)} sequences for a batch of {b} targets")
            plan_signed = seq.sign is not None
            for s in seqs:
                if not isinstance(s, RotationSequence):
                    raise TypeError(
                        f"expected RotationSequence, got {type(s)}")
                if tuple(s.shape) != tuple(seq.shape):
                    raise ValueError(
                        f"sequence shape {s.shape} != plan shape "
                        f"{seq.shape}; pad_to a stable wave count first")
                if not plan_signed and (s.sign is not None
                                        or s.reflect != seq.reflect):
                    raise ValueError(
                        "mixed sign/reflect structure in one batch; plan "
                        "on a sign-carrying representative first")
            C, S, G = stack_request_waves(seqs, plan_signed)
            shared = False

        key = ("batched", direct, shared, G is None)
        fn = self._cached_fn(
            key, lambda: self._build_batched(direct, shared, G is None))
        if G is None:
            return fn(A, C, S)
        return fn(A, C, S, G)

    def _build_batched(self, direct: bool, shared: bool, g_none: bool):
        cap = registry.get_backend(self.method).capability
        method, kwargs = self.method, self.kwargs
        reflect = self.sequence.reflect
        run = planned_run if direct else planned_apply
        run_fused = planned_run if direct else planned_apply_batched

        def local_batched(a, c, s, g):
            # mirror SequencePlan._apply_batched_impl, shard-locally:
            # one fused launch / one flattened call / vmap / loop
            if cap.batch_via == "fused":
                return run_fused(method, kwargs, reflect, a, c, s, g)
            if shared and cap.batch_via == "flatten":
                bl, ml, nl = a.shape
                out = run(method, kwargs, reflect,
                          a.reshape(bl * ml, nl), c, s, g)
                return out.reshape(bl, ml, nl)
            if shared:
                return jax.vmap(lambda ai: run(method, kwargs, reflect,
                                               ai, c, s, g))(a)
            if cap.supports_vmap:
                in_axes = (0, 0, 0, None if g is None else 0)
                return jax.vmap(
                    lambda ai, ci, si, gi: run(method, kwargs, reflect,
                                               ai, ci, si, gi),
                    in_axes=in_axes)(a, c, s, g)
            return jnp.stack([
                run(method, kwargs, reflect, a[i], c[i], s[i],
                    None if g is None else g[i])
                for i in range(a.shape[0])])

        wave_spec = P(None, None) if shared else P(None, None, None)
        A_spec = P(None, self.row_axes, None)
        if g_none:
            return jax.jit(compat.shard_map(
                lambda a, c, s: local_batched(a, c, s, None),
                mesh=self.mesh,
                in_specs=(A_spec, wave_spec, wave_spec),
                out_specs=A_spec))
        return jax.jit(compat.shard_map(
            local_batched, mesh=self.mesh,
            in_specs=(A_spec, wave_spec, wave_spec, wave_spec),
            out_specs=A_spec))

    def _column_sharded(self, A):
        from repro.dist.colsharded import rot_sequence_column_sharded_padded
        kw = dict(self.kwargs)
        return rot_sequence_column_sharded_padded(
            A, self.sequence, self.mesh, col_axis=self.col_axis,
            n_b=kw.get("n_b", 64), k_b=kw.get("k_b", 16),
            method=self.method)

    # -- bookkeeping ------------------------------------------------------
    def _check_rows(self, m: int) -> None:
        if int(m) % max(1, self.devices) != 0:
            raise ValueError(
                f"row count {m} does not divide over {self.devices} "
                f"shards ({self.row_axes}); pad the target rows")

    def _launches_per_shard(self, b: int) -> int:
        cap = registry.get_backend(self.method).capability
        if cap.batch_via == "fused":
            return 1
        if cap.batch_via == "flatten" or cap.supports_vmap:
            return 1
        return b

    def comm_components(self, *, batch: int = 1,
                        shared_sequence: bool = True, m: int = 0) -> dict:
        """The plan's comm term (``cost_components``-consistent)."""
        seq = self.sequence
        problem = registry.Problem(
            m=max(1, int(m) or seq.n), n=seq.n, k=seq.k,
            dtype=str(seq.dtype), platform=compat.default_platform(),
            signs=seq.sign is not None, sharded=True, batch=batch,
            shared_sequence=shared_sequence, live_planes=seq.k_live,
            devices=self.devices)
        return registry._comm_components(problem)

    def _record_dispatch(self, A, measured_s: float, *, launches: int,
                         shared: bool = True) -> None:
        """Host-side obs attribution of one completed sharded dispatch.

        The fused kernel's own launch accounting is tracer-guarded and
        never fires under ``shard_map`` tracing, so the dist layer is
        the accounting authority for its dispatches: comm bytes and
        launches-per-shard come from the same comm-extended model the
        planner ranked with.
        """
        seq = self.sequence
        if A.ndim == 3:
            b, m = int(A.shape[0]), int(A.shape[1])
        else:
            b, m = 1, int(A.shape[0])
        kw = dict(self.kwargs)
        problem = registry.Problem(
            m=m, n=seq.n, k=seq.k, dtype=str(A.dtype),
            platform=compat.default_platform(),
            signs=seq.sign is not None, sharded=True, batch=b,
            shared_sequence=shared, live_planes=seq.k_live,
            devices=self.devices)
        rplan = self.plan if self.plan is not None else registry.Plan(
            method=self.method, n_b=kw.get("n_b"), k_b=kw.get("k_b"),
            m_blk=kw.get("m_blk"))
        try:
            comp = registry.cost_components(self.method, problem, rplan)
        except ValueError:
            comp = {"flops": 0.0, "bytes": 0.0, "seconds": 0.0,
                    "setup": {"seconds": 0.0}, "stream": {"seconds": 0.0},
                    "comm": {"bytes": 0.0, "seconds": 0.0}}
        comm_bytes = comp.get("comm", {}).get("bytes", 0.0)
        obs.roofline.record_dispatch(
            backend=self.method, m_total=problem.m_total, n=seq.n,
            k=seq.k, batch=b, dtype=str(A.dtype),
            tile={key: val for key, val in kw.items()
                  if key in ("n_b", "k_b", "m_blk")},
            planes_live=problem.planes_live,
            planes_total=problem.planes_total,
            predicted_flops=comp["flops"], predicted_bytes=comp["bytes"],
            predicted_s=comp["seconds"], measured_s=measured_s,
            predicted_setup_s=comp["setup"]["seconds"],
            predicted_stream_s=comp["stream"]["seconds"],
            shared_sequence=shared, comm_bytes=comm_bytes,
            launches_per_shard=launches)
        obs.inc("dist.applies")
        obs.inc("dist.comm_bytes", comm_bytes)
        obs.gauge("dist.devices", self.devices)
        obs.gauge("dist.launches_per_shard", launches)
        obs.observe("dist.apply_seconds", measured_s)

    # -- rebinding / serialization ----------------------------------------
    def rebind(self, sequence: RotationSequence) -> "ShardedSequencePlan":
        """Bind the frozen decision to a new same-shape sequence."""
        old = self.sequence
        if sequence.shape != old.shape:
            raise ValueError(
                f"rebind needs matching wave shape {old.shape}; "
                f"got {sequence.shape}")
        if (sequence.sign is not None) != (old.sign is not None) \
                and self.method != _IDENTITY:
            spec = registry.get_backend(self.method)
            if sequence.sign is not None \
                    and not spec.capability.supports_signs:
                raise ValueError(
                    f"plan method {self.method!r} cannot carry per-entry "
                    f"signs; re-plan the sign-carrying sequence")
        new = dataclasses.replace(self, sequence=sequence)
        # the jitted shard_map closures see waves only as call
        # arguments, so a same-structure rebind reuses the compiled fns
        cache = self.__dict__.get("_fn_cache")
        if cache is not None \
                and sequence.reflect == old.reflect \
                and (sequence.sign is None) == (old.sign is None):
            object.__setattr__(new, "_fn_cache", cache)
        return new

    def to_dict(self) -> dict:
        """Serialize the sharded dispatch decision (not waves, not mesh).

        Mirrors :meth:`SequencePlan.to_dict` — JAX-version-keyed, wave
        signature included — plus the mesh *shape contract*: device
        count, row axes, partition.  The mesh itself is process state;
        :meth:`from_dict` rebinds to a live mesh and rejects one whose
        extent over the stored axes differs.
        """
        seq = self.sequence
        d = {
            "format": SHARDED_PLAN_DICT_FORMAT,
            "jax": registry._jax_version_str(),
            "method": self.method,
            "kwargs": dict(self.kwargs),
            "devices": self.devices,
            "row_axes": list(self.row_axes),
            "partition": self.partition,
            "col_axis": self.col_axis,
            "execute_sharded": bool(self.execute_sharded),
            "shape": list(seq.shape),
            "dtype": str(seq.dtype),
            "signed": seq.sign is not None,
            "reflect": bool(seq.reflect),
        }
        if self.plan is not None:
            d["plan"] = {"method": self.plan.method, "n_b": self.plan.n_b,
                         "k_b": self.plan.k_b, "m_blk": self.plan.m_blk,
                         "est_seconds": self.plan.est_seconds,
                         "source": self.plan.source}
        return d

    @classmethod
    def from_dict(cls, d: dict, sequence: RotationSequence,
                  mesh) -> "ShardedSequencePlan":
        """Rebuild a frozen sharded plan bound to ``sequence`` + ``mesh``.

        Raises ``ValueError`` on any mismatch (treat as a cache miss):
        format/JAX version, wave signature, unregistered backend, or a
        mesh whose extent over the stored axes is not the stored device
        count — a sharded decision never transfers across mesh sizes,
        exactly like its plan-cache class.
        """
        if d.get("format") != SHARDED_PLAN_DICT_FORMAT:
            raise ValueError(
                f"unsupported ShardedSequencePlan dict format "
                f"{d.get('format')!r}")
        jax_now = registry._jax_version_str()
        if d.get("jax") != jax_now:
            raise ValueError(
                f"plan serialized under JAX {d.get('jax')!r}; running "
                f"{jax_now}")
        if tuple(d.get("shape", ())) != tuple(sequence.shape):
            raise ValueError(
                f"plan serialized for wave shape {d.get('shape')}; "
                f"sequence has {sequence.shape}")
        if d.get("signed", False) != (sequence.sign is not None) \
                or d.get("reflect", False) != bool(sequence.reflect):
            raise ValueError(
                "plan serialized for a different sign/reflect structure")
        if d.get("dtype") != str(sequence.dtype):
            raise ValueError(
                f"plan serialized for dtype {d.get('dtype')!r}; "
                f"sequence is {sequence.dtype}")
        partition = d.get("partition", "row")
        row_axes = tuple(d.get("row_axes", ("data",)))
        col_axis = d.get("col_axis", "model")
        axes = col_axis if partition == "column" else row_axes
        devices = int(d.get("devices", 1))
        if _mesh_devices(mesh, axes) != devices:
            raise ValueError(
                f"plan serialized for {devices} devices over {axes!r}; "
                f"mesh has {_mesh_devices(mesh, axes)} — sharded "
                f"decisions never transfer across mesh sizes")
        method = d["method"]
        if method != _IDENTITY:
            spec = registry.get_backend(method)  # raises on unknown
            if sequence.sign is not None \
                    and not spec.capability.supports_signs:
                raise ValueError(
                    f"serialized method {method!r} cannot carry signs")
        kwargs = tuple(sorted(d.get("kwargs", {}).items()))
        plan = None
        pd = d.get("plan")
        if pd is not None:
            plan = registry.Plan(
                method=str(pd.get("method", method)), n_b=pd.get("n_b"),
                k_b=pd.get("k_b"), m_blk=pd.get("m_blk"),
                est_seconds=float(pd.get("est_seconds", 0.0)),
                source="persisted")
        return cls(sequence=sequence, mesh=mesh, row_axes=row_axes,
                   method=method, kwargs=kwargs, plan=plan,
                   devices=devices,
                   execute_sharded=bool(d.get("execute_sharded", True)),
                   partition=partition, col_axis=col_axis)
