"""Column-sharded (CAQR-style panel) application of rotation sequences.

Each device owns a contiguous column slab of the target.  A band of
``k_b`` waves — one *panel* in the communication-avoiding sense of
Demmel–Grigori–Hoemmen–Langou (CAQR, arXiv 0809.2407) — must sweep
left-to-right across devices, so bands are *pipelined*: at superstep
``s`` device ``d`` processes band ``s - d``, and boundary planes are
exchanged **once per panel**, not once per wave, via three small
``collective_permute`` halos:

  - the ``(m_loc, k_b)`` partially-rotated **carry** columns (rightward),
  - one column of pre-band state (leftward) so the sweep can consume its
    right-neighbour's first column,
  - the ``(m_loc, k_b - 1)`` **realign** block (leftward), because the
    band sweep emits finalized columns shifted by ``k_b - 1``.

Per superstep each device communicates ``O(m_loc * k_b)`` elements
versus the ``O(m_loc * n_loc)`` it computes on — communication-efficient
in the same ``k_b / n_b`` sense as the paper's cache analysis (SS1.2),
with ICI links playing the role of the memory bus.  Pipeline
utilization is ``B / (B + D - 1)`` for ``B`` bands over ``D`` devices;
idle devices run no-op (identity-rotation) tiles so the program stays
SPMD-uniform.

This module is the drift-coordinate pipeline formerly hosted in
``repro.core.distributed`` (now a thin compat wrapper); the row-sharded
and batched fused paths live in :mod:`repro.dist.plan`.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.accumulate import accumulate_tile_factors
from repro.core.blocked import apply_tile
from repro.core.sequence import RotationSequence

__all__ = [
    "rot_sequence_column_sharded",
    "rot_sequence_column_sharded_padded",
    "column_sharded_comm_bytes",
]


def _require_sequence(seq, mesh, who: str):
    """Typed arguments only: the raw ``(A, C, S, mesh)`` positional form
    was removed after its deprecation release (PR 10)."""
    if not isinstance(seq, RotationSequence):
        raise TypeError(
            f"{who}(A, seq, mesh, ...) requires a RotationSequence; the "
            f"deprecated raw-array form {who}(A, C, S, mesh) was removed "
            f"— wrap the waves: RotationSequence(C, S)")
    if mesh is None:
        raise TypeError(f"{who}() missing required argument: 'mesh'")
    return seq, mesh


def _pack_local(C, S, c0, k_b, n_b, T_tot, p0):
    """Sheared tiles for one band over a device-local diagonal range.

    ``c0`` may be a traced device offset; gathers handle it.  Returns
    ``(T_tot, n_b, k_b)`` tiles covering diagonals ``[c0, c0 + T_tot*n_b)``.
    """
    J, k = C.shape
    u = c0 + jnp.arange(T_tot * n_b)
    p = jnp.arange(k_b)
    jg = u[:, None] - p[None, :]
    pg = p0 + p
    valid = (jg >= 0) & (jg < J) & (pg < k)[None, :]
    jc = jnp.clip(jg, 0, J - 1)
    pc = jnp.clip(pg, 0, k - 1)
    Ct = jnp.where(valid, C[jc, pc], 1.0).astype(C.dtype)
    St = jnp.where(valid, S[jc, pc], 0.0).astype(S.dtype)
    Gt = jnp.full_like(Ct, -1.0)
    shape = (T_tot, n_b, k_b)
    return Ct.reshape(shape), St.reshape(shape), Gt.reshape(shape)


def _sweep(X0carry, fresh_tiles, Ct, St, Gt, use_mxu: bool):
    """Scan tiles with carry; returns (final_carry, out_tiles)."""
    if use_mxu:
        Q = accumulate_tile_factors(Ct, St, Gt, dtype=X0carry.dtype)

        def step(carry, xs):
            q, ft = xs
            X = jnp.concatenate([carry, ft], axis=1)
            X = jnp.dot(X, q,
                        preferred_element_type=jnp.float32).astype(X.dtype)
            n_b = ft.shape[1]
            return X[:, n_b:], X[:, :n_b]

        return jax.lax.scan(step, X0carry, (Q, fresh_tiles))

    def step(carry, xs):
        ct, st, gt, ft = xs
        X = jnp.concatenate([carry, ft], axis=1)
        X = apply_tile(X, ct, st, gt)
        n_b = ft.shape[1]
        return X[:, n_b:], X[:, :n_b]

    return jax.lax.scan(step, X0carry, (Ct, St, Gt, fresh_tiles))


def rot_sequence_column_sharded(A, seq, mesh=None, *,
                                col_axis: str = "model",
                                n_b: int = 64, k_b: int = 16,
                                row_axes=(), method: str = "blocked"):
    """Column-sharded pipelined application of a :class:`RotationSequence`.

    Drift-coordinate scheme: each band's sweep emits its output shifted
    right by ``delta = k_b - 1`` state columns (the wavefront's natural
    output offset), so after band ``pb`` the device state holds matrix
    column ``i - pb*delta`` at state index ``i``.  Content drifts through
    right padding and is sliced back once at the end — no per-band
    realignment collective is needed.

    Each superstep is split in two phases so the pipeline needs only a
    one-column look-ahead halo: every device first applies *tile 0* of its
    current band, permutes that tile's first output column leftward (the
    right-neighbour value the *previous*-band device needs for its last
    tile), then sweeps its remaining tiles.

    Padding requirements (see :func:`rot_sequence_column_sharded_padded`
    for the public wrapper): global width ``W = D * n_loc`` with
    ``n_loc = T_loc * n_b``, ``T_loc >= 2`` and ``W >= n + B * (k_b - 1)``.
    """
    seq, mesh = _require_sequence(seq, mesh, "rot_sequence_column_sharded")
    C, S = seq.cos, seq.sin
    if seq.sign is not None or seq.reflect:
        raise ValueError(
            "column-sharded pipeline supports plain rotation sequences "
            "only (no per-entry signs / reflectors)")
    m, W = A.shape
    J, k = C.shape
    D = mesh.shape[col_axis]
    assert W % D == 0, (W, D)
    n_loc = W // D
    assert n_loc % n_b == 0, (n_loc, n_b)
    T_loc = n_loc // n_b
    assert T_loc >= 2, "need n_loc >= 2 * n_b for the split superstep"
    delta = k_b - 1
    B = math.ceil(k / k_b)
    assert W >= (J + 1) + B * delta, "insufficient drift padding"
    use_mxu = method == "accumulated"

    def device_fn(A_loc, C_full, S_full):
        d = jax.lax.axis_index(col_axis)
        D_ = D
        m_loc = A_loc.shape[0]
        right = [(i, (i + 1) % D_) for i in range(D_)]
        left = [(i, (i - 1) % D_) for i in range(D_)]

        def superstep(s, state):
            A_cur, carry_recv = state
            pb = s - d
            active = (pb >= 0) & (pb < B)
            pb_c = jnp.clip(pb, 0, B - 1)

            # rotations for this device's diagonal range, in drifted state
            # coordinates: state index i <-> matrix column i - pb*delta
            c0 = d * n_loc - pb_c * delta
            Ct, St, Gt = _pack_local(
                C_full, S_full, c0, k_b, n_b, T_loc, pb_c * k_b
            )
            Ct = jnp.where(active, Ct, jnp.ones_like(Ct))
            St = jnp.where(active, St, jnp.zeros_like(St))

            synth = jnp.concatenate(
                [jnp.zeros((m_loc, k_b - 1), A_loc.dtype), A_cur[:, :1]],
                axis=1,
            )
            carry_in = jnp.where(d == 0, synth, carry_recv)

            # --- phase 1: tile 0 (consumes only own fresh columns) ---
            fresh_own = A_cur[:, 1:]  # n_loc - 1 columns
            carry1, out0 = _sweep(
                carry_in, fresh_own[:, :n_b][None, :, :],
                Ct[:1], St[:1], Gt[:1], use_mxu)
            out0 = out0[0]  # (m_loc, n_b)

            # --- phase 2: halo = neighbour's tile-0 first output column
            # (post-its-band state), or its untouched slab head if the
            # neighbour is idle this superstep ---
            send = jnp.where(active, out0[:, :1], A_cur[:, :1])
            halo = jax.lax.ppermute(send, col_axis, left)
            halo = jnp.where(d == D_ - 1, jnp.zeros_like(halo), halo)

            # --- phase 3: remaining T_loc - 1 tiles ---
            fresh_rest = jnp.concatenate(
                [fresh_own[:, n_b:], halo], axis=1)
            rest_tiles = fresh_rest.reshape(
                m_loc, T_loc - 1, n_b).transpose(1, 0, 2)
            carry_out, out_rest = _sweep(
                carry1, rest_tiles, Ct[1:], St[1:], Gt[1:], use_mxu)
            O = jnp.concatenate(
                [out0[None], out_rest], axis=0
            ).transpose(1, 0, 2).reshape(m_loc, n_loc)

            A_new = jnp.where(active, O, A_cur)
            carry_next = jax.lax.ppermute(carry_out, col_axis, right)
            return (A_new, carry_next)

        carry0 = jnp.zeros((m_loc, k_b), A_loc.dtype)
        # match the varying-manual-axes type of the slab (plus the pipe
        # axis the ppermute varies over) so the fori carry types agree;
        # identity on JAX versions without vma tracking (repro.compat)
        carry0 = compat.pvary_like(carry0, A_loc, extra=(col_axis,))
        A_fin, _ = jax.lax.fori_loop(
            0, B + D_ - 1, superstep, (A_loc, carry0)
        )
        return A_fin

    row_spec = row_axes if row_axes else None
    fn = compat.shard_map(
        device_fn,
        mesh=mesh,
        in_specs=(P(row_spec, col_axis), P(None, None), P(None, None)),
        out_specs=P(row_spec, col_axis),
    )
    return fn(A, C, S)


def rot_sequence_column_sharded_padded(A, seq, mesh=None, *,
                                       col_axis: str = "model",
                                       n_b: int = 64, k_b: int = 16,
                                       row_axes=(),
                                       method: str = "blocked"):
    """Public wrapper: pads ``A`` for drift + divisibility, slices back."""
    seq, mesh = _require_sequence(seq, mesh,
                                  "rot_sequence_column_sharded_padded")
    m, n = A.shape
    J, k = seq.shape
    assert J == n - 1
    D = mesh.shape[col_axis]
    delta = k_b - 1
    B = math.ceil(k / k_b)
    # choose n_loc: multiple of n_b, >= 2*n_b, and D*n_loc >= n + B*delta
    need = n + B * delta
    n_loc = max(2 * n_b, n_b * math.ceil(need / (D * n_b)))
    W = D * n_loc
    Ap = jnp.pad(A, ((0, 0), (0, W - n)))
    out = rot_sequence_column_sharded(
        Ap, seq, mesh, col_axis=col_axis, n_b=n_b, k_b=k_b,
        row_axes=row_axes, method=method,
    )
    return jax.lax.slice_in_dim(out, B * delta, B * delta + n, axis=1)


def _live_waves(sequence: RotationSequence) -> int:
    """Count of waves holding at least one live (non-identity) plane.

    Mirrors the fused kernel's liveness rule: an entry is dead iff it is
    the exact identity *rotation* ``(c, s, g) = (1, 0, -1)`` — padded
    reflectors are live (det -1), so sign-carrying entries are dead only
    where the sign marks a rotation.
    """
    import numpy as np

    C = np.asarray(sequence.cos)
    S = np.asarray(sequence.sin)
    if sequence.sign is not None:
        G = np.asarray(sequence.sign)
    else:
        fill = 1.0 if sequence.reflect else -1.0
        G = np.full_like(C, fill)
    live = ~((C == 1.0) & (S == 0.0) & (G < 0))
    return int(np.count_nonzero(live.any(axis=0)))


def column_sharded_comm_bytes(m_loc: int, n: int, k: int, D: int,
                              n_b: int, k_b: int, itemsize: int = 4, *,
                              sequence: Optional[RotationSequence] = None,
                              live_planes: Optional[int] = None) -> dict:
    """Analytic per-device communication volume of the pipelined algorithm
    vs an all-gather baseline — the distributed analogue of paper SS1.2.

    Identity padding is exchange-free: a band whose ``k_b`` waves are
    all identity sweeps nothing across the boundary, so only *live*
    bands are priced.  Pass ``sequence`` to count live waves exactly
    (the fused kernel's per-wave ``valid_planes`` liveness rule —
    ``pad_to`` tails and ``seq.T`` staircases price far below the dense
    ``(n-1, k)`` grid), or ``live_planes`` (the static
    ``RotationSequence.k_live`` bound) to model a ``pad_to`` tail of
    ``ceil(live_planes / (n-1))`` leading live waves.  With neither,
    every band is assumed live (the dense grid — the historical
    behaviour, which overstated boundary traffic for padded sequences).

    Returns ``{"pipelined", "allgather", "ratio", "bands",
    "live_bands"}`` (bytes; ``ratio = allgather / pipelined``).
    """
    J = max(1, n - 1)
    B = math.ceil(k / k_b)
    if sequence is not None:
        if sequence.shape != (n - 1, k):
            raise ValueError(
                f"sequence shape {sequence.shape} != waves ({n - 1}, {k})")
        waves = _live_waves(sequence)
    elif live_planes is not None:
        waves = min(k, math.ceil(max(0, int(live_planes)) / J))
    else:
        waves = k
    # pad_to tails / staircase fills trail the live region, so live
    # waves occupy leading bands; a mid-grid dead band still permutes
    # its (cheap) identity halos in the real schedule, but contributes
    # no boundary *planes* — the quantity this model prices.
    live_bands = min(B, math.ceil(waves / k_b))
    supersteps = live_bands + D - 1
    per_step = m_loc * (1 + k_b + (k_b - 1)) * itemsize
    pipelined = supersteps * per_step
    # gather full row-panel once per live band
    allgather = live_bands * m_loc * n * itemsize
    return {"pipelined": pipelined, "allgather": allgather,
            "ratio": allgather / max(pipelined, 1),
            "bands": B, "live_bands": live_bands}
