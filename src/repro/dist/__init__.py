"""repro.dist — sharded execution as a first-class plan.

The distributed layer on the plan-once/apply-many spine:

* :func:`plan_sharded` / :class:`ShardedSequencePlan` — resolve mesh +
  ``PartitionSpec`` + backend once (``method="auto"`` arbitrates
  sharded-fused vs replicated through the comm-extended §6 cost
  model), then apply row-sharded ``(m, n)`` and batched ``(b, m, n)``
  targets with one planned launch per shard under ``shard_map``.
* :func:`rot_sequence_row_sharded` — one-shot convenience over a fresh
  row plan (plan-holding callers should keep the plan instead).
* :mod:`repro.dist.colsharded` — the CAQR-style column-panel pipeline
  (boundary planes exchanged once per ``k_b``-wave panel) and its
  live-window-aware :func:`column_sharded_comm_bytes` accounting.

SPMD primitives (``shard_map``, ``ppermute``, ``axis_index``, …) are
confined to this package (+ ``repro.parallel`` / ``repro.compat``) by
analyzer rule RA206, which also keeps this layer off direct kernel
imports — all execution goes through the planned
:mod:`repro.core.sequence` hooks.
"""
from __future__ import annotations

from repro.dist.colsharded import (column_sharded_comm_bytes,
                                   rot_sequence_column_sharded,
                                   rot_sequence_column_sharded_padded)
from repro.dist.plan import (SHARDED_PLAN_DICT_FORMAT, ShardedSequencePlan,
                             modeled_crossover, plan_sharded)

__all__ = [
    "ShardedSequencePlan", "plan_sharded", "modeled_crossover",
    "SHARDED_PLAN_DICT_FORMAT",
    "rot_sequence_row_sharded",
    "rot_sequence_column_sharded",
    "rot_sequence_column_sharded_padded",
    "column_sharded_comm_bytes",
]


def rot_sequence_row_sharded(A, seq, mesh=None, *, row_axes=("data",),
                             n_b=None, k_b=None, method: str = "blocked"):
    """Row-sharded application: zero stream communication (paper SS7).

    One-shot convenience over :func:`plan_sharded` — rows of ``A``
    shard over ``row_axes``, the sequence replicates, and each shard
    runs one planned backend call with the backend's native autodiff
    (matching the historical ``core.distributed`` semantics).  Repeated
    applications should hold the :class:`ShardedSequencePlan`.

    ``method`` may be any shard_map-capable registry backend or
    ``"auto"`` (arbitrates sharded vs replicated via the comm-extended
    cost model).
    """
    from repro.core.sequence import RotationSequence

    if not isinstance(seq, RotationSequence):
        raise TypeError(
            "rot_sequence_row_sharded(A, seq, mesh) requires a "
            "RotationSequence; the deprecated raw-array form "
            "(A, C, S, mesh) was removed — wrap the waves: "
            "RotationSequence(C, S)")
    if mesh is None:
        raise TypeError(
            "rot_sequence_row_sharded() missing required argument: "
            "'mesh'")
    plan = plan_sharded(seq, like=A, mesh=mesh, row_axes=row_axes,
                        method=method, n_b=n_b, k_b=k_b)
    return plan.apply(A, direct=True)
