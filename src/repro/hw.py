"""Per-chip hardware peak rates (jax-free; importable by report tools).

The single source of truth for platform peaks, shared by the dispatch
registry's cost model (``repro.core.registry``) and the offline roofline
report (``repro.launch.roofline``).  Deliberately dependency-free so
log-parsing scripts don't pay a JAX import to read four constants.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

__all__ = ["Hardware", "PLATFORMS"]


@dataclasses.dataclass(frozen=True)
class Hardware:
    """Per-chip peak rates used by the cost model and the roofline."""
    name: str
    mxu_flops: float   # dense-matmul peak FLOP/s
    vpu_flops: float   # elementwise/VPU peak FLOP/s
    hbm_bw: float      # main-memory bandwidth B/s
    link_bw: float     # interconnect B/s per link


PLATFORMS: Dict[str, Hardware] = {
    "tpu": Hardware("tpu-v5e", mxu_flops=197e12, vpu_flops=4e12,
                    hbm_bw=819e9, link_bw=50e9),
    "gpu": Hardware("gpu-a100", mxu_flops=312e12, vpu_flops=19.5e12,
                    hbm_bw=2.0e12, link_bw=300e9),
    "cpu": Hardware("cpu-host", mxu_flops=1.5e12, vpu_flops=0.4e12,
                    hbm_bw=100e9, link_bw=25e9),
}
