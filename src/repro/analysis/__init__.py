"""AST-based static invariant analyzer for the repro codebase.

Run it as ``python -m repro.analysis`` (or ``make lint``).  See
:mod:`repro.analysis.rules` for the rule families and
:mod:`repro.analysis.engine` for the machinery (alias-resolving import
tables, suppression pragmas, baseline, mtime cache).
"""
from .engine import (ModuleInfo, Rule, Violation, analyze_file,
                     analyze_paths, baseline_key, load_baseline,
                     write_baseline)
from .rules import ALL_RULES, all_rules, rules_matching

__all__ = [
    "ModuleInfo", "Rule", "Violation", "analyze_file", "analyze_paths",
    "baseline_key", "load_baseline", "write_baseline",
    "ALL_RULES", "all_rules", "rules_matching",
]
