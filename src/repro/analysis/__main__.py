"""CLI for the repro static invariant analyzer.

Exit status is 0 when every violation is suppressed or baselined, 1
otherwise — ``make lint`` and the CI lint job gate on it.

Examples::

    python -m repro.analysis                      # whole tree
    python -m repro.analysis --rules RA2          # one family
    python -m repro.analysis src/repro/eig        # one subtree
    python -m repro.analysis --list-rules         # rule table
    python -m repro.analysis --update-baseline    # grandfather the tree
"""
from __future__ import annotations

import argparse
import json
import sys

from .engine import (DEFAULT_BASELINE, analyze_paths, baseline_key,
                     default_roots, load_baseline, write_baseline)
from .rules import all_rules, rules_matching


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="AST-based invariant analyzer (see rules with "
                    "--list-rules)")
    ap.add_argument("paths", nargs="*",
                    help="files/dirs to analyze (default: src/repro, "
                         "benchmarks, examples, tests)")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule/family selectors, e.g. "
                         "'RA2' or 'RA101,RA3'")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="baseline file of grandfathered violations")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report baselined violations too")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from the current tree")
    ap.add_argument("--no-cache", action="store_true",
                    help="ignore the mtime cache for this run")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable output")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule table and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in all_rules():
            print(f"{rule.id}  {rule.title}")
            doc = (type(rule).__doc__ or "").strip().splitlines()
            for line in doc:
                print(f"       {line.strip()}")
            print()
        return 0

    if args.rules:
        selectors = [s.strip() for s in args.rules.split(",") if s.strip()]
        rules = rules_matching(selectors)
        if not rules:
            print(f"error: no rules match {args.rules!r}", file=sys.stderr)
            return 2
    else:
        rules = all_rules()

    paths = args.paths or default_roots()
    violations = analyze_paths(paths, rules, use_cache=not args.no_cache,
                               explicit_fixtures=bool(args.paths))

    if args.update_baseline:
        path = write_baseline(violations, args.baseline)
        print(f"baseline: {len(violations)} entr"
              f"{'y' if len(violations) == 1 else 'ies'} -> {path}")
        return 0

    baseline = set() if args.no_baseline else load_baseline(args.baseline)
    fresh = [v for v in violations if baseline_key(v) not in baseline]
    grandfathered = len(violations) - len(fresh)

    if args.as_json:
        print(json.dumps({
            "violations": [
                {"rule": v.rule, "path": v.path, "line": v.line,
                 "message": v.message} for v in fresh],
            "grandfathered": grandfathered,
        }, indent=1))
    else:
        for v in fresh:
            print(v.format())
        tail = f" ({grandfathered} baselined)" if grandfathered else ""
        print(f"repro.analysis: {len(fresh)} violation"
              f"{'' if len(fresh) == 1 else 's'}{tail}, "
              f"{len(rules)} rules")
    return 1 if fresh else 0


if __name__ == "__main__":
    raise SystemExit(main())
