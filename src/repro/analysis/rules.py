"""The repo's invariants as named, suppressible analyzer rules.

Five families, each replacing (and strengthening) a Makefile grep gate
or encoding a contract no grep could see:

* **RA1xx compat isolation** — version-sensitive JAX surface only in
  ``repro.compat`` (replaces ``compat-gate``).
* **RA2xx dispatch layering** — one public entry point, registry-only
  kernel dispatch, typed serve/eig layers (replaces ``seq-gate``,
  ``serve-gate``, ``eig-gate``).
* **RA3xx bitwise contract** — every 2x2 plane application routes
  through :func:`repro.core.rotations.plane_update`; no fold-prone
  literal signs in traced code (the PR 5 bug class).
* **RA4xx kernel hygiene** — no host round-trips or grid-dim
  reductions inside Pallas kernel bodies; on-chip budgets and tile
  clamps single-sourced in :mod:`repro.kernels.limits`.
* **RA5xx plan-cache determinism + timing discipline** — no wall-clock
  or RNG in cache-key or cost-model code paths; all measurement clocks
  routed through :mod:`repro.obs.timing`.

Suppress a single line with ``# repro-lint: disable=RA301`` (or the
family, ``disable=RA3``); grandfather legacy hits via the baseline file
(``python -m repro.analysis --update-baseline``).
"""
from __future__ import annotations

import ast
import re
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from .engine import ModuleInfo, Rule, Violation

__all__ = ["ALL_RULES", "all_rules", "rules_matching"]


# --------------------------------------------------------------------------
# shared helpers
# --------------------------------------------------------------------------

def _in_repro(mi: ModuleInfo) -> bool:
    return mi.module == "repro" or mi.module.startswith("repro.")


def _is_simple(node: ast.AST) -> bool:
    """Leaf-ish operand of a product term: name, attr, index, constant."""
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        return _is_simple(node.operand)
    return isinstance(node, (ast.Name, ast.Attribute, ast.Subscript,
                             ast.Constant))


def _leaf(node: ast.AST) -> str:
    return ast.unparse(node)


def _function_references(mi: ModuleInfo, fn: ast.AST) -> List[str]:
    out = []
    for node in ast.walk(fn):
        if isinstance(node, ast.Attribute):
            if isinstance(mi.parents.get(node), ast.Attribute):
                continue
            dd = mi.dotted(node)
            if dd:
                out.append(dd)
        elif isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
            if isinstance(mi.parents.get(node), ast.Attribute):
                continue
            out.append(mi.aliases.get(node.id, node.id))
    return out


# --------------------------------------------------------------------------
# RA1xx — compat isolation
# --------------------------------------------------------------------------

class RA101VersionSensitiveAttr(Rule):
    """Version-sensitive JAX API used outside ``repro.compat``.

    Incident: the repo supports jax 0.4.37 through 0.5.x, across which
    ``shard_map``/``typeof``/``pcast``/``pvary`` and the pltpu
    ``CompilerParams`` spelling all moved or changed name.  The old
    ``compat-gate`` grepped for literal spellings and missed aliased
    imports (``from jax.experimental.shard_map import shard_map as
    smap``); this rule resolves every import alias first.
    """

    id = "RA101"
    title = "version-sensitive JAX API outside compat.py"

    BANNED: Tuple[str, ...] = (
        "jax.shard_map",
        "jax.experimental.shard_map",
        "jax.typeof",
        "jax.lax.pcast",
        "jax.lax.pvary",
        "jax.experimental.pallas.tpu.CompilerParams",
        "jax.experimental.pallas.tpu.TPUCompilerParams",
    )

    def _bad(self, dotted: str) -> Optional[str]:
        for b in self.BANNED:
            if dotted == b or dotted.startswith(b + "."):
                return b
        return None

    def check(self, mi: ModuleInfo) -> Iterable[Violation]:
        if mi.module == "repro.compat":
            return
        for line, target in mi.import_targets:
            b = self._bad(target)
            if b:
                yield Violation(self.id, mi.logical, line,
                                f"import of version-sensitive '{b}'; use "
                                f"the repro.compat shim")
        for node, dotted in mi.references():
            b = self._bad(dotted)
            if b:
                yield self.hit(mi, node,
                               f"use of version-sensitive '{b}'; use the "
                               f"repro.compat shim")


class RA102PlatformProbe(Rule):
    """Backend/platform probed outside ``repro.compat``.

    Incident: scattered ``jax.default_backend()`` calls made CPU-vs-TPU
    behaviour (x64 defaults, interpret-mode defaults) diverge between
    the library and the benchmark harness.  All platform questions go
    through ``compat.default_platform()`` / ``compat.is_tpu()`` so one
    module defines what "on TPU" means.
    """

    id = "RA102"
    title = "platform probe outside compat.py"

    PROBES = ("jax.default_backend", "jax.devices", "jax.local_devices",
              "jax.device_count", "jax.local_device_count")

    def check(self, mi: ModuleInfo) -> Iterable[Violation]:
        if mi.module == "repro.compat":
            return
        for node, dotted in mi.references():
            if dotted in self.PROBES:
                yield self.hit(mi, node,
                               f"platform probe '{dotted}'; use "
                               f"repro.compat.default_platform()/is_tpu()")


class RA103X64FlagMutation(Rule):
    """``jax_enable_x64`` flipped directly instead of via compat.

    Incident: a bare ``jax.config.update("jax_enable_x64", True)`` in a
    test leaked x64 mode into every later test in the process; the
    ``compat.enable_x64()`` context manager restores the previous value
    (and uses ``jax.experimental.enable_x64`` where available).
    """

    id = "RA103"
    title = "jax_enable_x64 mutated outside compat.py"

    def check(self, mi: ModuleInfo) -> Iterable[Violation]:
        if mi.module == "repro.compat":
            return
        for node in ast.walk(mi.tree):
            if not isinstance(node, ast.Call):
                continue
            dotted = mi.dotted(node.func)
            if dotted != "jax.config.update":
                continue
            if (node.args and isinstance(node.args[0], ast.Constant)
                    and node.args[0].value == "jax_enable_x64"):
                yield self.hit(mi, node,
                               "direct jax_enable_x64 mutation; use the "
                               "repro.compat.enable_x64() context manager")


# --------------------------------------------------------------------------
# RA2xx — dispatch layering
# --------------------------------------------------------------------------

class RA201RawApplyOutsideApi(Rule):
    """``apply_rotation_sequence`` used outside ``repro.core.api``.

    Incident: the raw-array wrapper bypasses ``SequencePlan`` caching
    and re-plans on every call; library code must go through
    ``seq.plan(...)``/``plan.apply(...)``.  The old ``seq-gate`` regex
    ``apply_rotation_sequence\\s*\\(`` missed aliased imports (``from
    repro.core.api import apply_rotation_sequence as _ars``) — this
    rule resolves the alias table, so the call site is caught whatever
    the local name is (see the regression fixture).
    """

    id = "RA201"
    title = "apply_rotation_sequence outside core/api.py"

    ALLOWED = {"repro.core.api", "repro.core"}
    TARGETS = {"repro.core.api.apply_rotation_sequence",
               "repro.core.apply_rotation_sequence",
               "repro.apply_rotation_sequence"}

    def check(self, mi: ModuleInfo) -> Iterable[Violation]:
        if not _in_repro(mi) or mi.module in self.ALLOWED:
            return
        for line, target in mi.import_targets:
            if target in self.TARGETS:
                yield Violation(self.id, mi.logical, line,
                                "import of apply_rotation_sequence; use "
                                "seq.plan(...)/plan.apply(...)")
        for node in ast.walk(mi.tree):
            if not isinstance(node, ast.Call):
                continue
            dotted = mi.dotted(node.func)
            if dotted in self.TARGETS:
                yield self.hit(mi, node,
                               "call to apply_rotation_sequence; use "
                               "seq.plan(...)/plan.apply(...)")


class RA202KernelImportOutsideRegistry(Rule):
    """``repro.kernels.rotseq*`` imported outside the dispatch layer.

    Incident: the registry's cost model can only keep its promises if
    every rotation-sequence kernel launch flows through it; a direct
    ``rot_sequence_batched(...)`` call skips the SMEM/VMEM budget guard
    and can hand Mosaic a panel it cannot compile.  Only
    ``repro.core.api`` (the registered backends) may import the
    ``rotseq*`` kernel packages; kernels may import each other.
    """

    id = "RA202"
    title = "rotseq kernel import outside core/api.py"

    ALLOWED = {"repro.core.api"}
    PREFIX = "repro.kernels.rotseq"

    def check(self, mi: ModuleInfo) -> Iterable[Violation]:
        if not _in_repro(mi) or mi.module in self.ALLOWED:
            return
        if mi.module.startswith("repro.kernels"):
            return
        for line, target in mi.import_targets:
            if target.startswith(self.PREFIX):
                yield Violation(self.id, mi.logical, line,
                                f"direct kernel import '{target}'; "
                                f"dispatch via repro.core.registry")
        for node, dotted in mi.references():
            if dotted.startswith(self.PREFIX):
                yield self.hit(mi, node,
                               f"direct kernel reference '{dotted}'; "
                               f"dispatch via repro.core.registry")


class RA203TypedLayerOnly(Rule):
    """serve/eig layer reaching below the typed sequence API.

    Incident: the eig and serve layers are consumers of the paper's
    apply machinery; when ``tridiagonalize`` briefly imported
    ``core.blocked`` directly it silently pinned one backend and
    bypassed plan caching.  These layers touch only
    ``RotationSequence``/``SequencePlan`` (plus the registry); the
    backend zoo (``rot_sequence_*``) and internal core modules are off
    limits (replaces ``eig-gate``/``serve-gate``).
    ``repro.kernels.limits`` is carved out: it is pure host arithmetic
    (budget constants, tile clamps) with no kernel machinery, designed
    to be importable from every layer.
    """

    id = "RA203"
    title = "serve/eig layer bypassing the typed API"

    LAYERS = ("repro.serve", "repro.eig")
    BANNED_PREFIXES = ("repro.kernels", "repro.core.blocked",
                       "repro.core.accumulate", "repro.core.ref")
    CARVE_OUTS = ("repro.kernels.limits",)
    BANNED_NAMES = {
        "rot_sequence_blocked", "rot_sequence_accumulated",
        "rot_sequence_unoptimized", "rot_sequence_wavefront",
        "rot_sequence_wave", "rot_sequence_mxu", "rot_sequence_batched",
    }

    def _banned(self, dotted: str) -> bool:
        if any(dotted == c or dotted.startswith(c + ".")
               for c in self.CARVE_OUTS):
            return False
        return (any(dotted == p or dotted.startswith(p + ".")
                    for p in self.BANNED_PREFIXES)
                or dotted.rsplit(".", 1)[-1] in self.BANNED_NAMES)

    def _layer(self, mi: ModuleInfo) -> bool:
        return any(mi.module == p or mi.module.startswith(p + ".")
                   for p in self.LAYERS)

    def check(self, mi: ModuleInfo) -> Iterable[Violation]:
        if not self._layer(mi):
            return
        for line, target in mi.import_targets:
            if self._banned(target):
                yield Violation(self.id, mi.logical, line,
                                f"layer import '{target}'; serve/eig use "
                                f"RotationSequence/SequencePlan only")
        for node, dotted in mi.references():
            if self._banned(dotted):
                yield self.hit(mi, node,
                               f"layer reference '{dotted}'; serve/eig "
                               f"use RotationSequence/SequencePlan only")


class RA204StreamConcurrencyDiscipline(Rule):
    """Concurrency primitives outside the stream engine; engine reaching
    below the service.

    Incident: the first streaming-engine draft grew a second ad-hoc
    worker thread inside the launcher and called
    ``SequencePlan.apply_batched`` straight from it — the two dispatch
    paths then raced the (pre-lock) obs counters and double-resolved a
    bucket plan, breaking the exactly-once planning invariant that the
    warm-start tests pin.  Two confinements, statically:

    * thread/queue primitives (``threading``, ``queue``,
      ``concurrent.futures``, ``_thread``, ``multiprocessing``) live
      only in ``repro.serve.stream`` — the serving stack's one
      concurrent component.  Carve-outs for the pre-existing
      infrastructure users: ``repro.obs.*`` (metric/trace/roofline
      locks), ``repro.ckpt.manager`` (async checkpoint writer), and
      ``repro.parallel.sharding`` (a ``threading.local`` for axis-rule
      scoping).
    * ``repro.serve.stream`` itself executes only through
      ``RotationService`` bucket internals /
      ``SequencePlan.apply_batched`` handles — importing
      ``repro.core.*`` or ``repro.kernels.*`` machinery from the engine
      would open a second dispatch path next to the service's
      plan-exactly-once state.
    """

    id = "RA204"
    title = "thread/queue primitive outside serve.stream, or engine " \
            "bypassing the service"

    THREAD_ROOTS = ("threading", "queue", "concurrent.futures", "_thread",
                    "multiprocessing")
    ENGINE = "repro.serve.stream"
    CARVE_OUTS = ("repro.obs", "repro.ckpt.manager",
                  "repro.parallel.sharding")
    ENGINE_BANNED_PREFIXES = ("repro.core", "repro.kernels")

    def _thread_primitive(self, dotted: str) -> Optional[str]:
        for root in self.THREAD_ROOTS:
            if dotted == root or dotted.startswith(root + "."):
                return root
        return None

    def _carved_out(self, mi: ModuleInfo) -> bool:
        return any(mi.module == c or mi.module.startswith(c + ".")
                   for c in self.CARVE_OUTS)

    def check(self, mi: ModuleInfo) -> Iterable[Violation]:
        if not _in_repro(mi):
            return
        if mi.module == self.ENGINE:
            # inside the engine: concurrency is fine, core/kernels are not
            for line, target in mi.import_targets:
                if any(target == p or target.startswith(p + ".")
                       for p in self.ENGINE_BANNED_PREFIXES):
                    yield Violation(
                        self.id, mi.logical, line,
                        f"stream engine import '{target}'; execute only "
                        f"through RotationService/SequencePlan handles")
            return
        if self._carved_out(mi):
            return
        imported_roots = {t.split(".")[0] for _l, t in mi.import_targets}
        for line, target in mi.import_targets:
            root = self._thread_primitive(target)
            if root:
                yield Violation(
                    self.id, mi.logical, line,
                    f"thread/queue primitive '{root}' outside "
                    f"repro.serve.stream; the stream engine is the one "
                    f"concurrent serving component")
        for node, dotted in mi.references():
            root = self._thread_primitive(dotted)
            # a local variable that happens to be named `queue` yields
            # dotted chains like "queue.append" — only flag chains whose
            # root module is actually imported here
            if root and dotted.split(".")[0] in imported_roots:
                yield self.hit(
                    mi, node,
                    f"thread/queue reference '{dotted}' outside "
                    f"repro.serve.stream; the stream engine is the one "
                    f"concurrent serving component")


class RA205SilentSharedSequenceDefault(Rule):
    """``Problem(batch>1)`` built outside the plan layers without saying
    whether the batch shares one rotation sequence.

    Incident: the serving path bucketed b independent requests into one
    ``(b, m, n)`` dispatch and priced it as ``Problem(batch=64)`` — the
    ``shared_sequence=True`` default silently claimed the per-sequence
    setup (packing, Q_t accumulation) would be paid once and amortized
    over the batch.  It is paid ``b`` times for per-request traffic, so
    ``method="auto"`` picked ``accumulated``, rebuilt 64 factor sets per
    flush, and ran ~10x slower than the fused kernel at batch 64; the
    serving bench had to pin ``method="rotseq_batched"`` to stay above
    its throughput floor.  The fix threads ``shared_sequence`` from
    every producer, and this rule keeps the default from lying again:
    any ``repro.*`` module outside ``repro.core.registry`` /
    ``repro.core.sequence`` (the layers that *define* the pricing and
    normalize the flag) that constructs a registry ``Problem`` with a
    batch that is not literally 1 must spell ``shared_sequence=``
    explicitly — whichever value it means.
    """

    id = "RA205"
    title = "batched Problem() without explicit shared_sequence"

    ALLOWED = {"repro.core.registry", "repro.core.sequence"}
    TARGETS = {"repro.core.registry.Problem"}

    @staticmethod
    def _batch_may_exceed_one(node: ast.AST) -> bool:
        # literal 0/1 batches price identically either way; anything
        # else (a larger literal, or a runtime value we cannot see
        # through) can be a per-request bucket and must be labelled
        if isinstance(node, ast.Constant) and node.value in (0, 1, True):
            return False
        return True

    def check(self, mi: ModuleInfo) -> Iterable[Violation]:
        if not _in_repro(mi) or mi.module in self.ALLOWED:
            return
        for node in ast.walk(mi.tree):
            if not isinstance(node, ast.Call):
                continue
            if mi.dotted(node.func) not in self.TARGETS:
                continue
            kw_names = {kw.arg for kw in node.keywords}
            if None in kw_names:
                continue  # **splat may carry shared_sequence; can't see
            if "shared_sequence" in kw_names:
                continue
            batch = next((kw.value for kw in node.keywords
                          if kw.arg == "batch"), None)
            if batch is not None and self._batch_may_exceed_one(batch):
                yield self.hit(
                    mi, node,
                    "Problem(batch=...) without shared_sequence=; a "
                    "per-request bucket priced as a shared-sequence "
                    "batch amortizes setup it actually pays b times — "
                    "say shared_sequence=True/False explicitly")


class RA206SpmdConfinement(Rule):
    """SPMD primitive outside the dist layer, or dist importing kernels.

    Incident (PR 10): the distributed path became a first-class plan
    (``repro.dist``) precisely so that sharded execution flows through
    the same registry arbitration, plan cache, and obs attribution as
    everything else.  Two confinements keep it that way:

    * SPMD collectives and mesh primitives (``shard_map``,
      ``ppermute``, ``axis_index``, ``psum``, ``all_gather``, …) live
      only in ``repro.dist`` / ``repro.parallel`` / ``repro.compat``
      (the version shim that *defines* the ``shard_map`` spelling).  A
      stray collective elsewhere is a second distribution path the
      comm-extended cost model cannot see.
    * ``repro.dist`` itself never imports ``repro.kernels.*`` — every
      shard executes through the planned :mod:`repro.core.sequence`
      hooks (``planned_apply`` / ``planned_apply_batched``), so a
      sharded dispatch cannot dodge the registry's SMEM/VMEM budget
      guard or launch accounting.  ``repro.kernels.limits`` stays
      importable (pure host arithmetic, same carve-out as RA203).
    """

    id = "RA206"
    title = "SPMD primitive outside repro.dist, or dist importing kernels"

    ALLOWED = ("repro.dist", "repro.parallel", "repro.compat")
    DIST = "repro.dist"
    SPMD_NAMES = {"shard_map", "ppermute", "axis_index", "psum", "pmean",
                  "all_gather", "psum_scatter", "all_to_all", "pshuffle"}
    KERNEL_PREFIX = "repro.kernels"
    KERNEL_CARVE_OUTS = ("repro.kernels.limits",)

    def _spmd(self, dotted: str) -> bool:
        if dotted.rsplit(".", 1)[-1] not in self.SPMD_NAMES:
            return False
        return dotted.startswith(("jax.", "repro.compat."))

    def _kernel(self, dotted: str) -> bool:
        if any(dotted == c or dotted.startswith(c + ".")
               for c in self.KERNEL_CARVE_OUTS):
            return False
        return (dotted == self.KERNEL_PREFIX
                or dotted.startswith(self.KERNEL_PREFIX + "."))

    def check(self, mi: ModuleInfo) -> Iterable[Violation]:
        if not _in_repro(mi):
            return
        if mi.module == self.DIST or mi.module.startswith(self.DIST + "."):
            for line, target in mi.import_targets:
                if self._kernel(target):
                    yield Violation(
                        self.id, mi.logical, line,
                        f"kernel import '{target}' in repro.dist; shards "
                        f"execute through the planned repro.core.sequence "
                        f"hooks only")
            for node, dotted in mi.references():
                if self._kernel(dotted):
                    yield self.hit(
                        mi, node,
                        f"kernel reference '{dotted}' in repro.dist; "
                        f"shards execute through the planned "
                        f"repro.core.sequence hooks only")
            return
        if any(mi.module == a or mi.module.startswith(a + ".")
               for a in self.ALLOWED):
            return
        for line, target in mi.import_targets:
            if self._spmd(target):
                yield Violation(
                    self.id, mi.logical, line,
                    f"SPMD primitive import '{target}' outside repro.dist; "
                    f"distribution goes through repro.dist plans")
        for node, dotted in mi.references():
            if self._spmd(dotted):
                yield self.hit(
                    mi, node,
                    f"SPMD primitive '{dotted}' outside repro.dist; "
                    f"distribution goes through repro.dist plans")


# --------------------------------------------------------------------------
# RA3xx — bitwise contract
# --------------------------------------------------------------------------

def _mult_terms(node: ast.AST) -> Optional[Tuple[str, str, bool]]:
    """Decompose ``a * b`` into (leaf_a, leaf_b, negated).

    ``-a * b`` (parsed as ``(-a) * b``) and ``-(a * b)`` both normalize
    to the positive pair with ``negated=True`` so the crosswise matcher
    sees ``-s*x + c*y`` and ``s*x - c*y`` as the same subtraction form.
    """
    neg = False
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        neg = True
        node = node.operand
    if not (isinstance(node, ast.BinOp) and isinstance(node.op, ast.Mult)):
        return None
    left, right = node.left, node.right
    if isinstance(left, ast.UnaryOp) and isinstance(left.op, ast.USub):
        neg = not neg
        left = left.operand
    if isinstance(right, ast.UnaryOp) and isinstance(right.op, ast.USub):
        neg = not neg
        right = right.operand
    if not (_is_simple(left) and _is_simple(right)):
        return None
    return _leaf(left), _leaf(right), neg


def _two_term_forms(node: ast.BinOp) -> Optional[Tuple[str, Tuple, Tuple]]:
    """Classify ``t1 + t2`` / ``t1 - t2`` of two products as add/sub form.

    Returns ``(form, pair1, pair2)`` where each pair is a frozenset of
    the two leaf strings of one product and ``form`` folds all sign
    information: ``c*x + s*y`` -> add; ``s*x - c*y`` and ``-s*x + c*y``
    -> sub.
    """
    if not isinstance(node.op, (ast.Add, ast.Sub)):
        return None
    t1 = _mult_terms(node.left)
    t2 = _mult_terms(node.right)
    if t1 is None or t2 is None:
        return None
    a1, b1, n1 = t1
    a2, b2, n2 = t2
    sub = isinstance(node.op, ast.Sub)
    # fold term signs: (-u) + v == v - u; u - (-v) == u + v; etc.
    effective_sub = (n1 != n2) != sub
    return ("sub" if effective_sub else "add",
            frozenset((a1, b1)), frozenset((a2, b2)))


class RA301InlinePlaneStencil(Rule):
    """Inline 2x2 plane application instead of ``plane_update``.

    Incident (PR 5): two hand-inlined copies of the rotation stencil
    drifted — one contracted ``g*(s*x - c*y)`` and one ``-s*x + c*y``,
    which XLA fuses into different multiply orders, so the "same"
    sequence produced bit-different planes on different paths and the
    bit-stability suite only caught it on one backend.  Every crosswise
    pair ``{c*x + s*y, s*x - c*y}`` over the same four operands must be
    the one canonical :func:`repro.core.rotations.plane_update`.
    """

    id = "RA301"
    title = "inline 2x2 plane stencil (use plane_update)"

    EXEMPT = {"repro.core.rotations"}

    def check(self, mi: ModuleInfo) -> Iterable[Violation]:
        if not _in_repro(mi) or mi.module in self.EXEMPT:
            return
        for fn in mi.functions():
            adds: List[Tuple[ast.AST, frozenset, frozenset]] = []
            subs: List[Tuple[ast.AST, frozenset, frozenset]] = []
            for node in ast.walk(fn):
                if not isinstance(node, ast.BinOp):
                    continue
                form = _two_term_forms(node)
                if form is None:
                    continue
                kind, p1, p2 = form
                (adds if kind == "add" else subs).append((node, p1, p2))
            reported: Set[int] = set()
            for anode, ap1, ap2 in adds:
                leaves = ap1 | ap2
                if len(leaves) != 4:
                    continue
                for snode, sp1, sp2 in subs:
                    if (sp1 | sp2) != leaves:
                        continue
                    if {sp1, sp2} == {ap1, ap2}:
                        continue  # same pairing: sum/difference, not a plane
                    target = max(anode.lineno, snode.lineno)
                    if target in reported:
                        continue
                    reported.add(target)
                    node = anode if anode.lineno == target else snode
                    yield self.hit(
                        mi, node,
                        "inline 2x2 plane stencil; route through "
                        "repro.core.rotations.plane_update")


class RA302FoldableSignLiteral(Rule):
    """Literal ``±1`` sign handed to ``plane_update`` in traced code.

    Incident (PR 5): passing the reflector sign as a Python scalar let
    XLA constant-fold ``g * (...)`` into a re-associated contraction,
    flipping low-order bits between the fused kernel and the reference.
    In traced (jax/jnp-using) functions the sign must be a runtime
    array (``jnp.where(refl, -1.0, 1.0)``-style), which the fold cannot
    see through.  Host-side numpy recurrences (eig layer) are exempt:
    nothing folds them.
    """

    id = "RA302"
    title = "foldable scalar sign in traced plane_update call"

    def _literal_sign(self, node: ast.AST) -> bool:
        if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
            node = node.operand
        return (isinstance(node, ast.Constant)
                and isinstance(node.value, (int, float))
                and abs(node.value) == 1)

    def _traced(self, mi: ModuleInfo, fn: ast.AST) -> bool:
        return any(ref == "jax" or ref.startswith("jax.")
                   for ref in _function_references(mi, fn))

    def check(self, mi: ModuleInfo) -> Iterable[Violation]:
        if not _in_repro(mi):
            return
        for fn in mi.functions():
            traced = None  # lazy: only probe functions that call the API
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                dotted = mi.dotted(node.func) or ""
                if not (dotted == "plane_update"
                        or dotted.endswith(".plane_update")):
                    continue
                g = node.args[4] if len(node.args) >= 5 else None
                for kw in node.keywords:
                    if kw.arg == "g":
                        g = kw.value
                if g is None or not self._literal_sign(g):
                    continue
                if traced is None:
                    traced = self._traced(mi, fn)
                if traced:
                    yield self.hit(
                        mi, node,
                        "literal ±1 sign in traced plane_update call; "
                        "pass a runtime array so XLA cannot fold it")


# --------------------------------------------------------------------------
# RA4xx — kernel hygiene
# --------------------------------------------------------------------------

def _kernel_bodies(mi: ModuleInfo) -> List[ast.AST]:
    """FunctionDefs that are Pallas kernel bodies in this module.

    Resolves the repo's idiom: ``kernel = functools.partial(_kern, ...)``
    then ``pl.pallas_call(kernel, ...)`` — the first pallas_call
    argument is unwrapped through the partial assignment to the
    underlying FunctionDef.
    """
    defs: Dict[str, ast.AST] = {
        fn.name: fn for fn in mi.functions()}
    partial_of: Dict[str, str] = {}
    for node in ast.walk(mi.tree):
        if not (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and isinstance(node.value, ast.Call)):
            continue
        dotted = mi.dotted(node.value.func) or ""
        if dotted == "functools.partial" and node.value.args \
                and isinstance(node.value.args[0], ast.Name):
            partial_of[node.targets[0].id] = node.value.args[0].id
    bodies: List[ast.AST] = []
    for node in ast.walk(mi.tree):
        if not isinstance(node, ast.Call):
            continue
        dotted = mi.dotted(node.func) or ""
        if not dotted.endswith(".pallas_call"):
            continue
        if not node.args:
            continue
        arg = node.args[0]
        name = None
        if isinstance(arg, ast.Name):
            name = partial_of.get(arg.id, arg.id)
        elif isinstance(arg, ast.Call):  # inline functools.partial(...)
            inner = mi.dotted(arg.func) or ""
            if inner == "functools.partial" and arg.args \
                    and isinstance(arg.args[0], ast.Name):
                name = arg.args[0].id
        if name and name in defs:
            bodies.append(defs[name])
    return bodies


class RA401KernelHostRoundTrip(Rule):
    """Host round-trip inside a Pallas kernel body.

    Incident: an ``.item()`` debug probe in an interpret-mode kernel
    ran green locally, then failed Mosaic lowering on TPU — interpret
    mode executes host Python that compiled kernels cannot.  Kernel
    bodies stay pure traced code: no ``float()``/``bool()`` on traced
    values, no ``.item()``, no host numpy, no ``jax.device_get``.
    """

    id = "RA401"
    title = "host round-trip in Pallas kernel body"

    HOST_CALLS = {"float", "bool"}

    def check(self, mi: ModuleInfo) -> Iterable[Violation]:
        if not _in_repro(mi):
            return
        for body in _kernel_bodies(mi):
            for node in ast.walk(body):
                if isinstance(node, ast.Call):
                    if isinstance(node.func, ast.Name) \
                            and node.func.id in self.HOST_CALLS \
                            and node.func.id not in mi.aliases:
                        yield self.hit(
                            mi, node,
                            f"host conversion {node.func.id}() in kernel "
                            f"body; kernels must stay traced")
                    elif isinstance(node.func, ast.Attribute) \
                            and node.func.attr == "item":
                        yield self.hit(
                            mi, node,
                            ".item() in kernel body; kernels must stay "
                            "traced")
                dotted = None
                if isinstance(node, ast.Attribute) and not isinstance(
                        mi.parents.get(node), ast.Attribute):
                    dotted = mi.dotted(node)
                if dotted and (dotted.startswith("numpy.")
                               or dotted == "jax.device_get"):
                    yield self.hit(
                        mi, node,
                        f"host reference '{dotted}' in kernel body; "
                        f"kernels must stay traced")


class RA402GridDimReduction(Rule):
    """``jnp`` reduction over a traced grid index in a kernel body.

    Incident: reducing an expression built from ``pl.program_id``
    inside a kernel re-materializes the grid dimension as data — it
    traces in interpret mode but defeats the revisiting/pipelining
    analysis the grid exists to express, and Mosaic lowers it to a
    serialized scan.  Grid-dim logic belongs in index maps, not
    reductions.
    """

    id = "RA402"
    title = "jnp reduction over traced grid dim in kernel body"

    REDUCTIONS = {"sum", "max", "min", "prod", "mean", "any", "all",
                  "argmax", "argmin", "cumsum", "cumprod"}
    GRID_FNS = (".program_id", ".num_programs")

    def check(self, mi: ModuleInfo) -> Iterable[Violation]:
        if not _in_repro(mi):
            return
        for body in _kernel_bodies(mi):
            for node in ast.walk(body):
                if not isinstance(node, ast.Call):
                    continue
                dotted = mi.dotted(node.func) or ""
                if not (dotted.startswith("jax.numpy.")
                        and dotted.rsplit(".", 1)[-1] in self.REDUCTIONS):
                    continue
                hit = False
                for a in node.args:
                    for sub in ast.walk(a):
                        if isinstance(sub, ast.Call):
                            inner = mi.dotted(sub.func) or ""
                            if inner.endswith(self.GRID_FNS):
                                hit = True
                    if hit:
                        break
                if hit:
                    yield self.hit(
                        mi, node,
                        "jnp reduction over pl.program_id/num_programs; "
                        "express grid logic in index maps instead")


class RA403BudgetConstantOutsideLimits(Rule):
    """On-chip budget constant defined outside ``repro.kernels.limits``.

    Incident (PR 5): the SMEM panel budget lived in the registry cost
    guard while the kernel wrapper carried its own copy of the clamp,
    coupled only by a "mirror the kernel" comment; retuning one side
    would silently misprice the other.  Budget constants
    (``*_BUDGET``) are defined once in :mod:`repro.kernels.limits` and
    imported everywhere else.
    """

    id = "RA403"
    title = "budget constant defined outside kernels/limits.py"

    EXEMPT = {"repro.kernels.limits"}
    NAME_RE = re.compile(r"^_?[A-Z0-9]*(SMEM|VMEM)[A-Z0-9_]*BUDGET$|"
                         r"^_?[A-Z0-9_]*BUDGET$")

    def check(self, mi: ModuleInfo) -> Iterable[Violation]:
        if not _in_repro(mi) or mi.module in self.EXEMPT:
            return
        for node in ast.walk(mi.tree):
            targets: List[ast.AST] = []
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                targets = [node.target]
            for t in targets:
                if isinstance(t, ast.Name) and self.NAME_RE.match(t.id) \
                        and t.id not in mi.aliases:
                    yield self.hit(
                        mi, node,
                        f"budget constant '{t.id}' defined here; define "
                        f"in repro.kernels.limits and import it")


def _is_round_up_expr(node: ast.AST) -> bool:
    """Match the hand-inlined ``((x + M-1) // M) * M`` round-up shape."""
    if not (isinstance(node, ast.BinOp) and isinstance(node.op, ast.Mult)):
        return False
    for div, mult in ((node.left, node.right), (node.right, node.left)):
        if not (isinstance(div, ast.BinOp)
                and isinstance(div.op, ast.FloorDiv)):
            continue
        add = div.left
        m_str = ast.unparse(mult)
        if ast.unparse(div.right) != m_str:
            continue
        # ((x + mult - 1) // mult) parses the numerator as Sub(Add(..), 1)
        if isinstance(add, ast.BinOp) and isinstance(add.op, ast.Sub) \
                and isinstance(add.right, ast.Constant) \
                and add.right.value == 1:
            inner = add.left
            if isinstance(inner, ast.BinOp) \
                    and isinstance(inner.op, ast.Add) \
                    and m_str in (ast.unparse(inner.left),
                                  ast.unparse(inner.right)):
                return True
        if not (isinstance(add, ast.BinOp) and isinstance(add.op, ast.Add)):
            continue
        for k in (add.left, add.right):
            if isinstance(k, ast.Constant) and isinstance(mult, ast.Constant) \
                    and isinstance(k.value, int) \
                    and k.value == mult.value - 1:
                return True
            if ast.unparse(k) == f"{m_str} - 1":
                return True
    return False


class RA404RederivedClamp(Rule):
    """Tile round-up/clamp re-derived instead of imported from limits.

    Incident (PR 5): three private ``_round_up`` copies plus an inline
    ``((m + 7) // 8) * 8`` in the registry meant the cost guard's idea
    of the kernel's padded shape could drift from the kernel's own.
    :func:`repro.kernels.limits.round_up` and
    :func:`repro.kernels.limits.clamp_m_blk` are the only definitions.
    """

    id = "RA404"
    title = "round-up/clamp re-derived outside kernels/limits.py"

    EXEMPT = {"repro.kernels.limits"}
    HELPER_NAMES = {"round_up", "_round_up", "clamp_m_blk", "_clamp_m_blk"}

    def check(self, mi: ModuleInfo) -> Iterable[Violation]:
        if not _in_repro(mi) or mi.module in self.EXEMPT:
            return
        for fn in mi.functions():
            if fn.name in self.HELPER_NAMES:
                yield self.hit(
                    mi, fn,
                    f"local helper '{fn.name}' shadows "
                    f"repro.kernels.limits; import it instead")
        for node in ast.walk(mi.tree):
            if isinstance(node, ast.BinOp) and _is_round_up_expr(node):
                yield self.hit(
                    mi, node,
                    "inline ((x + M-1) // M) * M round-up; use "
                    "repro.kernels.limits.round_up/clamp_m_blk")


# --------------------------------------------------------------------------
# RA5xx — plan-cache determinism
# --------------------------------------------------------------------------

class RA501NondeterministicKeyPath(Rule):
    """Wall-clock or RNG in a cache-key or cost-model function.

    Incident: the on-disk plan store replays cached plans across
    processes and CI runs; a timestamp or RNG draw folded into a plan
    key (or a cost estimate) makes two identical problems hash to
    different plans, silently defeating plan reuse and making perf
    regressions unreproducible.  Measurement helpers (``_measure*``)
    may time things; ``cost_*``/``*_key`` functions must be pure.
    """

    id = "RA501"
    title = "time/random in cache-key or cost-model path"

    FUNC_RE = re.compile(r"^(cost_|plan_key$|cache_key$|fingerprint)|_key$")
    BANNED_ROOTS = ("time", "random", "secrets", "uuid")
    BANNED_PREFIXES = ("numpy.random", "datetime", "os.urandom",
                       "jax.random")

    def check(self, mi: ModuleInfo) -> Iterable[Violation]:
        if not _in_repro(mi):
            return
        for fn in mi.functions():
            if not self.FUNC_RE.search(fn.name):
                continue
            for node in ast.walk(fn):
                dotted = None
                if isinstance(node, ast.Attribute) and not isinstance(
                        mi.parents.get(node), ast.Attribute):
                    dotted = mi.dotted(node)
                if not dotted:
                    continue
                root = dotted.split(".")[0]
                if root in self.BANNED_ROOTS or any(
                        dotted == p or dotted.startswith(p + ".")
                        for p in self.BANNED_PREFIXES):
                    yield self.hit(
                        mi, node,
                        f"nondeterministic '{dotted}' in key/cost path "
                        f"'{fn.name}'; keys and costs must be pure")


class RA502AdHocTiming(Rule):
    """Ad-hoc wall-clock timing outside ``repro.obs``.

    Incident (PR 7): the benchmarks, the train loop, the serve launcher
    and three examples each carried a private ``time.perf_counter()``
    stopwatch.  When the serving throughput row was found to count
    identity pad slots as served requests, every copy had to be audited
    by hand to establish which numbers were comparable — and none of
    them fed the roofline attribution, so model-vs-measured fractions
    silently excluded exactly the paths people quoted.
    :mod:`repro.obs.timing` is the single sanctioned clock
    (``benchmarks.common`` is the one shim allowed to re-export it);
    library, benchmark and example code must not reference the stdlib
    clocks or ``timeit`` directly.  Tests are out of scope: they assert
    on behaviour, not on published numbers.
    """

    id = "RA502"
    title = "ad-hoc timing outside repro.obs"

    SCOPES = ("repro", "benchmarks", "examples")
    EXEMPT = {"repro.obs", "benchmarks.common"}
    CLOCKS = ("time.time", "time.time_ns", "time.perf_counter",
              "time.perf_counter_ns", "time.monotonic",
              "time.monotonic_ns", "time.process_time",
              "time.process_time_ns")

    def _scoped(self, mi: ModuleInfo) -> bool:
        if not any(mi.module == s or mi.module.startswith(s + ".")
                   for s in self.SCOPES):
            return False
        return not (mi.module in self.EXEMPT
                    or mi.module.startswith("repro.obs."))

    @staticmethod
    def _is_timeit(dotted: str) -> bool:
        return dotted == "timeit" or dotted.startswith("timeit.")

    def check(self, mi: ModuleInfo) -> Iterable[Violation]:
        if not self._scoped(mi):
            return
        for line, target in mi.import_targets:
            if self._is_timeit(target):
                yield Violation(self.id, mi.logical, line,
                                "import of timeit; time through "
                                "repro.obs.timing instead")
            elif target in self.CLOCKS:
                yield Violation(self.id, mi.logical, line,
                                f"import of stdlib clock '{target}'; use "
                                f"repro.obs.timing.now()")
        for node, dotted in mi.references():
            if dotted in self.CLOCKS:
                yield self.hit(
                    mi, node,
                    f"ad-hoc clock '{dotted}'; repro.obs.timing is the "
                    f"single sanctioned timing home")
            elif self._is_timeit(dotted):
                yield self.hit(
                    mi, node,
                    f"timeit reference '{dotted}'; time through "
                    f"repro.obs.timing instead")


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------

ALL_RULES: Tuple[type, ...] = (
    RA101VersionSensitiveAttr,
    RA102PlatformProbe,
    RA103X64FlagMutation,
    RA201RawApplyOutsideApi,
    RA202KernelImportOutsideRegistry,
    RA203TypedLayerOnly,
    RA204StreamConcurrencyDiscipline,
    RA205SilentSharedSequenceDefault,
    RA206SpmdConfinement,
    RA301InlinePlaneStencil,
    RA302FoldableSignLiteral,
    RA401KernelHostRoundTrip,
    RA402GridDimReduction,
    RA403BudgetConstantOutsideLimits,
    RA404RederivedClamp,
    RA501NondeterministicKeyPath,
    RA502AdHocTiming,
)


def all_rules() -> List[Rule]:
    return [cls() for cls in ALL_RULES]


def rules_matching(selectors: Sequence[str]) -> List[Rule]:
    """Instantiate rules whose id matches any selector prefix.

    ``RA2`` selects the whole family; ``RA203`` one rule.
    """
    out = []
    for cls in ALL_RULES:
        rule = cls()
        if any(rule.id.startswith(sel) for sel in selectors):
            out.append(rule)
    return out
