"""Core machinery of the ``repro`` static invariant analyzer.

This module knows nothing about the repo's specific invariants (those
live in :mod:`repro.analysis.rules`); it provides the pieces every rule
shares:

* **module model** (:class:`ModuleInfo`): one parsed source file with
  its AST, a parent map, the *logical* repo path (fixtures can override
  it with a ``# repro-lint: fixture-as=...`` pragma so a file under
  ``tests/analysis_fixtures/`` is analyzed as if it lived at a real
  library path), and — crucially — a resolved **import alias table**.
  The grep gates this analyzer replaces matched literal attribute
  spellings, so ``from repro.core.api import apply_rotation_sequence
  as _ars`` slipped straight past them; here every ``Name``/
  ``Attribute`` chain resolves through the alias table to a fully
  qualified dotted path before any rule looks at it.
* **suppression** (``# repro-lint: disable=RA301`` on the offending
  line, or ``disable-next=`` on the line above) and a checked-in
  **baseline** file so a legacy violation can be grandfathered without
  weakening the gate for new code.
* **mtime caching**: per-file results are cached under
  ``~/.cache/repro/lint_cache.json`` (override: ``REPRO_LINT_CACHE``;
  ``off`` disables) keyed by (mtime, size, rules digest), so the
  ``make lint`` hot path re-parses only files that changed.
"""
from __future__ import annotations

import ast
import dataclasses
import hashlib
import json
import os
import re
import tempfile
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

__all__ = [
    "Violation", "ModuleInfo", "Rule", "analyze_file", "analyze_paths",
    "iter_source_files", "load_baseline", "write_baseline",
    "baseline_key", "repo_root", "default_roots", "DEFAULT_BASELINE",
]


_PRAGMA_RE = re.compile(r"#\s*repro-lint:\s*([^#]*)")
_DIRECTIVE_RE = re.compile(
    r"(disable|disable-next|fixture-as)\s*=\s*([\w./,\- ]+)")

_CACHE_ENV = "REPRO_LINT_CACHE"
_CACHE_FORMAT = 1


# --------------------------------------------------------------------------
# data model
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Violation:
    """One rule hit: ``path:line: RAxxx message``."""
    rule: str          # e.g. "RA201"
    path: str          # logical repo-relative posix path
    line: int
    message: str

    @property
    def family(self) -> str:
        return self.rule[:3]

    def format(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


def baseline_key(v: Violation) -> str:
    """Stable identity of a violation for baseline matching.

    Line numbers are excluded on purpose: unrelated edits above a
    grandfathered violation must not un-baseline it.
    """
    return f"{v.path}::{v.rule}::{v.message}"


class Rule:
    """Base class: one named, suppressible invariant check.

    Subclasses set ``id`` (e.g. ``"RA201"``), ``title``, and implement
    :meth:`check`; the class docstring records the motivating incident
    (shown by ``python -m repro.analysis --list-rules``).
    """

    id: str = ""
    title: str = ""

    @property
    def family(self) -> str:
        return self.id[:3]

    def check(self, mi: "ModuleInfo") -> Iterable[Violation]:
        raise NotImplementedError

    def hit(self, mi: "ModuleInfo", node: ast.AST, message: str) -> Violation:
        return Violation(rule=self.id, path=mi.logical,
                         line=getattr(node, "lineno", 1), message=message)


# --------------------------------------------------------------------------
# module model
# --------------------------------------------------------------------------

def _module_name(logical: str) -> str:
    """Dotted module name of a logical repo path.

    ``src/repro/core/api.py`` -> ``repro.core.api``;
    ``tests/test_x.py`` -> ``tests.test_x`` (never a ``repro.*`` name,
    so library-scoped rules skip non-library trees automatically).
    """
    p = logical.replace(os.sep, "/")
    if p.startswith("src/"):
        p = p[len("src/"):]
    if p.endswith(".py"):
        p = p[:-3]
    parts = [seg for seg in p.split("/") if seg]
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


class ModuleInfo:
    """One parsed file plus everything the rules need to query it."""

    def __init__(self, path: str, source: str, logical: str):
        self.path = path
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        self.logical = logical.replace(os.sep, "/")
        self.module = _module_name(self.logical)
        self.parents: Dict[ast.AST, ast.AST] = {}
        for parent in ast.walk(self.tree):
            for child in ast.iter_child_nodes(parent):
                self.parents[child] = parent
        self.aliases = self._collect_aliases()
        self.suppressed = self._collect_suppressions()
        # (lineno, fully-qualified dotted target) for every import binding
        self.import_targets = self._collect_import_targets()

    # -- pragmas -----------------------------------------------------------

    @staticmethod
    def parse_pragmas(source: str) -> List[Tuple[int, str, str]]:
        """All ``(lineno, directive, value)`` repro-lint pragmas."""
        out = []
        for i, line in enumerate(source.splitlines(), start=1):
            m = _PRAGMA_RE.search(line)
            if not m:
                continue
            for dm in _DIRECTIVE_RE.finditer(m.group(1)):
                out.append((i, dm.group(1), dm.group(2).strip()))
        return out

    def _collect_suppressions(self) -> Dict[int, Set[str]]:
        sup: Dict[int, Set[str]] = {}
        for line, directive, value in self.parse_pragmas(self.source):
            ids = {tok.strip() for tok in value.split(",") if tok.strip()}
            if directive == "disable":
                sup.setdefault(line, set()).update(ids)
            elif directive == "disable-next":
                sup.setdefault(line + 1, set()).update(ids)
        return sup

    def is_suppressed(self, v: Violation) -> bool:
        ids = self.suppressed.get(v.line, ())
        return v.rule in ids or v.family in ids

    # -- imports and name resolution --------------------------------------

    def _package(self) -> List[str]:
        parts = self.module.split(".") if self.module else []
        if self.logical.endswith("__init__.py"):
            return parts
        return parts[:-1]

    def _collect_aliases(self) -> Dict[str, str]:
        """Local name -> fully qualified dotted target, from imports."""
        aliases: Dict[str, str] = {}
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for al in node.names:
                    if al.asname:
                        aliases[al.asname] = al.name
                    else:
                        root = al.name.split(".")[0]
                        aliases.setdefault(root, root)
            elif isinstance(node, ast.ImportFrom):
                base: List[str] = []
                if node.level:
                    pkg = self._package()
                    drop = node.level - 1
                    base = pkg[:len(pkg) - drop] if drop else pkg
                if node.module:
                    base = base + node.module.split(".")
                for al in node.names:
                    if al.name == "*":
                        continue
                    target = ".".join(base + [al.name])
                    aliases[al.asname or al.name] = target
        return aliases

    def _collect_import_targets(self) -> List[Tuple[int, str]]:
        out: List[Tuple[int, str]] = []
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for al in node.names:
                    out.append((node.lineno, al.name))
            elif isinstance(node, ast.ImportFrom):
                base: List[str] = []
                if node.level:
                    pkg = self._package()
                    drop = node.level - 1
                    base = pkg[:len(pkg) - drop] if drop else pkg
                if node.module:
                    base = base + node.module.split(".")
                for al in node.names:
                    if al.name == "*":
                        out.append((node.lineno, ".".join(base)))
                    else:
                        out.append((node.lineno, ".".join(base + [al.name])))
        return out

    def dotted(self, node: ast.AST) -> Optional[str]:
        """Fully-qualified dotted path of a Name/Attribute chain.

        Resolves the root through the alias table, so ``sm.shard_map``
        after ``import jax.experimental.shard_map as sm`` yields
        ``jax.experimental.shard_map.shard_map`` — the resolution step
        the literal grep gates fundamentally could not perform.
        """
        parts: List[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if isinstance(node, ast.Name):
            parts.append(self.aliases.get(node.id, node.id))
            return ".".join(reversed(parts))
        return None

    def references(self) -> List[Tuple[ast.AST, str]]:
        """Every maximal Name/Attribute chain, resolved to dotted form."""
        out: List[Tuple[ast.AST, str]] = []
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Attribute):
                if isinstance(self.parents.get(node), ast.Attribute):
                    continue  # only the outermost link of a chain
                dd = self.dotted(node)
                if dd:
                    out.append((node, dd))
            elif isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
                if isinstance(self.parents.get(node), ast.Attribute):
                    continue
                target = self.aliases.get(node.id)
                if target and target != node.id:
                    out.append((node, target))
        return out

    def functions(self) -> List[ast.AST]:
        return [n for n in ast.walk(self.tree)
                if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]


# --------------------------------------------------------------------------
# walking + caching
# --------------------------------------------------------------------------

def repo_root() -> str:
    """Repository root, derived from this package's location."""
    here = os.path.dirname(os.path.abspath(__file__))   # src/repro/analysis
    return os.path.dirname(os.path.dirname(os.path.dirname(here)))


DEFAULT_BASELINE = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "baseline.json")

_SKIP_DIRS = {"__pycache__", ".git", "analysis_fixtures", ".claude"}


def default_roots() -> List[str]:
    root = repo_root()
    roots = []
    for rel in ("src/repro", "benchmarks", "examples", "tests"):
        p = os.path.join(root, rel)
        if os.path.isdir(p):
            roots.append(p)
    return roots


def iter_source_files(paths: Sequence[str]) -> List[str]:
    out: List[str] = []
    for p in paths:
        if os.path.isfile(p):
            out.append(p)
            continue
        for dirpath, dirnames, filenames in os.walk(p):
            dirnames[:] = [d for d in dirnames if d not in _SKIP_DIRS]
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    out.append(os.path.join(dirpath, fn))
    return out


def _logical_path(path: str, source: str) -> str:
    """Repo-relative analysis path, honouring ``fixture-as`` pragmas."""
    for _, directive, value in ModuleInfo.parse_pragmas(source):
        if directive == "fixture-as":
            return value
    rel = os.path.relpath(os.path.abspath(path), repo_root())
    return rel.replace(os.sep, "/")


def _cache_path() -> Optional[str]:
    override = os.environ.get(_CACHE_ENV)
    if override is not None:
        if override.strip().lower() in ("", "off", "0", "none"):
            return None
        return os.path.expanduser(override)
    base = os.environ.get("XDG_CACHE_HOME",
                          os.path.join(os.path.expanduser("~"), ".cache"))
    return os.path.join(base, "repro", "lint_cache.json")


def _rules_digest(rules: Sequence[Rule]) -> str:
    """Digest of the analyzer's own sources + active rule ids.

    Any edit to the engine or the rule set invalidates every cached
    entry — a stale cache must never mask (or invent) violations.
    """
    h = hashlib.sha256()
    pkg = os.path.dirname(os.path.abspath(__file__))
    for fn in sorted(os.listdir(pkg)):
        if fn.endswith(".py"):
            with open(os.path.join(pkg, fn), "rb") as f:
                h.update(f.read())
    h.update(",".join(sorted(r.id for r in rules)).encode())
    return h.hexdigest()[:16]


def _load_cache(path: str) -> dict:
    try:
        with open(path) as f:
            payload = json.load(f)
    except (OSError, ValueError):
        return {}
    if not isinstance(payload, dict) \
            or payload.get("format") != _CACHE_FORMAT:
        return {}
    return payload.get("files", {})


def _store_cache(path: str, files: dict) -> None:
    payload = {"format": _CACHE_FORMAT, "files": files}
    try:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path),
                                   prefix=".lint.", suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f)
            os.replace(tmp, path)
        except BaseException:
            os.unlink(tmp)
            raise
    except OSError:
        pass  # read-only cache dir: degrade to uncached


# --------------------------------------------------------------------------
# analysis entry points
# --------------------------------------------------------------------------

def analyze_file(path: str, rules: Sequence[Rule],
                 explicit: bool = False) -> List[Violation]:
    """Run ``rules`` over one file; [] for fixture files unless explicit.

    Fixture files (bearing a ``fixture-as`` pragma) are skipped during
    tree walks — they contain violations *on purpose* — but analyzed
    normally when named directly (the fixture tests do exactly that).
    """
    with open(path, encoding="utf-8") as f:
        source = f.read()
    logical = _logical_path(path, source)
    is_fixture = logical != os.path.relpath(
        os.path.abspath(path), repo_root()).replace(os.sep, "/")
    if is_fixture and not explicit:
        return []
    try:
        mi = ModuleInfo(path, source, logical)
    except SyntaxError as e:
        return [Violation(rule="RA000", path=logical,
                          line=e.lineno or 1,
                          message=f"syntax error: {e.msg}")]
    out: List[Violation] = []
    seen: Set[Tuple[str, int]] = set()
    for rule in rules:
        for v in rule.check(mi):
            if (v.rule, v.line) in seen:
                continue  # one report per rule per line
            seen.add((v.rule, v.line))
            if not mi.is_suppressed(v):
                out.append(v)
    out.sort(key=lambda v: (v.path, v.line, v.rule))
    return out


def analyze_paths(paths: Sequence[str], rules: Sequence[Rule],
                  use_cache: bool = True,
                  explicit_fixtures: bool = False) -> List[Violation]:
    """Analyze files/trees, with the mtime cache on the walk hot path."""
    files = iter_source_files(paths)
    cache_file = _cache_path() if use_cache else None
    cache = _load_cache(cache_file) if cache_file else {}
    digest = _rules_digest(rules)
    out: List[Violation] = []
    fresh: dict = {}
    dirty = False
    for path in files:
        ap = os.path.abspath(path)
        try:
            st = os.stat(ap)
        except OSError:
            continue
        entry = cache.get(ap)
        if (entry is not None and entry.get("digest") == digest
                and entry.get("mtime") == st.st_mtime
                and entry.get("size") == st.st_size):
            vs = [Violation(**d) for d in entry["violations"]]
        else:
            vs = analyze_file(ap, rules, explicit=explicit_fixtures)
            dirty = True
        fresh[ap] = {"digest": digest, "mtime": st.st_mtime,
                     "size": st.st_size,
                     "violations": [dataclasses.asdict(v) for v in vs]}
        out.extend(vs)
    if cache_file and dirty:
        _store_cache(cache_file, fresh)
    out.sort(key=lambda v: (v.path, v.line, v.rule))
    return out


# --------------------------------------------------------------------------
# baseline
# --------------------------------------------------------------------------

def load_baseline(path: str = DEFAULT_BASELINE) -> Set[str]:
    try:
        with open(path) as f:
            payload = json.load(f)
    except (OSError, ValueError):
        return set()
    if not isinstance(payload, dict):
        return set()
    return set(payload.get("entries", []))


def write_baseline(violations: Sequence[Violation],
                   path: str = DEFAULT_BASELINE) -> str:
    payload = {
        "format": 1,
        "comment": "Grandfathered repro.analysis violations. Entries are "
                   "path::rule::message (line-independent). Shrink this "
                   "file; never grow it for new code.",
        "entries": sorted({baseline_key(v) for v in violations}),
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    return path
