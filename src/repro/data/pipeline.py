"""Deterministic synthetic data pipeline (host-sharded, restart-safe).

Generates reproducible pseudo-token streams: batch ``i`` is a pure
function of ``(seed, step, host_slice)`` so training is bitwise
reproducible across restarts and *elastic* reshards — a host joining with
a different data-parallel size regenerates exactly the global batch it is
responsible for.  A markov-ish structure (token t+1 depends on t) gives
the LM a learnable signal for convergence tests.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["DataConfig", "SyntheticLM", "make_batch"]


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0


def make_batch(cfg: DataConfig, step: int, *, start: int = 0,
               count: Optional[int] = None):
    """Rows ``[start, start+count)`` of the global batch for ``step``.

    Learnable structure: ``tok[t+1] = (a * tok[t] + b + noise) % vocab``
    with per-sequence (a, b) drawn from a small pool.
    """
    count = cfg.global_batch if count is None else count
    # fixed affine map (shared across sequences) + rare noise: strongly
    # learnable next-token structure for convergence tests
    a = 1 + 2 * ((cfg.seed % 8) + 1)
    b = (cfg.seed * 31 + 7) % cfg.vocab
    toks = np.empty((count, cfg.seq_len + 1), np.int32)
    for i in range(count):
        r = np.random.default_rng(
            np.uint64((cfg.seed * 7_919 + step) * 1_000_003 + start + i))
        x = np.empty(cfg.seq_len + 1, np.int64)
        x[0] = r.integers(0, cfg.vocab)
        noise = (r.random(cfg.seq_len) < 0.05) * r.integers(
            0, cfg.vocab, cfg.seq_len)
        for t in range(cfg.seq_len):
            x[t + 1] = (a * x[t] + b + noise[t]) % cfg.vocab
        toks[i] = x
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class SyntheticLM:
    """Iterator over global batches; slices rows for this host."""

    def __init__(self, cfg: DataConfig, *, host_index: int = 0,
                 host_count: int = 1, start_step: int = 0):
        assert cfg.global_batch % host_count == 0
        self.cfg = cfg
        self.per_host = cfg.global_batch // host_count
        self.start_row = host_index * self.per_host
        self.step = start_step

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        b = make_batch(self.cfg, self.step, start=self.start_row,
                       count=self.per_host)
        self.step += 1
        return b
