"""Fault-tolerant checkpointing: async, atomic, elastic.

* **Atomic**: each step writes to ``step_N.tmp/`` then ``os.replace``s to
  ``step_N/`` — a crashed writer never corrupts the latest checkpoint.
* **Async**: ``save`` snapshots to host memory (device_get) and hands the
  serialization to a background thread; training continues.  ``wait()``
  joins outstanding writes (called before exit and before deleting old
  steps).
* **Elastic**: arrays are stored as plain ``.npy`` with a JSON manifest of
  tree paths; ``restore`` rebuilds the pytree and ``jax.device_put``s with
  whatever sharding the *current* mesh prescribes — a checkpoint written
  on N hosts restores on M hosts (ZeRO re-sharding happens at load).
* **Retention**: keeps the newest ``keep`` complete checkpoints.

Quantized optimizer states (``optim.Quantized``) round-trip transparently
(int8 payload + scales are leaves).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["CheckpointManager"]


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    paths = [jax.tree_util.keystr(kp) for kp, _ in
             jax.tree_util.tree_flatten_with_path(tree)[0]]
    return leaves, paths, treedef


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------ save ----

    def save(self, step: int, tree: Any, *, blocking: bool = False):
        """Snapshot ``tree`` and write checkpoint ``step`` asynchronously."""
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)

        def write():
            try:
                tmp = os.path.join(self.dir, f"step_{step}.tmp")
                final = os.path.join(self.dir, f"step_{step}")
                if os.path.exists(tmp):
                    shutil.rmtree(tmp)
                os.makedirs(tmp)
                leaves, paths, _ = _flatten(host_tree)
                manifest = []
                for i, (leaf, path) in enumerate(zip(leaves, paths)):
                    np.save(os.path.join(tmp, f"{i}.npy"), leaf,
                            allow_pickle=False)
                    manifest.append({"i": i, "path": path,
                                     "dtype": str(leaf.dtype),
                                     "shape": list(leaf.shape)})
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump({"step": step, "leaves": manifest}, f)
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.replace(tmp, final)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=write, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # --------------------------------------------------------- restore ----

    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name,
                                               "manifest.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any, *, shardings: Any = None):
        """Rebuild ``like``-structured tree from checkpoint ``step``.

        ``shardings``: optional matching pytree of ``NamedSharding`` — when
        given, each leaf is ``device_put`` with it (elastic re-shard).
        """
        path = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        leaves_like, treedef = jax.tree_util.tree_flatten(like)
        assert len(manifest["leaves"]) == len(leaves_like), (
            len(manifest["leaves"]), len(leaves_like))
        arrs = [np.load(os.path.join(path, f"{e['i']}.npy"))
                for e in manifest["leaves"]]
        if shardings is not None:
            flat_sh = treedef.flatten_up_to(shardings)
            arrs = [jax.device_put(a, s) for a, s in zip(arrs, flat_sh)]
        else:
            arrs = [jax.device_put(a) for a in arrs]
        return jax.tree_util.tree_unflatten(treedef, arrs)
