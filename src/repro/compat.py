"""JAX version-compatibility shim (single source of truth).

Every version-sensitive JAX attribute lookup in this repo lives here —
the rule (enforced by ``make check``'s grep gate) is: **no raw
``jax.shard_map`` / ``jax.typeof`` / ``jax.lax.pcast`` /
``pltpu.CompilerParams`` outside this module**.

Resolved surfaces, spanning JAX 0.4.x -> 0.5.x+ and nightlies:

* :func:`shard_map` — ``jax.shard_map`` (0.5+) vs
  ``jax.experimental.shard_map.shard_map`` (0.4.x, with ``check_rep``
  disabled: the pipelined collectives in ``repro.dist`` are not
  replication-inferable on the old checker).
* :func:`varying_axes` / :func:`pvary` / :func:`pvary_like` — the
  varying-manual-axes ("vma") type system.  Nightlies track which mesh
  axes a value varies over and require explicit ``pcast``/``pvary`` to
  make loop-carry types agree; 0.4.x has no such tracking, so the probe
  returns ``()`` and the cast is the identity.
* :func:`tpu_compiler_params` — ``pltpu.CompilerParams`` (new name) vs
  ``pltpu.TPUCompilerParams`` (0.4.x) vs a raw ``mosaic`` params dict
  (very old releases).
* :func:`default_platform` / :func:`is_tpu` — backend detection used by
  the dispatch registry to gate Pallas backends and interpret mode.
"""
from __future__ import annotations

from typing import Any, Iterable, Sequence

import jax

__all__ = [
    "JAX_VERSION",
    "MIN_SUPPORTED",
    "shard_map",
    "varying_axes",
    "pvary",
    "pvary_like",
    "tpu_compiler_params",
    "default_platform",
    "is_tpu",
    "is_tracer",
    "pallas_interpret_default",
    "enable_x64",
    "x64_enabled",
]


def _parse_version(v: str) -> tuple:
    parts = []
    for tok in v.split(".")[:3]:
        num = "".join(ch for ch in tok if ch.isdigit())
        parts.append(int(num) if num else 0)
    return tuple(parts)


JAX_VERSION: tuple = _parse_version(jax.__version__)
MIN_SUPPORTED: tuple = (0, 4, 37)

if JAX_VERSION < MIN_SUPPORTED:  # pragma: no cover - old-env guard
    import warnings

    warnings.warn(
        f"repro supports JAX >= {'.'.join(map(str, MIN_SUPPORTED))}; "
        f"found {jax.__version__}. Expect breakage.",
        stacklevel=2,
    )


# --------------------------------------------------------------------------
# shard_map
# --------------------------------------------------------------------------

def shard_map(f, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` with a 0.4.x experimental-namespace fallback."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    # check_rep=False: the 0.4.x replication checker rejects the manual
    # ppermute pipelines in repro.dist (same semantics either way).
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=False,
    )


# --------------------------------------------------------------------------
# varying-manual-axes (vma) probing and casting
# --------------------------------------------------------------------------

def varying_axes(x: Any) -> tuple:
    """Mesh axes ``x`` is device-varying over inside ``shard_map``.

    On JAX versions without vma tracking (<= 0.4.x) this is always
    ``()`` — those versions do not distinguish varying from replicated
    values in the type system, so no cast is ever needed.
    """
    typeof = getattr(jax, "typeof", None)
    if typeof is None:
        return ()
    try:
        aval = typeof(x)
    except Exception:
        return ()
    return tuple(getattr(aval, "vma", ()) or ())


def pvary(x, axes: Sequence[str]):
    """Cast ``x`` to be device-varying over ``axes`` (identity if n/a)."""
    axes = tuple(axes)
    if not axes:
        return x
    lax = jax.lax
    if hasattr(lax, "pcast"):
        return lax.pcast(x, axes, to="varying")
    if hasattr(lax, "pvary"):
        return lax.pvary(x, axes)
    return x  # no vma type system: replicated values are fine as carries


def pvary_like(x, ref: Any, extra: Iterable[str] = ()):
    """Match ``x``'s varying-axes type to ``ref`` (plus ``extra`` axes).

    The canonical use is making a freshly created constant (identity
    matrix, zero carry) a legal ``scan``/``fori_loop`` carry alongside
    device-varying operands inside ``shard_map``.
    """
    want = set(varying_axes(ref)) | set(extra)
    need = tuple(sorted(want - set(varying_axes(x))))
    return pvary(x, need) if need else x


# --------------------------------------------------------------------------
# Pallas TPU compiler params
# --------------------------------------------------------------------------

def tpu_compiler_params(**kwargs):
    """Construct TPU compiler params across the pltpu renames.

    ``pltpu.CompilerParams`` (new) -> ``pltpu.TPUCompilerParams``
    (0.4.x) -> ``{"mosaic": {...}}`` dict (ancient).  Unknown kwargs are
    dropped with a warning rather than crashing, so newer tuning knobs
    degrade gracefully on older compilers.
    """
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = getattr(pltpu, "TPUCompilerParams", None)
    if cls is None:  # pragma: no cover - ancient JAX
        return dict(mosaic=kwargs)
    fields = getattr(cls, "__dataclass_fields__", None)
    if fields is not None:
        unknown = [k for k in kwargs if k not in fields]
        if unknown:  # pragma: no cover - forward-compat path
            import warnings

            warnings.warn(
                f"dropping TPU compiler params unsupported on "
                f"jax {jax.__version__}: {unknown}", stacklevel=2,
            )
            kwargs = {k: v for k, v in kwargs.items() if k in fields}
    return cls(**kwargs)


# --------------------------------------------------------------------------
# platform detection
# --------------------------------------------------------------------------

def default_platform() -> str:
    """Lowercase default backend platform: ``cpu`` / ``gpu`` / ``tpu``."""
    try:
        return jax.default_backend().lower()
    except Exception:  # pragma: no cover - uninitialized backends
        return "cpu"


def is_tpu() -> bool:
    return default_platform() == "tpu"


def _tracer_class():
    """Resolve the abstract-tracer base across the jax.core shuffles."""
    import warnings

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        core = getattr(jax, "core", None)
        return getattr(core, "Tracer", None) if core is not None else None


_TRACER_CLS = _tracer_class()


def is_tracer(x: Any) -> bool:
    """Whether ``x`` is an abstract tracer (inside jit/vmap/grad).

    Host-side instrumentation (repro.obs spans, roofline timing) must
    be a no-op under tracing — there is no concrete value to time and
    ``block_until_ready`` would be meaningless — so every instrumented
    seam guards with this.
    """
    return _TRACER_CLS is not None and isinstance(x, _TRACER_CLS)


def pallas_interpret_default() -> bool:
    """Interpret-mode default for Pallas calls: compiled only on TPU."""
    return not is_tpu()


# --------------------------------------------------------------------------
# 64-bit mode
# --------------------------------------------------------------------------

def x64_enabled() -> bool:
    """Whether jnp currently keeps float64 inputs at 64-bit precision."""
    return bool(jax.config.read("jax_enable_x64"))


def enable_x64(enabled: bool = True):
    """Context manager scoping 64-bit mode (float64 eigen/SVD paths).

    ``jax.experimental.enable_x64`` where available (all supported
    versions), else a manual ``jax.config`` toggle with restore.
    """
    cm = getattr(__import__("jax.experimental", fromlist=["enable_x64"]),
                 "enable_x64", None)
    if cm is not None:
        return cm(enabled)

    import contextlib  # pragma: no cover - future-proofing fallback

    @contextlib.contextmanager
    def _toggle():
        prev = x64_enabled()
        jax.config.update("jax_enable_x64", enabled)
        try:
            yield
        finally:
            jax.config.update("jax_enable_x64", prev)

    return _toggle()
