"""Serve a small model with batched requests (greedy decode).

    PYTHONPATH=src python examples/serve_lm.py
"""
import jax

from repro.configs import get_config
from repro.obs import timing
from repro.models import build_model
from repro.serve import ServeEngine

cfg = get_config("smollm-135m").reduced()
model = build_model(cfg)
params = model.init(jax.random.key(0))

eng = ServeEngine(model, cfg, params, batch=4, max_len=96)
prompts = [[1, 2, 3, 4], [10, 11], [42, 43, 44], [7]]
t0 = timing.now()
outs = eng.generate(prompts, max_new=24)
dt = timing.now() - t0
for p, o in zip(prompts, outs):
    print(f"prompt={p} -> completion={o}")
tok = sum(map(len, outs))
print(f"{tok} tokens, {tok/dt:.1f} tok/s (batched greedy, CPU)")
