"""Quickstart: apply a sequence of planar rotations to a matrix.

Demonstrates the API ladder from the paper's baseline to the optimized
TPU-oriented paths, verifies they agree, and shows the idiomatic
plan-once/apply-many flow (plus autodiff) of the first-class
``RotationSequence`` type.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import apply_rotation_sequence, random_sequence
from repro.obs import timing

m, n, k = 1024, 512, 64
A = jnp.asarray(np.random.default_rng(0).standard_normal((m, n)),
                jnp.float32)
seq = random_sequence(jax.random.key(0), n, k)  # a RotationSequence

print(f"A: {m}x{n}, rotations: {n-1}x{k}  "
      f"({6*m*(n-1)*k/1e9:.2f} Gflop)")

ref = None
for method, kw in [
    ("unoptimized", {}),                       # Algorithm 1.2
    ("blocked", dict(n_b=64, k_b=16)),         # paper SS2/SS5 blocking
    ("accumulated", dict(n_b=96, k_b=96)),     # rs_gemm / TPU MXU path
]:
    fn = lambda: seq.apply(A, method=method, **kw)
    out = jax.block_until_ready(fn())
    t0 = timing.now()
    jax.block_until_ready(fn())
    dt = timing.now() - t0
    if ref is None:
        ref = out
    err = float(jnp.abs(out - ref).max())
    print(f"{method:12s} {dt*1e3:8.1f} ms   "
          f"{6*m*(n-1)*k/dt/1e9:7.2f} Gflop/s   max|diff|={err:.2e}")

# plan-once/apply-many: resolve the registry a single time, then hit the
# chosen backend directly on every call
plan = seq.plan(like=A, method="auto")
out_auto = jax.block_until_ready(plan.apply(A))
print(f"plan: {plan.method}  kwargs={dict(plan.kwargs)}  "
      f"max|diff|={float(jnp.abs(out_auto - ref).max()):.2e}")

# composition: the transposed sequence undoes the original ...
roundtrip = seq.T.apply(seq.apply(A, method="blocked"), method="blocked")
print(f"seq.T roundtrip        max|diff|={float(jnp.abs(roundtrip - A).max()):.2e}")

# ... and jax.grad works through plan.apply (cotangent = one application
# of the transposed sequence; no unrolled rotation tape)
g = jax.grad(lambda a: (plan.apply(a) ** 2).sum())(A)
print(f"jax.grad through plan.apply: grad shape {g.shape}")

# the raw-array compat wrapper is still available for loose C/S arrays
out_compat = apply_rotation_sequence(A, seq.cos, seq.sin, method="auto")
assert (out_compat == out_auto).all()

# Pallas TPU kernels, validated in interpret mode on CPU
out = seq.apply(A[:64], method="pallas_mxu", n_b=32, k_b=32, m_blk=64)
err = float(jnp.abs(out - ref[:64]).max())
print(f"pallas_mxu (interpret)  max|diff|={err:.2e}")
print("OK")
