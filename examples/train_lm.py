"""End-to-end driver: train a language model on the synthetic pipeline.

Reduced configs run on CPU; full configs target the production mesh via
the launcher.  Trains a few hundred steps, checkpoints, and proves
restart-resume continuity.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import tempfile

import jax

from repro.configs import get_config
from repro.data import DataConfig, SyntheticLM
from repro.models import build_model
from repro.optim import AdamW, warmup_cosine
from repro.train import TrainLoop, make_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="smollm-135m")
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--full", action="store_true",
                help="use the full (published-size) config")
args = ap.parse_args()

cfg = get_config(args.arch)
if not args.full:
    cfg = cfg.reduced()
model = build_model(cfg)
params = model.init(jax.random.key(0))
print(f"{cfg.name}: {sum(x.size for x in jax.tree.leaves(params))/1e6:.1f}M "
      f"params ({'full' if args.full else 'reduced'})")

opt = AdamW(lr=warmup_cosine(3e-3, warmup=20, total=args.steps))
step = jax.jit(make_train_step(model, cfg, opt, remat=False))
data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8))

with tempfile.TemporaryDirectory() as ckpt_dir:
    loop = TrainLoop(train_step=step, params=params,
                     opt_state=opt.init(params), data_iter=data,
                     ckpt_dir=ckpt_dir, ckpt_every=max(args.steps // 4, 1))
    half = args.steps // 2
    hist = loop.run(half)
    print(f"step {half}: loss {hist['loss'][-1]:.4f} "
          f"(from {hist['loss'][0]:.4f})")
    # simulate a preemption: new loop restores and continues
    loop2 = TrainLoop(train_step=step, params=params,
                      opt_state=opt.init(params),
                      data_iter=SyntheticLM(
                          DataConfig(vocab=cfg.vocab, seq_len=64,
                                     global_batch=8)),
                      ckpt_dir=ckpt_dir)
    restored = loop2.maybe_restore()
    print(f"restart: restored step {restored}")
    hist2 = loop2.run(args.steps - restored)
    print(f"final loss {hist2['loss'][-1]:.4f}")
