"""Eigendecomposition and SVD via recorded rotation sequences.

Exercises the public ``repro.eig`` API: both ``eigh_givens`` methods —
round-robin Jacobi and implicit-shift tridiagonal QR — record their
pivots in the paper's ``(n-1, K)`` C/S layout and accumulate the
eigenbasis by *delayed* application through the registry-dispatched
appliers (paper SS5.1), then a Golub-Kahan ``svd_givens`` round-trip.

    PYTHONPATH=src python examples/jacobi_eig.py
"""
import jax.numpy as jnp
import numpy as np

from repro.eig import eigh_givens, svd_givens
from repro.obs import timing

n = 64
rng = np.random.default_rng(0)
X = rng.standard_normal((n, n)).astype(np.float32)
H = jnp.asarray((X + X.T) / 2)
ref = np.sort(np.linalg.eigvalsh(np.asarray(H, np.float64)))
scale = np.abs(ref).max()

print(f"eigh_givens on a random symmetric {n}x{n} (float32):\n")
print(f"{'method':>8} {'val err':>10} {'|V^T V - I|':>12} "
      f"{'|V^T H V - L|':>14} {'time':>8}")
results = {}
for method in ("jacobi", "qr"):
    t0 = timing.now()
    w, V = eigh_givens(H, method=method, k_delay=32)
    dt = timing.now() - t0
    Vn = np.asarray(V, np.float64)
    val_err = np.abs(np.asarray(w) - ref).max() / scale
    orth = np.abs(Vn.T @ Vn - np.eye(n)).max()
    resid = np.abs(Vn.T @ np.asarray(H, np.float64) @ Vn
                   - np.diag(np.asarray(w, np.float64))).max() / scale
    results[method] = (val_err, orth, resid, dt)
    print(f"{method:>8} {val_err:>10.2e} {orth:>12.2e} "
          f"{resid:>14.2e} {dt:>7.2f}s")

assert all(r[0] < 1e-4 and r[2] < 1e-3 for r in results.values())

m, k = 96, 48
A = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
t0 = timing.now()
U, s, Vt = svd_givens(A)
dt = timing.now() - t0
sr = np.linalg.svd(np.asarray(A, np.float64), compute_uv=False)
rec = np.abs(np.asarray(U, np.float64) @ np.diag(np.asarray(s, np.float64))
             @ np.asarray(Vt, np.float64) - np.asarray(A)).max()
print(f"\nsvd_givens {m}x{k}: sing-val err "
      f"{np.abs(np.asarray(s) - sr).max() / sr.max():.2e}, "
      f"reconstruction {rec:.2e}, {dt:.2f}s")
print("OK")
