"""Eigendecomposition via rotation sequences (the paper's use-case).

Round-robin Jacobi records its pivots as a mixed rotation/reflector
sequence; the eigenbasis is recovered by applying the *recorded
sequence* with the optimized appliers — the "delayed sequences of
rotations" pattern (paper SS5.1) that motivates the whole kernel.

    PYTHONPATH=src python examples/jacobi_eig.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import jacobi_apply_basis, jacobi_eigh

n = 64
rng = np.random.default_rng(0)
X = rng.standard_normal((n, n)).astype(np.float32)
H = jnp.asarray((X + X.T) / 2)

res = jacobi_eigh(H, cycles=8)
print(f"n={n}: {res.cos.shape[1]} recorded waves, "
      f"off-diagonal norm {float(res.off_norm):.2e}")

ev = np.sort(np.asarray(res.eigenvalues))
ref = np.sort(np.linalg.eigvalsh(np.asarray(H, np.float64)))
print(f"eigenvalue max err vs numpy: {np.abs(ev - ref).max():.2e}")

# delayed application: rotate a tall matrix into the eigenbasis without
# ever forming V — this is where the optimized appliers earn their keep
G = jnp.asarray(rng.standard_normal((512, n)), jnp.float32)
GV = jacobi_apply_basis(res, G, method="accumulated")
V = jacobi_apply_basis(res, method="accumulated")
err = float(jnp.abs(GV - G @ V).max())
print(f"delayed-sequence application err: {err:.2e}")
print("OK")
