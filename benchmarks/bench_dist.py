"""Distributed-plan suite: sharded-fused vs replicated bucket execution.

Times a batch bucket applied through a row-sharded
:class:`repro.dist.ShardedSequencePlan` (one planned launch per shard
under ``shard_map``) against the replicated
``SequencePlan.apply_batched`` path on a forced 8-device host mesh, in
a subprocess (``XLA_FLAGS=--xla_force_host_platform_device_count``
must be set before JAX initializes).  Alongside the measured speedup
the suite reports the comm-extended §6 cost model's view of the same
problem — modeled inter-device bytes and the sharded-vs-replicated
crossover ratio — as deterministic warn-only context rows, so model
retunes surface in the BENCH artifacts without gating unrelated PRs.

Gating rows (``compare_baseline.SPEC``):

* ``dist/sharded_vs_replicated:speedup`` — replicated/sharded wall
  time; the abs_floor encodes "sharded execution stays in its
  performance class on a CPU CI host".
* ``:launches_per_shard`` (count) — exactly one planned launch per
  shard, the PR 10 acceptance invariant.
* ``:parity`` (count) — sharded output bit-identical to replicated.
"""
import os
import subprocess
import sys
import textwrap

from benchmarks.common import emit

DEVICES = 8

_CODE = """
import numpy as np, jax, jax.numpy as jnp
from repro import dist, obs
from repro.obs import timing
from repro.core.rotations import random_sequence

D = {D}
mesh = jax.make_mesh((D,), ("data",))
b, m, n, k = {b}, {m}, {n}, {k}
rng = np.random.default_rng(0)
A = jnp.asarray(rng.standard_normal((b, m, n)), jnp.float32)
seq = random_sequence(jax.random.key(0), n, k)

plan_sh = dist.plan_sharded(seq, like=A, mesh=mesh, method="blocked")
plan_rep = seq.plan(like=A, method="blocked", shared_sequence=True)

def timed(fn):
    jax.block_until_ready(fn())
    ts = []
    for _ in range(5):
        t0 = timing.now(); jax.block_until_ready(fn())
        ts.append(timing.now() - t0)
    return sorted(ts)[len(ts) // 2]

sh = timed(lambda: plan_sh.apply_batched(A, direct=True))
rep = timed(lambda: plan_rep.apply_batched(A, direct=True))

obs.set_enabled(True)
obs.reset()
out = plan_sh.apply_batched(A)
snap = obs.snapshot()
obs.set_enabled(False)
launches = snap["gauges"].get("dist.launches_per_shard", 0.0)
comm = snap["counters"].get("dist.comm_bytes", 0)
parity = int(bool(jnp.array_equal(out, plan_rep.apply_batched(A))))
sh_s, rep_s = dist.modeled_crossover(m, n, k, devices=D, batch=b,
                                     shared_sequence=True)
print("RESULT %.6f %.6f %.0f %.0f %d %.6e %.6e"
      % (sh, rep, launches, comm, parity, sh_s, rep_s))
"""


def run(quick: bool = False) -> None:
    b, m, n, k = (8, 256, 64, 16) if quick else (64, 512, 128, 32)
    env = dict(os.environ)
    env["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={DEVICES}"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    code = textwrap.dedent(_CODE.format(D=DEVICES, b=b, m=m, n=n, k=k))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=900, env=env)
    line = [ln for ln in r.stdout.splitlines() if ln.startswith("RESULT")]
    if not line:
        emit(f"dist/sharded_vs_replicated", 0.0, "FAILED")
        print(r.stdout, r.stderr, file=sys.stderr)
        return
    sh, rep, launches, comm, parity, sh_s, rep_s = \
        map(float, line[0].split()[1:])
    speedup = rep / sh if sh > 0 else 0.0
    emit("dist/sharded_vs_replicated", sh,
         f"speedup_{speedup:.2f}x_D{DEVICES}",
         metrics={"speedup": speedup, "parity": parity,
                  "launches_per_shard": launches})
    # deterministic cost-model context: modeled wire traffic for the
    # dispatch above, and how far the model says the sharded plan is
    # from the replicated one at this shape (ratio > 1: sharded wins)
    emit("dist/comm_model", 0.0,
         f"{comm:.0f}B_ratio_{rep_s / sh_s:.2f}",
         metrics={"comm_bytes": comm,
                  "modeled_crossover_ratio": rep_s / sh_s})


def main() -> None:
    """Standalone CLI used by CI: ``bench_dist.py --quick --json PATH``."""
    import argparse

    from benchmarks import common

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small bucket (CI artifact/regression run)")
    ap.add_argument("--json", default=None, metavar="PATH")
    args = ap.parse_args()

    common.reset_results()
    print("name,us_per_call,derived")
    run(quick=args.quick)
    if args.json:
        common.write_json(args.json, meta={"quick": args.quick,
                                           "devices": DEVICES})


if __name__ == "__main__":
    main()
