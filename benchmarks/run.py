"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``--only fig5`` restricts.
"""
import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the emitted rows as a JSON artifact "
                         "(BENCH_*.json for the CI regression compare)")
    args = ap.parse_args()

    from benchmarks import (bench_eig, bench_fig5, bench_fig6, bench_fig7,
                            bench_fig8, bench_iolb, bench_memops,
                            bench_serve, bench_smoke, common)
    suites = {
        "smoke": bench_smoke,
        "fig5": bench_fig5, "fig6": bench_fig6, "fig7": bench_fig7,
        "fig8": bench_fig8, "memops": bench_memops, "iolb": bench_iolb,
        "eig": bench_eig, "serve": bench_serve,
    }
    if args.only and args.only not in suites:
        ap.error(f"unknown suite {args.only!r}; one of {sorted(suites)}")
    print("name,us_per_call,derived")
    common.reset_results()
    failed = []
    for name, mod in suites.items():
        if args.only and name != args.only:
            continue
        try:
            mod.run()
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if args.json:
        common.write_json(args.json, meta={"only": args.only})
        print(f"wrote {args.json}", file=sys.stderr)
    if failed:
        print(f"FAILED suites: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == '__main__':
    main()
