"""Paper Fig. 5: serial flop rates of the algorithm ladder.

CPU-host mapping of the paper's variants (XLA replaces hand-written AVX):

  rs_unoptimized -> Algorithm 1.2 (fori_loop)
  rs_wavefront   -> Algorithm 1.3
  rs_fused       -> blocked with k_b = 2 (the 2x2-fusing reuse level)
  rs_kernel      -> blocked with tuned (n_b, k_b) (our wavefront kernel)
  rs_gemm        -> accumulated tile factors + GEMM sweeps (MXU path)

k = 180 (paper's setting), m = n swept.  The paper's finding — kernel >
fused > blocked > unoptimized, gemm wins at scale — is reproduced on the
XLA-CPU host; on the TPU target the gemm/MXU path is the headline (see
EXPERIMENTS.md SSPerf).
"""
from functools import partial

from repro.core.accumulate import rot_sequence_accumulated
from repro.core.blocked import rot_sequence_blocked
from repro.core.ref import rot_sequence_unoptimized, rot_sequence_wavefront

from benchmarks.common import emit, flops_of, problem, time_fn

VARIANTS = [
    ("rs_unoptimized", rot_sequence_unoptimized, (240, 480)),
    ("rs_wavefront", rot_sequence_wavefront, (240, 480)),
    ("rs_fused", partial(rot_sequence_blocked, n_b=64, k_b=2),
     (240, 480, 960)),
    ("rs_kernel", partial(rot_sequence_blocked, n_b=64, k_b=16),
     (240, 480, 960)),
    ("rs_gemm", partial(rot_sequence_accumulated, n_b=96, k_b=96),
     (240, 480, 960, 1920)),
]

K = 180


def run():
    for name, fn, sizes in VARIANTS:
        for n in sizes:
            A, seq = problem(n, n, K)
            dt = time_fn(fn, A, seq.cos, seq.sin)
            gf = flops_of(n, n, K) / dt / 1e9
            emit(f"fig5/{name}/n{n}", dt, f"{gf:.2f}_Gflops")


if __name__ == "__main__":
    run()
