"""Paper Fig. 6: kernel-size sweep (block shapes of the two TPU kernels).

The paper sweeps register tilings (m_r, k_r); the TPU analogue sweeps the
VMEM tile shape (n_b, k_b) of the blocked/accumulated algorithms.  The
paper's observation that a *flatter* tile (m_r=16, k_r=2) can beat the
memory-op-optimal one (m_r=8, k_r=5) shows up here as the n_b >> k_b
preference of the direct method vs the square preference of the MXU path.
"""
from functools import partial

from repro.core.accumulate import rot_sequence_accumulated
from repro.core.blocked import rot_sequence_blocked

from benchmarks.common import emit, flops_of, problem, time_fn

K = 180
N = 720


def run():
    A, seq = problem(N, N, K)
    for (n_b, k_b) in [(16, 2), (32, 4), (64, 8), (64, 16), (128, 16),
                       (32, 32), (16, 5)]:
        fn = partial(rot_sequence_blocked, n_b=n_b, k_b=k_b)
        dt = time_fn(fn, A, seq.cos, seq.sin)
        gf = flops_of(N, N, K) / dt / 1e9
        emit(f"fig6/blocked/nb{n_b}_kb{k_b}", dt, f"{gf:.2f}_Gflops")
    for (n_b, k_b) in [(32, 32), (64, 64), (96, 96), (128, 128),
                       (192, 64), (64, 192)]:
        fn = partial(rot_sequence_accumulated, n_b=n_b, k_b=k_b)
        dt = time_fn(fn, A, seq.cos, seq.sin)
        gf = flops_of(N, N, K) / dt / 1e9
        emit(f"fig6/accum/nb{n_b}_kb{k_b}", dt, f"{gf:.2f}_Gflops")


if __name__ == "__main__":
    run()
