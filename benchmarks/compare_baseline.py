"""CI perf-regression gate: compare BENCH_*.json artifacts to a baseline.

The tracked metrics live in :data:`SPEC`; the checked-in baseline
(``benchmarks/baselines/bench_baseline.json``) pins their reference
values.  Count-based metrics (rotation counts, plans resolved, buckets)
are compared near-exactly — they are deterministic where wall times are
noisy; rate metrics (interpret-mode Mrot/s, dispatch overhead) fail the
job when they regress more than ``rel_tol`` (default 30%) past the
baseline, with an ``abs_floor`` below which micro-timing jitter is
ignored.  Improvements never fail.

Usage::

  python benchmarks/compare_baseline.py \
      --baseline benchmarks/baselines/bench_baseline.json \
      BENCH_smoke.json BENCH_eig.json BENCH_serve.json

  # regenerate the baseline from fresh artifacts (then commit it)
  python benchmarks/compare_baseline.py --update --baseline ... BENCH_*.json
"""
from __future__ import annotations

import argparse
import json
import sys

# metric key: "<row name>:<metrics key>" as emitted by benchmarks.common
SPEC = {
    # dispatch overhead of per-call registry dispatch vs frozen
    # SequencePlan.apply — the plan-once/apply-many win; lower is better.
    "smoke/plan_once_apply_many:dispatch_overhead_us": dict(
        higher_is_better=False, rel_tol=0.30, abs_floor=500.0),
    # recorded-rotation application throughput (interpret-mode CPU CI).
    # Shared runners show ~2x wall-clock noise, so besides the 30%
    # relative band an absolute floor keeps the gate meaningful: any
    # run above it is in the right performance class (an
    # order-of-magnitude regression — e.g. dispatch falling off the
    # blocked path — still fails), while CPU-contention jitter passes.
    "eig/qr_apply_n64:mrot_s": dict(higher_is_better=True, rel_tol=0.30,
                                    abs_floor=0.5),
    # count-based: rotations recorded for the n=64 QR path (seeded,
    # deterministic up to libm convergence differences).
    "eig/qr_apply_n64:nrot": dict(higher_is_better=True, rel_tol=0.02,
                                  count=True),
    # count-based serving invariants: exactly one registry resolution
    # per shape bucket, and the expected bucket count.
    "serve/bucketed:plans_resolved": dict(higher_is_better=False,
                                          rel_tol=0.0, count=True),
    "serve/bucketed:buckets": dict(higher_is_better=False, rel_tol=0.0,
                                   count=True),
    # Serving wall-clock rates include Python admission overhead and
    # vary >30% even between runs on one machine, so they are tracked
    # as warn-only context rather than gating the job — the gating
    # serving metrics are the counts above (plus the issue-scoped
    # dispatch-overhead / Mrot/s rates).
    "serve/bucketed:req_s": dict(higher_is_better=True, rel_tol=0.30,
                                 warn_only=True),
    "serve/shared_batch:speedup": dict(higher_is_better=True,
                                       rel_tol=0.30, warn_only=True),
}


def _collect(artifact_paths) -> dict:
    """Flatten rows of all artifacts into {"row:metric": value}."""
    found = {}
    for path in artifact_paths:
        with open(path) as f:
            payload = json.load(f)
        for row in payload.get("rows", []):
            for mkey, val in row.get("metrics", {}).items():
                found[f"{row['name']}:{mkey}"] = float(val)
    return found


def _check(name: str, spec: dict, base: float, cur: float):
    """Returns (ok, message)."""
    rel_tol = spec.get("rel_tol", 0.30)
    floor = spec.get("abs_floor", 0.0)
    if spec.get("count"):
        ok = abs(cur - base) <= rel_tol * max(abs(base), 1.0)
        kind = "count"
    elif spec.get("higher_is_better", True):
        ok = cur >= base * (1.0 - rel_tol) or cur >= floor > 0
        kind = "rate"
    else:
        ok = cur <= base * (1.0 + rel_tol) or cur <= floor
        kind = "rate"
    verdict = "ok" if ok else "REGRESSED"
    return ok, (f"{verdict:9s} {name} [{kind}] "
                f"baseline={base:.4g} current={cur:.4g} "
                f"(rel_tol={rel_tol:.0%})")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--update", action="store_true",
                    help="write the baseline from the artifacts instead "
                         "of comparing")
    ap.add_argument("artifacts", nargs="+")
    args = ap.parse_args()

    found = _collect(args.artifacts)

    if args.update:
        metrics = {}
        for name in SPEC:
            if name not in found:
                sys.exit(f"cannot update baseline: metric {name!r} "
                         f"missing from artifacts")
            metrics[name] = found[name]
        with open(args.baseline, "w") as f:
            json.dump({"format": 1, "metrics": metrics}, f, indent=1,
                      sort_keys=True)
            f.write("\n")
        print(f"wrote baseline {args.baseline} ({len(metrics)} metrics)")
        return

    with open(args.baseline) as f:
        baseline = json.load(f)
    base_metrics = baseline.get("metrics", {})

    failures = []
    for name, base_val in sorted(base_metrics.items()):
        spec = SPEC.get(name, dict(higher_is_better=True, rel_tol=0.30))
        if name not in found:
            failures.append(name)
            print(f"MISSING   {name} (baseline={base_val:.4g}) — not "
                  f"emitted by the provided artifacts")
            continue
        ok, msg = _check(name, spec, float(base_val), found[name])
        if not ok and spec.get("warn_only"):
            msg = msg.replace("REGRESSED", "WARN     ") + " [warn-only]"
            ok = True
        print(msg)
        if not ok:
            failures.append(name)
    if failures:
        sys.exit(f"benchmark regression gate failed: {failures}")
    print(f"benchmark regression gate passed "
          f"({len(base_metrics)} metrics)")


if __name__ == "__main__":
    main()
