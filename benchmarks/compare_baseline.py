"""CI perf-regression gate: compare BENCH_*.json artifacts to a baseline.

The tracked metrics live in :data:`SPEC`; the checked-in baseline
(``benchmarks/baselines/bench_baseline.json``) pins their reference
values.  Count-based metrics (rotation counts, plans resolved, buckets)
are compared near-exactly — they are deterministic where wall times are
noisy; rate metrics (interpret-mode Mrot/s, dispatch overhead) fail the
job when they regress more than ``rel_tol`` (default 30%) past the
baseline, with an ``abs_floor`` below which micro-timing jitter is
ignored.  Improvements never fail.  Warn-only rows additionally carry a
``live_floor``: ordinary noise only warns, but a rate that collapses
below the absolute floor (e.g. a hung fused kernel driving serve
throughput to ~0) hard-fails the job.

Usage::

  python benchmarks/compare_baseline.py \
      --baseline benchmarks/baselines/bench_baseline.json \
      BENCH_smoke.json BENCH_eig.json BENCH_serve.json

  # regenerate the baseline from fresh artifacts (then commit it)
  python benchmarks/compare_baseline.py --update --baseline ... BENCH_*.json
"""
from __future__ import annotations

import argparse
import json
import sys

# metric key: "<row name>:<metrics key>" as emitted by benchmarks.common
SPEC = {
    # dispatch overhead of per-call registry dispatch vs frozen
    # SequencePlan.apply — the plan-once/apply-many win; lower is better.
    "smoke/plan_once_apply_many:dispatch_overhead_us": dict(
        higher_is_better=False, rel_tol=0.30, abs_floor=500.0),
    # recorded-rotation application throughput (interpret-mode CPU CI).
    # Shared runners show ~2x wall-clock noise, so besides the 30%
    # relative band an absolute floor keeps the gate meaningful: any
    # run above it is in the right performance class (an
    # order-of-magnitude regression — e.g. dispatch falling off the
    # blocked path — still fails), while CPU-contention jitter passes.
    "eig/qr_apply_n64:mrot_s": dict(higher_is_better=True, rel_tol=0.30,
                                    abs_floor=0.5),
    # count-based: rotations recorded for the n=64 QR path (seeded,
    # deterministic up to libm convergence differences).
    "eig/qr_apply_n64:nrot": dict(higher_is_better=True, rel_tol=0.02,
                                  count=True),
    # count-based serving invariants: exactly one registry resolution
    # per shape bucket, and the expected bucket count.
    "serve/bucketed:plans_resolved": dict(higher_is_better=False,
                                          rel_tol=0.0, count=True),
    "serve/bucketed:buckets": dict(higher_is_better=False, rel_tol=0.0,
                                   count=True),
    # Serving wall-clock rates include Python admission overhead and
    # vary >30% even between runs on one machine, so they are tracked
    # as warn-only context rather than gating the job — the gating
    # serving metrics are the counts above (plus the issue-scoped
    # dispatch-overhead / Mrot/s rates).  ``live_floor`` is the
    # absolute liveness backstop under warn-only: noise never fails
    # the gate, but a rate that *collapses* below the floor (a hung
    # fused kernel, a serving path that stopped returning) is a real
    # outage and fails CI instead of warning.
    "serve/bucketed:req_s": dict(higher_is_better=True, rel_tol=0.30,
                                 warn_only=True, live_floor=1.0),
    # real-vs-pad accounting (the PR 7 throughput fix): identity pad
    # slots on partially-full buckets are counted separately from real
    # requests and must stay at exactly zero for the canonical demo
    # stream (three exactly-full buckets).
    "serve/bucketed:pad_slots": dict(higher_is_better=False, rel_tol=0.0,
                                     count=True),
    "serve/bucketed:pad_slot_fraction": dict(higher_is_better=False,
                                             rel_tol=0.0, count=True),
    # obs-attributed serving telemetry (PR 7), warn-only context rows:
    # a fresh service resolving the canonical shapes must find every
    # plan in the process plan cache (hit rate 1.0), and the
    # admit->drain p99 tracks the tail a caller actually experiences.
    "serve/bucketed:plan_cache_hit_rate": dict(higher_is_better=True,
                                               rel_tol=0.10,
                                               warn_only=True,
                                               live_floor=0.0),
    "serve/bucketed:latency_p99_ms": dict(higher_is_better=False,
                                          rel_tol=0.50, warn_only=True),
    "serve/shared_batch:speedup": dict(higher_is_better=True,
                                       rel_tol=0.30, warn_only=True,
                                       live_floor=0.05),
    # fused one-launch bucket execution vs the per-request vmap/loop
    # fallback at batch 64 (CPU interpret mode).  Gating, not warn-only:
    # the abs_floor encodes the acceptance bar — any run >= 1.5x passes
    # regardless of baseline drift, and a run below it that also misses
    # the relative band fails.
    "serve/fused_vs_vmap:speedup": dict(higher_is_better=True,
                                        rel_tol=0.50, abs_floor=1.5),
    # measured-auto vs the old hand-pinned rotseq_batched plan on the
    # per-request acceptance bucket.  Gating: the serving-aware cost
    # model (per-request pricing + autotune arbitration) must never
    # cost more than ~11% of the pinned throughput — the abs_floor is
    # the acceptance bar (>= 0.9x passes regardless of baseline drift).
    "serve/auto_vs_pinned:ratio": dict(higher_is_better=True,
                                       rel_tol=0.30, abs_floor=0.9),
    # pure cost-model row: modeled per-request setup cliff (accumulated
    # over rotseq_batched, penalty-free attribution) at batch 64.  The
    # live_floor pins the >= 5x acceptance bar; deterministic
    # arithmetic, warn-only so model retunes surface in artifacts
    # without gating unrelated PRs unless the cliff flattens away.
    "serve/prediction_cliff:ratio": dict(higher_is_better=True,
                                         rel_tol=0.10, warn_only=True,
                                         live_floor=5.0),
    # sustained streaming throughput (the repro.serve.stream engine,
    # open-loop at batch 64).  ``live_floor`` encodes the subsystem's
    # acceptance bar — 5x the synchronous serve/bucketed baseline rate
    # (5 x 1750.999 req/s) — unconditionally: ordinary wall-clock noise
    # against the committed baseline only warns, but a run that cannot
    # clear 5x-synchronous means the engine lost its pipelining (a
    # blocking admission path, a serialized dispatcher) and fails CI.
    "serve/stream:req_s": dict(higher_is_better=True, rel_tol=0.30,
                               warn_only=True, live_floor=8755.0),
    # admit->result tail under saturation: dominated by the deliberate
    # open-loop queueing (max_pending deep), tracked warn-only for
    # drift like every other wall-clock serving row.
    "serve/stream:latency_p99_ms": dict(higher_is_better=False,
                                        rel_tol=0.50, warn_only=True),
    # sharded-fused vs replicated bucket execution on the forced
    # 8-device host mesh (PR 10).  Gating, with a deliberately lenient
    # abs_floor: CPU host "devices" are threads sharing one socket, so
    # the bar is "sharded execution stays in its performance class"
    # (>= 0.2x replicated), not a real multi-chip speedup.
    "dist/sharded_vs_replicated:speedup": dict(higher_is_better=True,
                                               rel_tol=0.50,
                                               abs_floor=0.2),
    # count-based acceptance invariants: exactly one planned launch per
    # shard, and bit-identical output vs the replicated batched path.
    "dist/sharded_vs_replicated:launches_per_shard": dict(
        higher_is_better=False, rel_tol=0.0, count=True),
    "dist/sharded_vs_replicated:parity": dict(higher_is_better=True,
                                              rel_tol=0.0, count=True),
    # comm-extended cost-model context rows (deterministic arithmetic,
    # warn-only so model retunes surface without gating unrelated PRs):
    # modeled inter-device bytes for the benchmark dispatch, and the
    # modeled replicated/sharded crossover ratio at the same shape.
    "dist/comm_model:comm_bytes": dict(higher_is_better=False,
                                       rel_tol=0.10, warn_only=True),
    "dist/comm_model:modeled_crossover_ratio": dict(
        higher_is_better=True, rel_tol=0.30, warn_only=True),
}


def _collect(artifact_paths) -> dict:
    """Flatten rows of all artifacts into {"row:metric": value}."""
    found = {}
    for path in artifact_paths:
        with open(path) as f:
            payload = json.load(f)
        for row in payload.get("rows", []):
            for mkey, val in row.get("metrics", {}).items():
                found[f"{row['name']}:{mkey}"] = float(val)
    return found


def _check(name: str, spec: dict, base: float, cur: float):
    """Returns (ok, message)."""
    rel_tol = spec.get("rel_tol", 0.30)
    floor = spec.get("abs_floor", 0.0)
    if spec.get("count"):
        ok = abs(cur - base) <= rel_tol * max(abs(base), 1.0)
        kind = "count"
    elif spec.get("higher_is_better", True):
        ok = cur >= base * (1.0 - rel_tol) or cur >= floor > 0
        kind = "rate"
    else:
        ok = cur <= base * (1.0 + rel_tol) or cur <= floor
        kind = "rate"
    verdict = "ok" if ok else "REGRESSED"
    return ok, (f"{verdict:9s} {name} [{kind}] "
                f"baseline={base:.4g} current={cur:.4g} "
                f"(rel_tol={rel_tol:.0%})")


def _evaluate(name: str, spec: dict, base: float, cur: float):
    """Full row verdict including warn-only + liveness-floor semantics.

    Warn-only rows absorb noise (a relative miss only warns) but never
    outages: a current value below the absolute ``live_floor`` — or
    NaN — hard-fails even under ``warn_only`` (a serving rate that
    collapsed to ~0 is a hung kernel, not jitter).
    """
    if spec.get("warn_only"):
        # the floor is checked unconditionally: a collapsed rate must
        # fail even when the committed baseline has itself drifted low
        # enough that the relative band would still be satisfied
        floor = spec.get("live_floor", 0.0)
        if cur != cur or cur < floor:
            return False, (f"DEAD      {name} [liveness] "
                           f"current={cur:.4g} < live_floor={floor:.4g} "
                           f"— rate collapsed, failing despite warn-only")
    ok, msg = _check(name, spec, base, cur)
    if not ok and spec.get("warn_only"):
        return True, msg.replace("REGRESSED", "WARN     ") + " [warn-only]"
    return ok, msg


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--update", action="store_true",
                    help="write the baseline from the artifacts instead "
                         "of comparing")
    ap.add_argument("artifacts", nargs="+")
    args = ap.parse_args()

    found = _collect(args.artifacts)

    if args.update:
        metrics = {}
        for name in SPEC:
            if name not in found:
                sys.exit(f"cannot update baseline: metric {name!r} "
                         f"missing from artifacts")
            metrics[name] = found[name]
        with open(args.baseline, "w") as f:
            json.dump({"format": 1, "metrics": metrics}, f, indent=1,
                      sort_keys=True)
            f.write("\n")
        print(f"wrote baseline {args.baseline} ({len(metrics)} metrics)")
        return

    with open(args.baseline) as f:
        baseline = json.load(f)
    base_metrics = baseline.get("metrics", {})

    failures = []
    for name, base_val in sorted(base_metrics.items()):
        spec = SPEC.get(name, dict(higher_is_better=True, rel_tol=0.30))
        if name not in found:
            failures.append(name)
            print(f"MISSING   {name} (baseline={base_val:.4g}) — not "
                  f"emitted by the provided artifacts")
            continue
        ok, msg = _evaluate(name, spec, float(base_val), found[name])
        print(msg)
        if not ok:
            failures.append(name)
    if failures:
        sys.exit(f"benchmark regression gate failed: {failures}")
    print(f"benchmark regression gate passed "
          f"({len(base_metrics)} metrics)")


if __name__ == "__main__":
    main()
