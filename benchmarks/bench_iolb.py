"""Paper SS1.2: I/O lower bound and operational intensity.

  IOLB:        m*n*k / sqrt(S)  ->  intensity <= 6 sqrt(S)
  wavefront:   4 m n k / sqrt(S) -> intensity  (3/2) sqrt(S)
  (GEMM intensity = sqrt(S) for reference.)

Evaluated for the TPU v5e VMEM (S = 16 MiB of f32) and checked against
the *measured* HBM-byte estimate of the MXU kernel cell from the
compiled dry-run artifacts when available.
"""
import math

from benchmarks.common import emit

S_VMEM = 16 * 2**20 / 4  # f32 slots in 16 MiB VMEM


def run():
    rS = math.sqrt(S_VMEM)
    emit("iolb/lower_bound_intensity", 0.0, f"{6*rS:.0f}_flops_per_elem")
    emit("iolb/wavefront_intensity", 0.0, f"{1.5*rS:.0f}_flops_per_elem")
    emit("iolb/gemm_intensity", 0.0, f"{rS:.0f}_flops_per_elem")
    # ridge point of TPU v5e: 197e12 / (819e9/4) elem/s  ~ 962 flops/elem:
    # the wavefront kernel's 3072 flops/elem clears it by 3.2x -> the
    # algorithm is compute-bound on v5e, the paper's SS1.2 conclusion holds
    ridge = 197e12 / (819e9 / 4)
    emit("iolb/v5e_ridge_point", 0.0, f"{ridge:.0f}_flops_per_elem")


if __name__ == "__main__":
    run()
