"""Registry smoke benchmark: one timed row per registered backend.

Runs a small problem through every backend in the dispatch registry
(Pallas paths in interpret mode off-TPU), then through ``method="auto"``
twice — the second call must hit the plan cache.  This is the
end-to-end liveness row for the dispatch subsystem, not a perf number.
"""
from benchmarks.common import (apply_method, emit, flops_of, problem,
                               registered_methods, select_plan, time_fn)
from repro.core.registry import plan_cache_stats

M, N, K = 16, 33, 7


def run():
    A, seq = problem(M, N, K)
    for method in registered_methods():
        kw = dict(n_b=8, k_b=4)
        if method.startswith("pallas"):
            kw.update(m_blk=8, interpret=True)
        dt = time_fn(lambda: apply_method(A, seq, method, **kw))
        gf = flops_of(M, N, K) / dt / 1e9
        emit(f"smoke/{method}", dt, f"{gf:.3f}_Gflops")

    plan = select_plan(M, N, K, dtype=A.dtype)
    hits0 = plan_cache_stats()["hits"]
    dt = time_fn(lambda: apply_method(A, seq, "auto"))
    assert plan_cache_stats()["hits"] > hits0, "auto plan cache missed"
    emit(f"smoke/auto->{plan.method}", dt,
         f"nb{plan.n_b}_kb{plan.k_b}_cached")


if __name__ == "__main__":
    run()
