"""Registry smoke benchmark: one timed row per registered backend.

Runs a small problem through every backend in the dispatch registry
(Pallas paths in interpret mode off-TPU), then through ``method="auto"``
twice — the second call must hit the plan cache.  This is the
end-to-end liveness row for the dispatch subsystem, not a perf number.
"""
from benchmarks.common import (apply_method, emit, flops_of, problem,
                               registered_methods, select_plan, time_fn,
                               timing)
from repro.core.registry import plan_cache_stats

M, N, K = 16, 33, 7


def run():
    A, seq = problem(M, N, K)
    for method in registered_methods():
        kw = dict(n_b=8, k_b=4)
        if method.startswith("pallas"):
            kw.update(m_blk=8, interpret=True)
        dt = time_fn(lambda: apply_method(A, seq, method, **kw))
        gf = flops_of(M, N, K) / dt / 1e9
        emit(f"smoke/{method}", dt, f"{gf:.3f}_Gflops")

    plan = select_plan(M, N, K, dtype=A.dtype)
    hits0 = plan_cache_stats()["hits"]
    dt = time_fn(lambda: apply_method(A, seq, "auto"))
    hit_delta = plan_cache_stats()["hits"] - hits0
    assert hit_delta > 0, "auto plan cache missed"
    emit(f"smoke/auto->{plan.method}", dt,
         f"nb{plan.n_b}_kb{plan.k_b}_cached",
         metrics={"cache_hit": 1})

    # plan-once/apply-many: amortized SequencePlan.apply vs per-call
    # registry dispatch — the API-level win the typed interface exists
    # for (dispatch + plan-cache probe + kwarg plumbing off the hot path)
    frozen = seq.plan(like=A, method="auto")
    dt_plan = time_fn(lambda: frozen.apply(A))
    dt_dispatch = time_fn(lambda: apply_method(A, seq, "auto"))
    assert (frozen.apply(A) == apply_method(A, seq, "auto")).all(), \
        "SequencePlan.apply diverged from dispatched apply"
    overhead_us = max(dt_dispatch - dt_plan, 0.0) * 1e6
    emit("smoke/plan_once_apply_many", dt_plan,
         f"dispatch_overhead_{overhead_us:.1f}us",
         metrics={"dispatch_overhead_us": overhead_us,
                  "plan_apply_us": dt_plan * 1e6})

    # eigensolver liveness: QR path end-to-end through the delayed buffer
    import numpy as np
    import jax.numpy as jnp

    from repro.eig import eigh_givens

    rng = np.random.default_rng(0)
    X = rng.standard_normal((16, 16)).astype(np.float32)
    H = jnp.asarray((X + X.T) / 2)
    t0 = timing.now()
    w, V = eigh_givens(H, method="qr", k_delay=8)
    dt = timing.now() - t0
    resid = float(jnp.abs(V.T @ H @ V - jnp.diag(w)).max())
    assert resid < 1e-4, f"eigh_givens residual {resid}"
    emit("smoke/eigh_qr_n16", dt, f"resid_{resid:.1e}")


if __name__ == "__main__":
    run()
