"""Eigensolver workload: recorded-rotation application throughput.

For each ``n`` this generates the full QR-path recording (staircase
tridiagonalization waves + one wave per implicit-shift sweep) and a
round-robin Jacobi recording, then times the *application* of the
recorded waves to an ``n x n`` basis through ``method="auto"`` — the
flop-dominant phase of ``eigh_givens`` and the paper's SS5.1 delayed-
sequence use case.  Derived column: applied rotations per second (only
non-identity grid entries are counted as rotations).

Generation (host-side scalar recurrences) is kept off the clock and its
cost bounded: at n=1024 the sweep budget is capped and the timed window
sliced, so the suite stays interactive on CPU.
"""
import numpy as np

import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import jacobi_eigh
from repro.core.rotations import RotationSequence
from repro.eig import tridiag_qr, tridiagonalize

SIZES = (64, 256, 1024)
_K_TIME = 512  # waves per timed application window


def _qr_recording(n: int, rng) -> RotationSequence:
    X = rng.standard_normal((n, n))
    tri = tridiagonalize((X + X.T) / 2)
    max_sweeps = None if n <= 256 else 8  # cap host generation at n=1024
    qr = tridiag_qr(tri.diag, tri.offdiag, max_sweeps=max_sweeps)
    C = np.concatenate([tri.cos, qr.cos], axis=1)
    S = np.concatenate([tri.sin, qr.sin], axis=1)
    return RotationSequence(jnp.asarray(C, jnp.float32),
                            jnp.asarray(S, jnp.float32))


def _time_apply(tag: str, n: int, seq: RotationSequence):
    sl = seq[:min(seq.k, _K_TIME)]  # timed window of recorded waves
    M = jnp.eye(n, dtype=jnp.float32)
    plan = sl.plan(like=M, method="auto")  # plan once, time the applies
    dt = time_fn(lambda: plan.apply(M))
    nrot = int(np.count_nonzero(np.asarray(sl.sin)))
    emit(f"eig/{tag}_n{n}", dt, f"{nrot / dt / 1e6:.2f}_Mrot_s",
         metrics={"mrot_s": nrot / dt / 1e6, "nrot": nrot,
                  "waves": int(sl.k)})


def run(sizes=SIZES) -> None:
    for n in sizes:
        rng = np.random.default_rng(n)
        _time_apply("qr_apply", n, _qr_recording(n, rng))
        X = rng.standard_normal((n, n)).astype(np.float32)
        res = jacobi_eigh(jnp.asarray((X + X.T) / 2),
                          cycles=2 if n <= 256 else 1)
        _time_apply("jacobi_apply", n, res.rotation_sequence())


def main() -> None:
    """Standalone CLI used by CI: ``bench_eig.py --quick --json PATH``."""
    import argparse

    from benchmarks import common

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smallest size only (CI artifact/regression run)")
    ap.add_argument("--json", default=None, metavar="PATH")
    args = ap.parse_args()

    common.reset_results()
    print("name,us_per_call,derived")
    run(sizes=(SIZES[0],) if args.quick else SIZES)
    if args.json:
        common.write_json(args.json, meta={"quick": args.quick})


if __name__ == "__main__":
    main()
