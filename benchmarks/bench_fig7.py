"""Paper Fig. 7: parallel speedup over row shards (paper SS7 + SS8.3).

Runs the row-sharded application on 1/2/4/8 host devices in a
subprocess (the paper parallelizes over ``i_b`` row blocks with OpenMP;
we shard rows over the mesh).  Also reports the column-sharded pipeline
(no CPU analogue in the paper) with its analytic communication ratio.
"""
import os
import subprocess
import sys
import textwrap

from benchmarks.common import emit

_CODE = """
import numpy as np, jax, jax.numpy as jnp
from repro.obs import timing
from repro.core.rotations import random_sequence
from repro.dist import (rot_sequence_row_sharded,
    rot_sequence_column_sharded_padded, column_sharded_comm_bytes)

D = {D}
mesh = jax.make_mesh((D,), ("data",))
m, n, k = 2048, 512, 64
rng = np.random.default_rng(0)
A = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
seq = random_sequence(jax.random.key(0), n, k)

def timed(fn):
    jax.block_until_ready(fn())
    ts = []
    for _ in range(3):
        t0 = timing.now(); jax.block_until_ready(fn())
        ts.append(timing.now() - t0)
    return sorted(ts)[1]

row = timed(lambda: rot_sequence_row_sharded(
    A, seq, mesh, row_axes=("data",), n_b=64, k_b=16,
    method="accumulated"))
mesh2 = jax.make_mesh((1, D), ("data", "model"))
col = timed(lambda: rot_sequence_column_sharded_padded(
    A, seq, mesh2, col_axis="model", n_b=32, k_b=16,
    row_axes=(), method="accumulated"))
comm = column_sharded_comm_bytes(m, n, k, D, 32, 16)
print("RESULT %.6f %.6f %.1f" % (row, col, comm["ratio"]))
"""


def run():
    base = None
    for D in (1, 2, 4, 8):
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={D}"
        env["PYTHONPATH"] = os.path.join(
            os.path.dirname(__file__), "..", "src")
        r = subprocess.run(
            [sys.executable, "-c", textwrap.dedent(_CODE.format(D=D))],
            capture_output=True, text=True, timeout=600, env=env)
        line = [l for l in r.stdout.splitlines()
                if l.startswith("RESULT")]
        if not line:
            emit(f"fig7/D{D}", 0.0, "FAILED")
            continue
        row_t, col_t, ratio = map(float, line[0].split()[1:])
        if D == 1:
            base = row_t
        emit(f"fig7/row_sharded/D{D}", row_t,
             f"speedup_{base/row_t:.2f}x")
        emit(f"fig7/col_pipeline/D{D}", col_t,
             f"comm_ratio_vs_allgather_{ratio:.0f}x")


if __name__ == "__main__":
    run()
