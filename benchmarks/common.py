"""Shared benchmark utilities: timing + CSV emission + JSON artifacts.

Timing is sourced from :mod:`repro.obs.timing` — the single sanctioned
clock (analyzer rule RA502).  This module is the one shim outside
``repro.obs`` allowed to re-export it, so per-file benchmark code never
touches ``time`` directly.
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import apply_rotation_sequence
from repro.core.registry import registered_methods, select_plan
from repro.core.rotations import random_sequence
from repro.obs import timing

__all__ = ["time_fn", "emit", "problem", "flops_of", "apply_method",
           "registered_methods", "select_plan", "timing",
           "reset_results", "collected_results", "write_json"]


# Structured sink mirroring the CSV rows: every emit() appends
# {"name", "us_per_call", "derived", "metrics"} here so CI can write a
# machine-readable BENCH_*.json artifact next to the human CSV stream.
# ``metrics`` holds numeric values the regression compare step consumes
# (counts, rates) without re-parsing the derived string.
_RESULTS: list = []


def reset_results() -> None:
    _RESULTS.clear()


def collected_results() -> list:
    return list(_RESULTS)


def write_json(path: str, meta: dict | None = None) -> str:
    """Write all rows emitted since ``reset_results`` as one artifact."""
    import platform as _platform

    import jax as _jax

    from repro.compat import default_platform

    payload = {
        "format": 1,
        "meta": dict(meta or {}, jax=_jax.__version__,
                     backend=default_platform(),
                     python=_platform.python_version()),
        "rows": collected_results(),
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def problem(m: int, n: int, k: int, seed: int = 0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    A = jnp.asarray(rng.standard_normal((m, n)), dtype)
    seq = random_sequence(jax.random.key(seed), n, k, dtype=dtype)
    return A, seq


def flops_of(m: int, n: int, k: int) -> float:
    return 6.0 * m * (n - 1) * k


def time_fn(fn, *args, reps: int = 3, warmup: int = 1) -> float:
    """Median wall time (s) of a jitted call."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = timing.now()
        jax.block_until_ready(fn(*args))
        ts.append(timing.now() - t0)
    return sorted(ts)[len(ts) // 2]


def emit(name: str, seconds: float, derived: str, metrics: dict | None = None):
    """CSV row: name,us_per_call,derived (+ structured metrics sink).

    ``metrics`` carries the numeric values encoded in ``derived`` (e.g.
    ``{"mrot_s": 12.3}``) into the JSON artifact for the CI regression
    compare; count-based metrics should be exact integers.
    """
    print(f"{name},{seconds*1e6:.1f},{derived}")
    _RESULTS.append({"name": name, "us_per_call": seconds * 1e6,
                     "derived": derived, "metrics": dict(metrics or {})})


def apply_method(A, seq, method: str = "auto", **kw):
    """Benchmark entry point routed through the dispatch registry.

    Deliberately exercises the raw-array compat wrapper (per-call
    dispatch); the plan-once/apply-many comparison row in bench_smoke
    uses ``seq.plan(...).apply`` directly.
    """
    return apply_rotation_sequence(A, seq.cos, seq.sin, method=method, **kw)
