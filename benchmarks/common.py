"""Shared benchmark utilities: timing + CSV emission."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import apply_rotation_sequence
from repro.core.registry import registered_methods, select_plan
from repro.core.rotations import random_sequence

__all__ = ["time_fn", "emit", "problem", "flops_of", "apply_method",
           "registered_methods", "select_plan"]


def problem(m: int, n: int, k: int, seed: int = 0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    A = jnp.asarray(rng.standard_normal((m, n)), dtype)
    seq = random_sequence(jax.random.key(seed), n, k, dtype=dtype)
    return A, seq


def flops_of(m: int, n: int, k: int) -> float:
    return 6.0 * m * (n - 1) * k


def time_fn(fn, *args, reps: int = 3, warmup: int = 1) -> float:
    """Median wall time (s) of a jitted call."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2]


def emit(name: str, seconds: float, derived: str):
    """CSV row: name,us_per_call,derived."""
    print(f"{name},{seconds*1e6:.1f},{derived}")


def apply_method(A, seq, method: str = "auto", **kw):
    """Benchmark entry point routed through the dispatch registry.

    Deliberately exercises the raw-array compat wrapper (per-call
    dispatch); the plan-once/apply-many comparison row in bench_smoke
    uses ``seq.plan(...).apply`` directly.
    """
    return apply_rotation_sequence(A, seq.cos, seq.sin, method=method, **kw)
