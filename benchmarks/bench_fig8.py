"""Paper Fig. 8: 2x2 reflector variants of every algorithm."""
from functools import partial

from repro.core.accumulate import rot_sequence_accumulated
from repro.core.blocked import rot_sequence_blocked
from repro.core.ref import rot_sequence_unoptimized

from benchmarks.common import emit, flops_of, problem, time_fn

K = 180


def run():
    for name, fn, sizes in [
        ("rs_unoptimized", partial(rot_sequence_unoptimized, reflect=True),
         (240,)),
        ("rs_kernel", partial(rot_sequence_blocked, n_b=64, k_b=16,
                              reflect=True), (240, 480, 960)),
        ("rs_gemm", partial(rot_sequence_accumulated, n_b=96, k_b=96,
                            reflect=True), (240, 480, 960)),
    ]:
        for n in sizes:
            A, seq = problem(n, n, K)
            dt = time_fn(fn, A, seq.cos, seq.sin)
            gf = flops_of(n, n, K) / dt / 1e9
            emit(f"fig8/{name}_reflect/n{n}", dt, f"{gf:.2f}_Gflops")


if __name__ == "__main__":
    run()
