"""Paper SS3 memory-operation model (Eqs 3.1-3.5) + TPU VMEM analogue.

Analytic table: memory operations per applied rotation for each reuse
level, and the (paper Eq 5.1-5.6 style) tile-size derivation for the TPU
memory hierarchy (VMEM playing every cache level at once).
"""
from benchmarks.common import emit

M_B, N_B, K_B = 4800, 216, 60  # paper's choices for context


def memops(m_b, n_b, k_b, *, n_r=None, k_r=None, m_r=None, kind="basic"):
    """Memory ops per rotation (paper SS3), normalized by m_b*(n_b-k_b)*k_b."""
    rot = m_b * (n_b - k_b) * k_b
    if kind == "basic":        # Eq 3.1
        ops = 4 * rot + 2 * (n_b - k_b) * k_b
    elif kind == "fused22":    # Eq 3.2
        ops = 2 * rot + 2 * (n_b - k_b) * k_b
    elif kind == "fused_nrkr":  # Eq 3.3
        ops = (2 / n_r + 2 / k_r + 2 / m_b) * rot
    elif kind == "wave_kernel":  # Eq 3.4
        ops = (2 / k_r + 2 / n_b + 2 / m_r) * rot
    return ops / rot


def run():
    emit("memops/basic", 0.0, f"{memops(M_B, N_B, K_B, kind='basic'):.3f}_ops_per_rot")
    emit("memops/fused_2x2", 0.0,
         f"{memops(M_B, N_B, K_B, kind='fused22'):.3f}_ops_per_rot")
    emit("memops/fused_2x2_eq33", 0.0,
         f"{memops(M_B, N_B, K_B, kind='fused_nrkr', n_r=2, k_r=2):.3f}_ops_per_rot")
    # paper kernels (Eq 3.4): m_r=8,k_r=5 vs m_r=16,k_r=2
    for m_r, k_r in [(8, 5), (16, 2), (12, 3)]:
        v = memops(M_B, N_B, K_B, kind='wave_kernel', m_r=m_r, k_r=k_r)
        emit(f"memops/kernel_mr{m_r}_kr{k_r}", 0.0, f"{v:.3f}_ops_per_rot")
    # TPU adaptation: VMEM tile (m_blk rows in lanes) — the same formula
    # with m_r -> m_blk=256 lanes, k_r -> k_b=16 waves in VMEM
    v = memops(M_B, N_B, 16, kind='wave_kernel', m_r=256, k_r=16)
    emit("memops/tpu_vmem_kernel_mblk256_kb16", 0.0,
         f"{v:.3f}_hbm_ops_per_rot")
    # MXU path: HBM ops per rotation = (2/k_b + 2/n_b + 2/m)*...*(flop
    # overhead 4/3) with n_b=k_b=128
    v = memops(M_B, 256, 128, kind='wave_kernel', m_r=256, k_r=128)
    emit("memops/tpu_mxu_kernel_nb128_kb128", 0.0,
         f"{v:.3f}_hbm_ops_per_rot")


if __name__ == "__main__":
    run()
