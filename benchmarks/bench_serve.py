"""Rotation-serving workload: batched application + bucketed service.

Two rows:

* ``serve/shared_batch`` — the core amortization
  :meth:`~repro.core.sequence.SequencePlan.apply_batched` exists for:
  one sequence applied to a batch of targets flattens to a single
  ``(b*m, n)`` memory pass, paying per-sequence setup (tile packing,
  accumulated ``Q_t`` factors) once instead of ``b`` times.  Timed
  against ``b`` separate ``plan.apply`` calls on the accumulated
  backend, where the amortized term dominates.
* ``serve/bucketed`` — the :class:`~repro.serve.RotationService` path:
  a mixed-shape stream admitted into shape buckets and executed through
  one frozen plan per bucket.  Wall-clock request throughput is noisy
  on shared CI runners, so the regression gate keys on this row's
  *count* metrics (buckets, registry plan resolutions) plus the
  throughput with generous headroom.
"""
import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core.registry import plan_cache_stats
from repro.core.rotations import random_sequence
from repro.serve import RotationService
from repro.serve.rotations import synthetic_stream

REQUESTS = 24
SLOTS = 8


def _shared_batch() -> None:
    rng = np.random.default_rng(0)
    b, m, n, k = 8, 64, 128, 32
    A = jnp.asarray(rng.standard_normal((b, m, n)), jnp.float32)
    seq = random_sequence(jax.random.key(0), n, k)
    plan = seq.plan(like=A, method="accumulated")
    dt_batched = time_fn(lambda: plan.apply_batched(A))
    plan1 = seq.plan(like=A[0], method="accumulated")
    dt_loop = time_fn(lambda: jax.block_until_ready(
        [plan1.apply(A[i]) for i in range(b)]))
    speedup = dt_loop / dt_batched if dt_batched > 0 else float("inf")
    emit("serve/shared_batch", dt_batched,
         f"x{speedup:.2f}_vs_{b}_applies",
         metrics={"speedup": speedup, "batch": b})


def _bucketed() -> None:
    # the canonical demo stream (repro.serve.rotations.DEMO_SHAPES) —
    # the launcher's --rotations mode drives the same workload, so the
    # CI bucket-count invariant tracks one definition
    requests = synthetic_stream(REQUESTS)
    misses0 = plan_cache_stats()["misses"]
    svc = RotationService(slots=SLOTS, store=False)
    svc.apply_many(requests)  # cold pass resolves one plan per bucket
    resolved = plan_cache_stats()["misses"] - misses0
    dt = time_fn(lambda: jax.block_until_ready(svc.apply_many(requests)))
    emit("serve/bucketed", dt,
         f"{REQUESTS / dt:.0f}_req_s_{len(svc._plans)}_buckets",
         metrics={"req_s": REQUESTS / dt,
                  "buckets": len(svc._plans),
                  "plans_resolved": resolved})


def run() -> None:
    _shared_batch()
    _bucketed()


if __name__ == "__main__":
    run()
