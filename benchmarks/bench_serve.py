"""Rotation-serving workload: batched application + bucketed service.

Three rows:

* ``serve/shared_batch`` — the core amortization
  :meth:`~repro.core.sequence.SequencePlan.apply_batched` exists for:
  one sequence applied to a batch of targets flattens to a single
  ``(b*m, n)`` memory pass, paying per-sequence setup (tile packing,
  accumulated ``Q_t`` factors) once instead of ``b`` times.  Timed
  against ``b`` separate ``plan.apply`` calls on the accumulated
  backend, where the amortized term dominates.
* ``serve/bucketed`` — the :class:`~repro.serve.RotationService` path:
  a mixed-shape stream admitted into shape buckets and executed through
  one frozen plan per bucket.  Wall-clock request throughput is noisy
  on shared CI runners, so the regression gate keys on this row's
  *count* metrics (buckets, registry plan resolutions) plus the
  throughput with generous headroom.
* ``serve/fused_vs_vmap`` — one fused ``rotseq_batched`` launch for a
  batch-64 bucket of wave-padded per-request sequences vs the same
  bucket through the per-request Pallas loop (``pallas_wave``,
  ``supports_vmap=False`` — one launch per request).  Both interpret
  mode on CPU CI; the ``speedup`` metric gates at an absolute 1.5x
  floor (the fused kernel skips the ``pad_to`` identity waves and pays
  dispatch once).
* ``serve/stream`` — sustained load through the async
  :class:`~repro.serve.StreamEngine`: open-loop submission into the
  batch-64 acceptance bucket for a fixed wall-clock window (block
  backpressure bounds pending work), then a draining close.  Sustained
  req/s is completed-requests over the window+drain; the p50/p99
  admit->result latencies come from the same
  ``serve.request_latency_seconds`` histogram the CI artifacts export.
  The acceptance bar (>= 5x the synchronous ``serve/bucketed`` rate)
  is the row's ``live_floor`` in the regression gate.
"""
import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn, timing
from repro import obs
from repro.core.registry import plan_cache_stats
from repro.core.rotations import random_sequence
from repro.serve import RotationService, StreamEngine
from repro.serve.rotations import synthetic_stream

REQUESTS = 24
SLOTS = 8
STREAM_WINDOW_S = 1.0
STREAM_BATCH = 64


def _shared_batch() -> None:
    rng = np.random.default_rng(0)
    b, m, n, k = 8, 64, 128, 32
    A = jnp.asarray(rng.standard_normal((b, m, n)), jnp.float32)
    seq = random_sequence(jax.random.key(0), n, k)
    plan = seq.plan(like=A, method="accumulated")
    dt_batched = time_fn(lambda: plan.apply_batched(A))
    plan1 = seq.plan(like=A[0], method="accumulated")
    dt_loop = time_fn(lambda: jax.block_until_ready(
        [plan1.apply(A[i]) for i in range(b)]))
    speedup = dt_loop / dt_batched if dt_batched > 0 else float("inf")
    emit("serve/shared_batch", dt_batched,
         f"x{speedup:.2f}_vs_{b}_applies",
         metrics={"speedup": speedup, "batch": b})


def _bucketed() -> None:
    # the canonical demo stream (repro.serve.rotations.DEMO_SHAPES) —
    # the launcher's --rotations mode drives the same workload, so the
    # CI bucket-count invariant tracks one definition
    requests = synthetic_stream(REQUESTS)
    misses0 = plan_cache_stats()["misses"]
    svc = RotationService(slots=SLOTS, store=False)
    svc.apply_many(requests)  # cold pass resolves one plan per bucket
    resolved = plan_cache_stats()["misses"] - misses0
    dt = time_fn(lambda: jax.block_until_ready(svc.apply_many(requests)))
    # obs-attributed metrics from separate passes (timing above stays
    # obs-off so the req_s row is comparable across PRs): real requests
    # vs identity pad slots and the admit->drain latency tail come from
    # one warm pass; the plan-cache hit rate from a *fresh* service
    # re-resolving the same shapes, which must find every plan in the
    # process plan cache.  All warn-only or exact-count in the gate.
    with obs.override(True):
        obs.reset()
        jax.block_until_ready(svc.apply_many(requests))
        svc2 = RotationService(slots=SLOTS, store=False)
        jax.block_until_ready(svc2.apply_many(requests))
        snap = obs.snapshot()
    c = snap["counters"]
    hits = c.get("registry.plan_cache.hits", 0)
    misses = c.get("registry.plan_cache.misses", 0)
    lat = snap["histograms"].get("serve.request_latency_seconds", {})
    emit("serve/bucketed", dt,
         f"{REQUESTS / dt:.0f}_req_s_{len(svc._plans)}_buckets",
         metrics={"req_s": REQUESTS / dt,
                  "buckets": len(svc._plans),
                  "plans_resolved": resolved,
                  "pad_slots": c.get("serve.pad_slots", 0),
                  "pad_slot_fraction":
                      snap["gauges"].get("serve.pad_slot_fraction", 0.0),
                  "plan_cache_hit_rate": hits / max(1, hits + misses),
                  "latency_p99_ms": lat.get("p99", 0.0) * 1e3})


def _fused_vs_vmap() -> None:
    """Acceptance row: fused one-launch bucket vs per-request launches.

    Batch 64, requests recorded at k=5 and pad_to'd to the bucket's
    k_pad=8 (identity tail the fused kernel skips, the loop multiplies
    through), CPU interpret mode for both sides.
    """
    rng = np.random.default_rng(0)
    b, m, n, k_req, k_pad = 64, 16, 32, 5, 8
    A = jnp.asarray(rng.standard_normal((b, m, n)), jnp.float32)
    seqs = [random_sequence(jax.random.key(i), n, k_req).pad_to(k_pad)
            for i in range(b)]
    plan_fused = seqs[0].plan(like=A, method="rotseq_batched")
    jax.block_until_ready(plan_fused.apply_batched(A, sequences=seqs))
    dt_fused = time_fn(lambda: plan_fused.apply_batched(A, sequences=seqs))
    plan_vmap = seqs[0].plan(like=A, method="pallas_wave")
    jax.block_until_ready(plan_vmap.apply_batched(A, sequences=seqs))
    # default reps=3: with reps=2 the "median" is the slower sample,
    # which would bias the gated speedup upward
    dt_vmap = time_fn(lambda: plan_vmap.apply_batched(A, sequences=seqs))
    speedup = dt_vmap / dt_fused if dt_fused > 0 else float("inf")
    emit("serve/fused_vs_vmap", dt_fused,
         f"x{speedup:.2f}_vs_{b}_per_request_launches",
         metrics={"speedup": speedup, "batch": b,
                  "fused_s": dt_fused, "vmap_s": dt_vmap})


def _stream() -> None:
    """Sustained-load streaming row (the acceptance bucket at batch 64).

    Open loop: the driver submits as fast as the engine admits for
    ``STREAM_WINDOW_S`` of wall clock (block backpressure caps pending
    work at four bucket closes, so the loop degrades gracefully into
    closed-loop when the device is the bottleneck), then closes with a
    full drain.  Throughput counts every completed request over the
    window plus drain; latencies are admit->result from the obs
    histogram, so the p99 includes queueing under saturation.
    """
    m, n, k_req = 16, 32, 5  # pads to the k_pad=8 acceptance bucket
    rng = np.random.default_rng(0)
    pool = [(random_sequence(jax.random.key(i), n, k_req),
             jnp.asarray(rng.standard_normal((m, n)), jnp.float32))
            for i in range(128)]
    with obs.override(True):
        obs.reset()
        # the bucket plans on the paper's fused batched kernel: the
        # ``auto`` cost model prices the bucket as one sequence
        # amortized across the batch (its ``accumulated`` pick rebuilds
        # per-request Q factors every batch on the serving path),
        # while ``rotseq_batched`` is priced for exactly this
        # per-request-waves workload (the serve/fused_vs_vmap row)
        eng = StreamEngine(slots=STREAM_BATCH, store=False,
                           max_pending=4 * STREAM_BATCH,
                           backpressure="block", min_age_s=0.002,
                           method="rotseq_batched")
        # warm outside the window: resolve the bucket plan, compile,
        # and spin up both engine threads on a full batch
        for t in [eng.submit(seq, A) for seq, A in pool[:STREAM_BATCH]]:
            t.result(timeout=120.0)
        obs.reset()  # counters/latencies cover only the timed window
        t0 = timing.now()
        submitted = 0
        while timing.now() - t0 < STREAM_WINDOW_S:
            seq, A = pool[submitted % len(pool)]
            eng.submit(seq, A)
            submitted += 1
        eng.close(drain=True)
        dt = timing.now() - t0
        snap = obs.snapshot()
    c = snap["counters"]
    completed = c.get("serve.stream.completed", 0)
    req_s = completed / dt if dt > 0 else 0.0
    lat = snap["histograms"].get("serve.request_latency_seconds", {})
    p50_ms = lat.get("p50", 0.0) * 1e3
    p99_ms = lat.get("p99", 0.0) * 1e3
    emit("serve/stream", dt,
         f"{req_s:.0f}_req_s_p50_{p50_ms:.2f}ms_p99_{p99_ms:.2f}ms",
         metrics={"req_s": req_s,
                  "completed": completed,
                  "batches": c.get("serve.batches", 0),
                  "closes_size": c.get("serve.stream.closes_size", 0),
                  "closes_age": c.get("serve.stream.closes_age", 0),
                  "latency_p50_ms": p50_ms,
                  "latency_p99_ms": p99_ms})


def run() -> None:
    _shared_batch()
    _bucketed()
    _fused_vs_vmap()
    _stream()


if __name__ == "__main__":
    run()
