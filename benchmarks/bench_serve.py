"""Rotation-serving workload: batched application + bucketed service.

Three rows:

* ``serve/shared_batch`` — the core amortization
  :meth:`~repro.core.sequence.SequencePlan.apply_batched` exists for:
  one sequence applied to a batch of targets flattens to a single
  ``(b*m, n)`` memory pass, paying per-sequence setup (tile packing,
  accumulated ``Q_t`` factors) once instead of ``b`` times.  Timed
  against ``b`` separate ``plan.apply`` calls on the accumulated
  backend, where the amortized term dominates.
* ``serve/bucketed`` — the :class:`~repro.serve.RotationService` path:
  a mixed-shape stream admitted into shape buckets and executed through
  one frozen plan per bucket.  Wall-clock request throughput is noisy
  on shared CI runners, so the regression gate keys on this row's
  *count* metrics (buckets, registry plan resolutions) plus the
  throughput with generous headroom.
* ``serve/fused_vs_vmap`` — one fused ``rotseq_batched`` launch for a
  batch-64 bucket of wave-padded per-request sequences vs the same
  bucket through the per-request Pallas loop (``pallas_wave``,
  ``supports_vmap=False`` — one launch per request).  Both interpret
  mode on CPU CI; the ``speedup`` metric gates at an absolute 1.5x
  floor (the fused kernel skips the ``pad_to`` identity waves and pays
  dispatch once).
* ``serve/auto_vs_pinned`` — ``method="auto"`` (measured autotune on
  the per-request bucket) against the hand-pinned ``rotseq_batched``
  plan on the same batch-64 bucket.  The ratio gates at an absolute
  0.9x floor: the cost model's per-request pricing plus measurement
  must never lose meaningfully to the pin that PR 8 needed.
* ``serve/prediction_cliff`` — pure cost-model row (no kernel runs):
  the penalty-free setup+stream attribution of ``accumulated`` over
  ``rotseq_batched`` at the per-request acceptance bucket.  Warn-only
  floor 5x — the modeled cliff that justifies the per-request setup
  correction (``docs/cost-model.md``, the worked batch-64 example).
* ``serve/stream`` — sustained load through the async
  :class:`~repro.serve.StreamEngine`: open-loop submission into the
  batch-64 acceptance bucket for a fixed wall-clock window (block
  backpressure bounds pending work), then a draining close.  Sustained
  req/s is completed-requests over the window+drain; the p50/p99
  admit->result latencies come from the same
  ``serve.request_latency_seconds`` histogram the CI artifacts export.
  The acceptance bar (>= 5x the synchronous ``serve/bucketed`` rate)
  is the row's ``live_floor`` in the regression gate.  Runs
  ``method="auto"`` — the row exists to prove the serving-aware cost
  model holds the floor without a backend pin.
"""
import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn, timing
from repro import obs
from repro.core.registry import plan_cache_stats
from repro.core.rotations import random_sequence
from repro.serve import RotationService, StreamEngine
from repro.serve.rotations import synthetic_stream

REQUESTS = 24
SLOTS = 8
STREAM_WINDOW_S = 1.0
STREAM_BATCH = 64


def _shared_batch() -> None:
    rng = np.random.default_rng(0)
    b, m, n, k = 8, 64, 128, 32
    A = jnp.asarray(rng.standard_normal((b, m, n)), jnp.float32)
    seq = random_sequence(jax.random.key(0), n, k)
    plan = seq.plan(like=A, method="accumulated")
    dt_batched = time_fn(lambda: plan.apply_batched(A))
    plan1 = seq.plan(like=A[0], method="accumulated")
    dt_loop = time_fn(lambda: jax.block_until_ready(
        [plan1.apply(A[i]) for i in range(b)]))
    speedup = dt_loop / dt_batched if dt_batched > 0 else float("inf")
    emit("serve/shared_batch", dt_batched,
         f"x{speedup:.2f}_vs_{b}_applies",
         metrics={"speedup": speedup, "batch": b})


def _bucketed() -> None:
    # the canonical demo stream (repro.serve.rotations.DEMO_SHAPES) —
    # the launcher's --rotations mode drives the same workload, so the
    # CI bucket-count invariant tracks one definition
    requests = synthetic_stream(REQUESTS)
    misses0 = plan_cache_stats()["misses"]
    svc = RotationService(slots=SLOTS, store=False)
    svc.apply_many(requests)  # cold pass resolves one plan per bucket
    resolved = plan_cache_stats()["misses"] - misses0
    dt = time_fn(lambda: jax.block_until_ready(svc.apply_many(requests)))
    # obs-attributed metrics from separate passes (timing above stays
    # obs-off so the req_s row is comparable across PRs): real requests
    # vs identity pad slots and the admit->drain latency tail come from
    # one warm pass; the plan-cache hit rate from a *fresh* service
    # re-resolving the same shapes, which must find every plan in the
    # process plan cache.  All warn-only or exact-count in the gate.
    with obs.override(True):
        obs.reset()
        jax.block_until_ready(svc.apply_many(requests))
        svc2 = RotationService(slots=SLOTS, store=False)
        jax.block_until_ready(svc2.apply_many(requests))
        snap = obs.snapshot()
    c = snap["counters"]
    hits = c.get("registry.plan_cache.hits", 0)
    misses = c.get("registry.plan_cache.misses", 0)
    lat = snap["histograms"].get("serve.request_latency_seconds", {})
    emit("serve/bucketed", dt,
         f"{REQUESTS / dt:.0f}_req_s_{len(svc._plans)}_buckets",
         metrics={"req_s": REQUESTS / dt,
                  "buckets": len(svc._plans),
                  "plans_resolved": resolved,
                  "pad_slots": c.get("serve.pad_slots", 0),
                  "pad_slot_fraction":
                      snap["gauges"].get("serve.pad_slot_fraction", 0.0),
                  "plan_cache_hit_rate": hits / max(1, hits + misses),
                  "latency_p99_ms": lat.get("p99", 0.0) * 1e3})


def _fused_vs_vmap() -> None:
    """Acceptance row: fused one-launch bucket vs per-request launches.

    Batch 64, requests recorded at k=5 and pad_to'd to the bucket's
    k_pad=8 (identity tail the fused kernel skips, the loop multiplies
    through), CPU interpret mode for both sides.
    """
    rng = np.random.default_rng(0)
    b, m, n, k_req, k_pad = 64, 16, 32, 5, 8
    A = jnp.asarray(rng.standard_normal((b, m, n)), jnp.float32)
    seqs = [random_sequence(jax.random.key(i), n, k_req).pad_to(k_pad)
            for i in range(b)]
    plan_fused = seqs[0].plan(like=A, method="rotseq_batched")
    jax.block_until_ready(plan_fused.apply_batched(A, sequences=seqs))
    dt_fused = time_fn(lambda: plan_fused.apply_batched(A, sequences=seqs))
    plan_vmap = seqs[0].plan(like=A, method="pallas_wave")
    jax.block_until_ready(plan_vmap.apply_batched(A, sequences=seqs))
    # default reps=3: with reps=2 the "median" is the slower sample,
    # which would bias the gated speedup upward
    dt_vmap = time_fn(lambda: plan_vmap.apply_batched(A, sequences=seqs))
    speedup = dt_vmap / dt_fused if dt_fused > 0 else float("inf")
    emit("serve/fused_vs_vmap", dt_fused,
         f"x{speedup:.2f}_vs_{b}_per_request_launches",
         metrics={"speedup": speedup, "batch": b,
                  "fused_s": dt_fused, "vmap_s": dt_vmap})


def _auto_vs_pinned() -> None:
    """Gate: measured-auto must hold against the old hand pin.

    Same per-request bucket as ``serve/fused_vs_vmap``.  ``auto`` plans
    with ``shared_sequence=False`` (the serving path's pricing) and
    ``autotune=True``; the pinned side is the ``rotseq_batched`` plan
    the stream bench hard-coded before the cost model learned to price
    per-request batches.  ``ratio = pinned_s / auto_s`` — 1.0 means
    auto found the pin (or an equal backend), and the gate's 0.9x
    absolute floor means auto may never cost >11% throughput.
    """
    rng = np.random.default_rng(0)
    b, m, n, k_req, k_pad = 64, 16, 32, 5, 8
    A = jnp.asarray(rng.standard_normal((b, m, n)), jnp.float32)
    seqs = [random_sequence(jax.random.key(i), n, k_req).pad_to(k_pad)
            for i in range(b)]
    plan_auto = seqs[0].plan(like=A, method="auto", autotune=True,
                             shared_sequence=False)
    # both sides are ~2.5ms interpret-mode dispatches on CPU CI with
    # +-20% run-to-run jitter; best-of-9 (not median) on each side keeps
    # the gated ratio from flaking against its 0.9x absolute floor —
    # min estimates intrinsic dispatch cost, which is what the ratio
    # compares

    def _best(fn, reps=9):
        jax.block_until_ready(fn())
        ts = []
        for _ in range(reps):
            t0 = timing.now()
            jax.block_until_ready(fn())
            ts.append(timing.now() - t0)
        return min(ts)

    dt_auto = _best(lambda: plan_auto.apply_batched(A, sequences=seqs))
    plan_pin = seqs[0].plan(like=A, method="rotseq_batched")
    dt_pin = _best(lambda: plan_pin.apply_batched(A, sequences=seqs))
    ratio = dt_pin / dt_auto if dt_auto > 0 else float("inf")
    emit("serve/auto_vs_pinned", dt_auto,
         f"auto_{plan_auto.method}_x{ratio:.2f}_vs_pinned",
         metrics={"ratio": ratio, "auto_s": dt_auto, "pinned_s": dt_pin})


def _prediction_cliff() -> None:
    """Warn row: the modeled per-request setup cliff at batch 64.

    No kernels run — this is :func:`repro.core.registry.cost_components`
    arithmetic on the acceptance bucket priced as a per-request batch
    (``shared_sequence=False``, 64 sequences, k_req=5 of k_pad=8 waves
    live).  ``accumulated`` pays 64 Q_t factor builds + packed-tile
    reads per dispatch; ``rotseq_batched`` streams the same rows once.
    The ratio of the penalty-free setup+stream attributions is the
    number ``docs/cost-model.md`` walks through (~5.7x) and the reason
    ``serve/stream`` can run un-pinned.  Warn-only with a 5x floor: a
    model change that flattens the cliff should fail loudly in CI
    artifacts without gating unrelated PRs.
    """
    from repro.core import registry

    b, m, n, k_req, k_pad = 64, 16, 32, 5, 8
    live = (n - 1) * k_req
    prob = registry.Problem(m=m, n=n, k=k_pad, dtype="float32",
                            platform="cpu", batch=b,
                            shared_sequence=False, live_planes=live)
    acc = registry.cost_components(
        "accumulated", prob, registry.Plan("accumulated", n_b=32, k_b=8))
    fused = registry.cost_components(
        "rotseq_batched", prob, registry.Plan("rotseq_batched", m_blk=16))
    acc_s = acc["setup"]["seconds"] + acc["stream"]["seconds"]
    fused_s = fused["setup"]["seconds"] + fused["stream"]["seconds"]
    ratio = acc_s / fused_s if fused_s > 0 else float("inf")
    emit("serve/prediction_cliff", acc_s,
         f"accumulated_x{ratio:.2f}_rotseq_batched_modeled",
         metrics={"ratio": ratio,
                  "accumulated_modeled_s": acc_s,
                  "fused_modeled_s": fused_s,
                  "accumulated_setup_s": acc["setup"]["seconds"],
                  "fused_setup_s": fused["setup"]["seconds"]})


def _stream() -> None:
    """Sustained-load streaming row (the acceptance bucket at batch 64).

    Open loop: the driver submits as fast as the engine admits for
    ``STREAM_WINDOW_S`` of wall clock (block backpressure caps pending
    work at four bucket closes, so the loop degrades gracefully into
    closed-loop when the device is the bottleneck), then closes with a
    full drain.  Throughput counts every completed request over the
    window plus drain; latencies are admit->result from the obs
    histogram, so the p99 includes queueing under saturation.
    """
    m, n, k_req = 16, 32, 5  # pads to the k_pad=8 acceptance bucket
    rng = np.random.default_rng(0)
    pool = [(random_sequence(jax.random.key(i), n, k_req),
             jnp.asarray(rng.standard_normal((m, n)), jnp.float32))
            for i in range(128)]
    with obs.override(True):
        obs.reset()
        # method="auto": the service prices its buckets as per-request
        # batches (shared_sequence=False), so the model stops charging
        # amortized setup for work paid b times.  On CPU the tiny
        # bucket is latency-floor bound and several backends model
        # within noise of each other, so autotune arbitrates: the model
        # prunes tiles, measurement (b distinct sequences through
        # apply_batched) picks the backend — which lands on the fused
        # rotseq_batched / wavefront family the old pin hard-coded.
        eng = StreamEngine(slots=STREAM_BATCH, store=False,
                           max_pending=4 * STREAM_BATCH,
                           backpressure="block", min_age_s=0.002,
                           method="auto", autotune=True)
        # warm outside the window: resolve the bucket plan, compile,
        # and spin up both engine threads on a full batch
        for t in [eng.submit(seq, A) for seq, A in pool[:STREAM_BATCH]]:
            t.result(timeout=120.0)
        obs.reset()  # counters/latencies cover only the timed window
        t0 = timing.now()
        submitted = 0
        while timing.now() - t0 < STREAM_WINDOW_S:
            seq, A = pool[submitted % len(pool)]
            eng.submit(seq, A)
            submitted += 1
        eng.close(drain=True)
        dt = timing.now() - t0
        snap = obs.snapshot()
    c = snap["counters"]
    completed = c.get("serve.stream.completed", 0)
    req_s = completed / dt if dt > 0 else 0.0
    lat = snap["histograms"].get("serve.request_latency_seconds", {})
    p50_ms = lat.get("p50", 0.0) * 1e3
    p99_ms = lat.get("p99", 0.0) * 1e3
    emit("serve/stream", dt,
         f"{req_s:.0f}_req_s_p50_{p50_ms:.2f}ms_p99_{p99_ms:.2f}ms",
         metrics={"req_s": req_s,
                  "completed": completed,
                  "batches": c.get("serve.batches", 0),
                  "closes_size": c.get("serve.stream.closes_size", 0),
                  "closes_age": c.get("serve.stream.closes_age", 0),
                  "latency_p50_ms": p50_ms,
                  "latency_p99_ms": p99_ms})


def run() -> None:
    _shared_batch()
    _bucketed()
    _fused_vs_vmap()
    _auto_vs_pinned()
    _prediction_cliff()
    _stream()


if __name__ == "__main__":
    run()
